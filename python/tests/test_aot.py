"""AOT lowering sanity: HLO text is produced, parseable-looking, and the
manifest matches the emitted files. (The Rust integration test actually
loads and executes the artifacts through PJRT.)"""

import os
import tempfile

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_lower_forces_produces_hlo_text():
    text = aot.lower_forces(128, 8, 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 5 parameters: alpha, yi, yj, p, mask
    assert "parameter(4)" in text
    # tuple root (return_tuple=True)
    assert "tuple(" in text or "ROOT" in text


def test_lower_sqdist_produces_hlo_text():
    text = aot.lower_sqdist(512, 8)
    assert "HloModule" in text
    assert "parameter(1)" in text


def test_build_all_writes_menu_and_manifest():
    with tempfile.TemporaryDirectory() as td:
        manifest = aot.build_all(td, verbose=False)
        files = set(os.listdir(td))
        assert "manifest.txt" in files
        n_expected = len(aot.FORCES_K) * len(aot.FORCES_D) + len(aot.SQDIST_M)
        assert len(manifest) == n_expected
        for line in manifest:
            kind, name = line.split()[0], line.split()[1]
            assert kind in ("forces", "sqdist")
            assert f"{name}.hlo.txt" in files
        with open(os.path.join(td, "manifest.txt")) as f:
            assert f.read().strip().count("\n") == n_expected - 1


def test_graph_outputs_match_kernel_directly():
    """The L2 graph is a thin wrapper: outputs equal the L1 kernel's."""
    rng = np.random.default_rng(7)
    b, k, d = 128, 8, 2
    alpha = jnp.asarray([1.0], dtype=jnp.float32)
    yi = jnp.asarray(rng.standard_normal((b, d)), dtype=jnp.float32)
    yj = jnp.asarray(rng.standard_normal((b, k, d)), dtype=jnp.float32)
    p = jnp.abs(jnp.asarray(rng.standard_normal((b, k)), dtype=jnp.float32))
    mask = jnp.ones((b, k), dtype=jnp.float32)
    out = model.forces_graph(alpha, yi, yj, p, mask)
    assert len(out) == 3
    assert out[0].shape == (b, d)
    assert out[1].shape == (b, d)
    assert out[2].shape == (b,)
