"""Validate the paper's closed-form gradient (Eq. 5) against autodiff of
the heavy-tailed objective (Eq. 4), and connect it to the slot semantics
implemented by the forces kernel / Rust backend."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def make_problem(n=24, d=2, seed=0):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    p = np.abs(rng.standard_normal((n, n))).astype(np.float32)
    p = (p + p.T) / 2.0
    np.fill_diagonal(p, 0.0)
    p /= p.sum()
    return y, jnp.asarray(p)


@pytest.mark.parametrize("alpha", [0.3, 0.5, 1.0, 2.0])
def test_eq5_matches_autodiff(alpha):
    y, p = make_problem(seed=int(alpha * 10))
    auto = jax.grad(lambda yy: ref.kl_loss_alpha(yy, p, alpha))(y)
    closed = ref.grad_formula_eq5(y, p, alpha)
    np.testing.assert_allclose(auto, closed, rtol=2e-3, atol=2e-4)


def test_gradient_zero_at_symmetric_fixed_point():
    """If q == p exactly, the gradient must vanish: place 2 points; p
    matching their q; Eq. 5 gives zero."""
    y = jnp.asarray([[0.0, 0.0], [1.0, 0.0]], dtype=jnp.float32)
    # With n=2 there is a single pair; q_ij = 1/2 each direction.
    p = jnp.asarray([[0.0, 0.5], [0.5, 0.0]], dtype=jnp.float32)
    g = ref.grad_formula_eq5(y, p, 1.0)
    np.testing.assert_allclose(g, jnp.zeros_like(g), atol=1e-6)


def test_attraction_repulsion_split_consistency():
    """The engine's split — attraction Σ p·g·(y_j−y_i) and repulsion
    Σ (w/Z)·g·(y_i−y_j) — recombines into −Eq.5/4 (movement direction =
    negative gradient)."""
    alpha = 0.7
    y, p = make_problem(n=16, seed=3)
    n = y.shape[0]
    diff = y[:, None, :] - y[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    g = 1.0 / (1.0 + d2 / alpha)
    w = (g**alpha) * (1.0 - jnp.eye(n))
    z = jnp.sum(w)
    attr = jnp.sum((p * g)[:, :, None] * (-diff), axis=1)       # toward
    rep = jnp.sum(((w / z) * g)[:, :, None] * diff, axis=1)     # away
    movement = attr + rep
    eq5 = ref.grad_formula_eq5(y, p, alpha)
    np.testing.assert_allclose(movement, -eq5 / 4.0, rtol=1e-4, atol=1e-6)
