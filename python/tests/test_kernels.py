"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.forces import forces_tile, BLOCK_B
from compile.kernels.sqdist import sqdist_tile, BLOCK_T


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@pytest.mark.parametrize("alpha", [0.3, 0.5, 1.0, 2.0])
@pytest.mark.parametrize("b,k,d", [(128, 8, 2), (256, 16, 4), (128, 32, 8)])
def test_forces_matches_ref(alpha, b, k, d):
    rng = np.random.default_rng(hash((b, k, d)) % 2**31)
    yi = rand(rng, b, d) * 3.0
    yj = rand(rng, b, k, d) * 3.0
    p = jnp.abs(rand(rng, b, k))
    mask = (rand(rng, b, k) > 0).astype(jnp.float32)
    a = jnp.asarray([alpha], dtype=jnp.float32)
    attr, rep, wsum = forces_tile(a, yi, yj, p, mask)
    eattr, erep, ewsum = ref.forces_ref(yi, yj, p, mask, alpha)
    np.testing.assert_allclose(attr, eattr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rep, erep, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(wsum, ewsum, rtol=1e-5, atol=1e-5)


def test_forces_fully_masked_is_zero():
    b, k, d = BLOCK_B, 8, 2
    rng = np.random.default_rng(0)
    yi, yj = rand(rng, b, d), rand(rng, b, k, d)
    p = jnp.abs(rand(rng, b, k))
    mask = jnp.zeros((b, k), dtype=jnp.float32)
    a = jnp.asarray([1.0], dtype=jnp.float32)
    attr, rep, wsum = forces_tile(a, yi, yj, p, mask)
    assert float(jnp.abs(attr).max()) == 0.0
    assert float(jnp.abs(rep).max()) == 0.0
    assert float(jnp.abs(wsum).max()) == 0.0


def test_forces_attraction_direction():
    """A single neighbour to the right: attraction +x, repulsion -x."""
    b, k, d = BLOCK_B, 8, 2
    yi = jnp.zeros((b, d), dtype=jnp.float32)
    yj = jnp.zeros((b, k, d), dtype=jnp.float32).at[:, 0, 0].set(2.0)
    p = jnp.zeros((b, k), dtype=jnp.float32).at[:, 0].set(1.0)
    mask = jnp.zeros((b, k), dtype=jnp.float32).at[:, 0].set(1.0)
    a = jnp.asarray([1.0], dtype=jnp.float32)
    attr, rep, _ = forces_tile(a, yi, yj, p, mask)
    assert float(attr[0, 0]) > 0.0
    assert float(rep[0, 0]) < 0.0


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([4, 8, 16, 32]),
    d=st.sampled_from([1, 2, 3, 5, 8, 16]),
    alpha=st.floats(0.1, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_forces_hypothesis_shapes(k, d, alpha, seed):
    rng = np.random.default_rng(seed)
    b = BLOCK_B
    yi = rand(rng, b, d)
    yj = rand(rng, b, k, d)
    p = jnp.abs(rand(rng, b, k)) * 0.1
    mask = (rand(rng, b, k) > -0.5).astype(jnp.float32)
    a = jnp.asarray([alpha], dtype=jnp.float32)
    attr, rep, wsum = forces_tile(a, yi, yj, p, mask)
    eattr, erep, ewsum = ref.forces_ref(yi, yj, p, mask, alpha)
    np.testing.assert_allclose(attr, eattr, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(rep, erep, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(wsum, ewsum, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("m", [8, 16, 64, 192])
def test_sqdist_matches_ref(m):
    rng = np.random.default_rng(m)
    a = rand(rng, BLOCK_T, m) * 2.0
    b = rand(rng, BLOCK_T, m) * 2.0
    got = sqdist_tile(a, b)
    expect = ref.sqdist_pairs_ref(a, b)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)


def test_sqdist_zero_for_identical():
    rng = np.random.default_rng(1)
    a = rand(rng, BLOCK_T, 16)
    got = sqdist_tile(a, a)
    np.testing.assert_allclose(got, jnp.zeros(BLOCK_T), atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([4, 8, 32, 128]),
    mult=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_sqdist_hypothesis(m, mult, seed):
    rng = np.random.default_rng(seed)
    t = BLOCK_T * mult
    a = rand(rng, t, m)
    b = rand(rng, t, m)
    got = sqdist_tile(a, b)
    np.testing.assert_allclose(got, ref.sqdist_pairs_ref(a, b), rtol=1e-4, atol=1e-5)


def test_kernel_identities():
    """w = g^alpha, w(0)=1, heavier tails for smaller alpha (mirrors the
    Rust ld::kernel tests so the two layers agree on the math)."""
    d2 = jnp.asarray([0.0, 0.5, 4.0, 25.0], dtype=jnp.float32)
    for alpha in [0.3, 1.0, 3.0]:
        g = ref.grad_factor(d2, alpha)
        w = ref.kernel_w(d2, alpha)
        np.testing.assert_allclose(w, g**alpha, rtol=1e-6)
        assert float(w[0]) == pytest.approx(1.0)
    assert float(ref.kernel_w(jnp.asarray(25.0), 0.3)) > float(
        ref.kernel_w(jnp.asarray(25.0), 1.0)
    )
