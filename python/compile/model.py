"""Layer-2: the JAX compute graphs the Rust coordinator executes.

FUnc-SNE's "model" is the embedding update itself; its fwd/bwd is the
analytic Eq. 5 gradient, which the ``forces`` kernel evaluates directly
(the closed form — validated against ``jax.grad`` of the Eq. 4 objective
in ``python/tests/test_gradient.py``). The L2 graphs below wrap the L1
Pallas kernels so that ``aot.py`` lowers kernel + surrounding graph into
a single HLO module per tile shape.
"""

import jax
import jax.numpy as jnp

from .kernels.forces import forces_tile
from .kernels.sqdist import sqdist_tile

__all__ = ["forces_graph", "sqdist_graph", "example_args_forces",
           "example_args_sqdist"]


def forces_graph(alpha, yi, yj, p, mask):
    """The per-batch force computation (one slot group).

    Returns a tuple (attr, rep, wsum) — tuple-returning so the HLO root
    is a tuple and the Rust side unwraps with ``to_tuple``.
    """
    attr, rep, wsum = forces_tile(alpha, yi, yj, p, mask)
    return (attr, rep, wsum)


def sqdist_graph(a, b):
    """Candidate-scoring graph: squared distances of T flat pairs."""
    return (sqdist_tile(a, b),)


def example_args_forces(b, k, d):
    """ShapeDtypeStructs for lowering a (B, K, D) forces variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((1,), f32),        # alpha
        jax.ShapeDtypeStruct((b, d), f32),      # yi
        jax.ShapeDtypeStruct((b, k, d), f32),   # yj
        jax.ShapeDtypeStruct((b, k), f32),      # p
        jax.ShapeDtypeStruct((b, k), f32),      # mask
    )


def example_args_sqdist(t, m):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((t, m), f32),
        jax.ShapeDtypeStruct((t, m), f32),
    )
