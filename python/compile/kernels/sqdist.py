"""Layer-1 Pallas kernel: flat-pair squared distances.

Scores a batch of KNN candidate pairs in the HD space: the Rust
coordinator gathers owner / candidate coordinate rows into two [T, M]
buffers and gets back the T squared distances in one call, replacing T·M
scalar work on the Rust side with one vectorised tile.

On a real TPU this is the MXU-friendly kernel: per grid step a
[BLOCK_T, M] block reduces over M; the paper notes its GPU build did
*not* parallelise the distance loop — this kernel is the adaptation that
does (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 512


def _sqdist_kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]
    b = b_ref[...]
    diff = a - b
    out_ref[...] = jnp.sum(diff * diff, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def sqdist_tile(a, b):
    """Squared distances of T pairs: a, b are [T, M] → [T]."""
    t_total, m = a.shape
    assert t_total % BLOCK_T == 0, f"T={t_total} must be a multiple of {BLOCK_T}"
    grid = (t_total // BLOCK_T,)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_T, m), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_T, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t_total,), jnp.float32),
        interpret=True,
    )(a, b)
