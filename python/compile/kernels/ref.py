"""Pure-jnp reference oracles for the Pallas kernels and the paper's math.

Everything here is *build-time only*: pytest checks the Pallas kernels
against these references, and ``test_gradient.py`` checks that the paper's
closed-form gradient (Eq. 5) matches ``jax.grad`` of the heavy-tailed KL
objective (Eq. 4) — which in turn validates the slot semantics the Rust
native backend and the Pallas ``forces`` kernel both implement.
"""

import jax.numpy as jnp

__all__ = [
    "grad_factor",
    "kernel_w",
    "forces_ref",
    "sqdist_pairs_ref",
    "kl_loss_alpha",
    "grad_formula_eq5",
]


def grad_factor(sq_dist, alpha):
    """g = w^{1/alpha} = 1 / (1 + d^2/alpha)  (Eq. 5 factor)."""
    return 1.0 / (1.0 + sq_dist / alpha)


def kernel_w(sq_dist, alpha):
    """Heavy-tailed LD kernel w = (1 + d^2/alpha)^(-alpha)  (Eq. 4)."""
    return grad_factor(sq_dist, alpha) ** alpha


def forces_ref(yi, yj, p, mask, alpha):
    """Reference force tile.

    Args:
      yi:   [B, D]     owner coordinates.
      yj:   [B, K, D]  gathered neighbour coordinates (padded).
      p:    [B, K]     attraction conditionals p_{j|i} (0 for
                       repulsion-only slots).
      mask: [B, K]     1.0 for valid slots, 0.0 for padding.
      alpha: scalar    tail-heaviness.

    Returns:
      attr: [B, D]  sum_k  p*g * (y_j - y_i)        (movement toward)
      rep:  [B, D]  sum_k  w*g * (y_i - y_j)        (movement away)
      wsum: [B]     sum_k  w                        (Z-estimate stats)
    """
    diff = yj - yi[:, None, :]                      # [B, K, D]
    d2 = jnp.sum(diff * diff, axis=-1)              # [B, K]
    g = 1.0 / (1.0 + d2 / alpha)
    w = g**alpha
    attr = jnp.sum((p * g * mask)[:, :, None] * diff, axis=1)
    rep = jnp.sum((w * g * mask)[:, :, None] * (-diff), axis=1)
    wsum = jnp.sum(w * mask, axis=1)
    return attr, rep, wsum


def sqdist_pairs_ref(a, b):
    """Reference squared distances of T flat pairs: a, b are [T, M]."""
    diff = a - b
    return jnp.sum(diff * diff, axis=-1)


def kl_loss_alpha(y, p_sym, alpha):
    """The heavy-tailed KL objective of Eq. 4 on a *small dense* problem.

    y:     [n, d] embedding.
    p_sym: [n, n] symmetric HD affinities with zero diagonal, summing to 1.
    alpha: tail parameter.

    Drops the constant sum p log p term: L = -sum_ij p_ij log q_ij.
    """
    n = y.shape[0]
    d2 = jnp.sum((y[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    w = (1.0 + d2 / alpha) ** (-alpha)
    w = w * (1.0 - jnp.eye(n))
    z = jnp.sum(w)
    q = w / z
    eps = 1e-12
    return -jnp.sum(p_sym * jnp.log(q + eps))


def grad_formula_eq5(y, p_sym, alpha):
    """The paper's closed-form gradient (Eq. 5):

        dL/dy_i = 4 * sum_j (p_ij - q_ij) * w_ij^{1/alpha} * (y_i - y_j)

    Note the classical t-SNE derivation yields this with the same
    constant 4 only when P and Q are both normalised over ordered pairs;
    we follow the paper's convention.
    """
    n = y.shape[0]
    diff = y[:, None, :] - y[None, :, :]            # [n, n, d]
    d2 = jnp.sum(diff * diff, axis=-1)
    g = 1.0 / (1.0 + d2 / alpha)
    w = g**alpha
    w = w * (1.0 - jnp.eye(n))
    q = w / jnp.sum(w)
    coeff = (p_sym - q) * g * (1.0 - jnp.eye(n))    # [n, n]
    return 4.0 * jnp.sum(coeff[:, :, None] * diff, axis=1)
