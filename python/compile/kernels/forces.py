"""Layer-1 Pallas kernel: the heavy-tailed force tile.

This is the compute hot-spot of FUnc-SNE: for a tile of B points with K
gathered neighbour slots each, evaluate the Eq. 4/5 kernel terms and
reduce them to per-point attraction / repulsion vectors and the
Z-estimate statistic. The Rust coordinator calls the AOT-compiled HLO of
this kernel three times per batch (HD slots / LD slots / negative
samples — see DESIGN.md §2).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
implementation assigns one GPU thread per point over global-memory
neighbour tables. Here the tile itself is the parallel unit: the Pallas
grid walks B-blocks (the HBM→VMEM schedule a CUDA threadblock would
express), and all K×D math inside a block is vectorised. Block sizing
keeps a block's operands (BLOCK_B·K·D + 2·BLOCK_B·K + 2·BLOCK_B·D f32)
well under VMEM budgets (≤ ~0.6 MiB at B=128, K=32, D=32).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO which both the python
tests and the Rust runtime execute. Real-TPU numbers are estimated in
DESIGN.md instead.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 divides every tile size in the AOT menu.
BLOCK_B = 128


def _forces_kernel(alpha_ref, yi_ref, yj_ref, p_ref, mask_ref,
                   attr_ref, rep_ref, wsum_ref):
    """One B-block: yi [b, D], yj [b, K, D], p/mask [b, K]."""
    alpha = alpha_ref[0]
    yi = yi_ref[...]                       # [b, D]
    yj = yj_ref[...]                       # [b, K, D]
    p = p_ref[...]                         # [b, K]
    mask = mask_ref[...]                   # [b, K]
    diff = yj - yi[:, None, :]             # [b, K, D]
    d2 = jnp.sum(diff * diff, axis=-1)     # [b, K]
    g = 1.0 / (1.0 + d2 / alpha)
    w = g**alpha
    ag = p * g * mask                      # [b, K]
    rg = w * g * mask
    attr_ref[...] = jnp.sum(ag[:, :, None] * diff, axis=1)
    rep_ref[...] = jnp.sum(rg[:, :, None] * (-diff), axis=1)
    wsum_ref[...] = jnp.sum(w * mask, axis=1)


@functools.partial(jax.jit, static_argnames=())
def forces_tile(alpha, yi, yj, p, mask):
    """Force tile: see ``ref.forces_ref`` for exact semantics.

    alpha: [1] f32 (array so it stays a runtime input of the AOT module).
    yi:    [B, D];  yj: [B, K, D];  p, mask: [B, K].
    Returns (attr [B, D], rep [B, D], wsum [B]).
    """
    b_total, d = yi.shape
    _, k, _ = yj.shape
    assert b_total % BLOCK_B == 0, f"B={b_total} must be a multiple of {BLOCK_B}"
    grid = (b_total // BLOCK_B,)
    return pl.pallas_call(
        _forces_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                   # alpha
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),         # yi
            pl.BlockSpec((BLOCK_B, k, d), lambda i: (i, 0, 0)),   # yj
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),         # p
            pl.BlockSpec((BLOCK_B, k), lambda i: (i, 0)),         # mask
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),         # attr
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),         # rep
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),             # wsum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_total, d), jnp.float32),
            jax.ShapeDtypeStruct((b_total, d), jnp.float32),
            jax.ShapeDtypeStruct((b_total,), jnp.float32),
        ],
        interpret=True,
    )(alpha, yi, yj, p, mask)
