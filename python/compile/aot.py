"""AOT lowering: JAX graphs → HLO *text* artifacts + manifest.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which the ``xla`` crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run once via ``make artifacts``; the Rust binary then never touches
Python. Usage:

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# The artifact menu. Tile shapes are chosen so that:
#  * B=512 amortises PJRT dispatch overhead while staying cache-friendly;
#  * K covers the default neighbour-set sizes (k_hd=32, k_ld=16, n_neg=8);
#  * D covers visualisation (2, 3, 4) and the paper's "intermediate
#    dimensionalities" experiments (8, 16, 32);
#  * M covers post-PCA HD dimensionalities (the recommended 16..192).
FORCES_B = 512
FORCES_K = (8, 16, 32)
FORCES_D = (2, 3, 4, 8, 16, 32)
SQDIST_T = 4096
SQDIST_M = (8, 16, 32, 64, 128, 192)


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forces(b, k, d):
    args = model.example_args_forces(b, k, d)
    return to_hlo_text(jax.jit(model.forces_graph).lower(*args))


def lower_sqdist(t, m):
    args = model.example_args_sqdist(t, m)
    return to_hlo_text(jax.jit(model.sqdist_graph).lower(*args))


def build_all(out_dir, verbose=True):
    """Lower the whole menu; returns the manifest lines."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for k in FORCES_K:
        for d in FORCES_D:
            name = f"forces_b{FORCES_B}_k{k}_d{d}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            text = lower_forces(FORCES_B, k, d)
            with open(path, "w") as f:
                f.write(text)
            manifest.append(f"forces {name} B={FORCES_B} K={k} D={d}")
            if verbose:
                print(f"  {name}: {len(text)} chars")
    for m in SQDIST_M:
        name = f"sqdist_t{SQDIST_T}_m{m}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_sqdist(SQDIST_T, m)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"sqdist {name} T={SQDIST_T} M={m}")
        if verbose:
            print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    ns = ap.parse_args()
    manifest = build_all(ns.out_dir, verbose=not ns.quiet)
    print(f"wrote {len(manifest)} artifacts + manifest.txt to {ns.out_dir}")


if __name__ == "__main__":
    main()
