//! Quickstart — build an embedding session with the fluent builder.
//!
//! The one call to learn is `Session::builder()`: give it a dataset,
//! tweak a few fields, `.build()?`, then `.run(...)`. The builder owns
//! backend selection (native vs AOT/PJRT artifacts), config validation
//! and optional PCA pre-reduction; mid-run steering happens through
//! `session.enqueue(Command::…)` (see `interactive_session.rs`). The
//! old direct `FuncSne` setters are internal now — the session command
//! queue is the public mutation path.
//!
//! Runs the full three-layer pipeline on a real small workload: 2 000
//! points of the COIL-20 twin, embedded to 2-D through the **PJRT
//! backend** (AOT-compiled Pallas/XLA tiles; falls back to native with a
//! notice if `make artifacts` hasn't been run), and reports the paper's
//! headline metric — the R_NX(K) AUC — against a UMAP-like baseline,
//! plus throughput. The run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use funcsne::baselines::umap_like::{umap_like, UmapConfig};
use funcsne::config::Backend;
use funcsne::coordinator::driver::{dataset_by_name, default_artifact_dir};
use funcsne::metrics::rnx::rnx_curve;
use funcsne::session::Session;
use funcsne::util::{plot, Stopwatch};

fn main() -> anyhow::Result<()> {
    // --- 1. data ---------------------------------------------------------
    let ds = dataset_by_name("coil", 2000, 42)?;
    println!("dataset: {} (n={}, d={})", ds.name, ds.n(), ds.d());

    // --- 2. build the session --------------------------------------------
    let have_artifacts = default_artifact_dir().join("manifest.txt").exists();
    let backend = if have_artifacts {
        Backend::Pjrt
    } else {
        eprintln!("NOTE: artifacts/ missing — run `make artifacts`; using native backend");
        Backend::Native
    };
    let n_iters = 700usize;
    let mut session = Session::builder()
        .dataset(ds.x.clone())
        .ld_dim(2)
        .alpha(1.0)
        .perplexity(10.0)
        .n_iters(n_iters)
        .backend(backend)
        .jumpstart_iters(80)
        .early_exag_iters(150)
        .build()?;

    // --- 3. run ------------------------------------------------------------
    let sw = Stopwatch::new();
    session.run_configured()?;
    let seconds = sw.elapsed_s();
    let iters_per_sec = n_iters as f64 / seconds.max(1e-9);
    let y = session.embedding();
    println!(
        "FUnc-SNE [{}]: {} iters in {:.2}s ({:.0} iters/s, {:.2e} point-updates/s)",
        session.backend_name(),
        n_iters,
        seconds,
        iters_per_sec,
        iters_per_sec * ds.n() as f64,
    );

    // --- 4. headline metric vs baseline ------------------------------------
    let ours = rnx_curve(&ds.x, y, 100);
    let sw = Stopwatch::new();
    let y_umap = umap_like(&ds.x, &UmapConfig::default());
    let t_umap = sw.elapsed_s();
    let umap = rnx_curve(&ds.x, &y_umap, 100);
    println!("\nR_NX AUC:   FUnc-SNE {:.3}  |  UMAP-like {:.3} ({t_umap:.2}s)", ours.auc, umap.auc);
    println!(
        "{}",
        plot::scatter_2d(
            "FUnc-SNE embedding of the COIL-20 twin (labels = objects)",
            y.data(),
            &ds.labels,
            ds.n(),
            78,
            22,
        )
    );
    println!(
        "{}",
        plot::line_chart(
            "R_NX(K) — FUnc-SNE (*) vs UMAP-like (o)",
            &[
                plot::Series::new("FUnc-SNE", ours.ks.iter().map(|&k| k as f64).collect(), ours.rnx.clone()),
                plot::Series::new("UMAP-like", umap.ks.iter().map(|&k| k as f64).collect(), umap.rnx.clone()),
            ],
            72,
            16,
            true,
        )
    );
    anyhow::ensure!(ours.auc > 0.3, "embedding quality regressed (AUC {})", ours.auc);
    println!("quickstart OK");
    Ok(())
}
