//! Demo client for the HTTP/JSON embedding service.
//!
//! Starts a server in-process on an ephemeral port (so the example is
//! self-contained), then drives it exactly like an external client
//! would — plain `TcpStream`, no HTTP library: create a session,
//! watch the background stepper advance it, flip α mid-run (the
//! paper's attraction–repulsion steering), insert points into the live
//! embedding, fetch frames, scrape metrics, and shut down.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```
//!
//! Against a standalone server (`funcsne serve`), the same requests
//! work verbatim via curl — see the crate docs of `funcsne::server`.

use funcsne::server::json::{self, Json};
use funcsne::server::{Server, ServerConfig};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // --- boot the service -------------------------------------------------
    let server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 2,
        max_sessions: 8,
        ..ServerConfig::default()
    })?;
    let addr = server.local_addr();
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());
    println!("» service listening on http://{addr}");

    // --- create a session from inline rows (three blobs in 8-D) ----------
    let ds = funcsne::data::datasets::blobs(300, 8, 3, 0.5, 8.0, 42);
    let rows: Vec<String> = (0..ds.x.n())
        .map(|i| {
            let cells: Vec<String> =
                ds.x.row(i).iter().map(|v| format!("{v:.4}")).collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    let spec = format!(
        "{{\"rows\": [{}], \"perplexity\": 12, \"k_hd\": 16, \"k_ld\": 8, \
          \"jumpstart_iters\": 20, \"seed\": 42}}",
        rows.join(",")
    );
    let (status, created) = request(addr, "POST", "/sessions", Some(&spec))?;
    anyhow::ensure!(status == 201, "create failed ({status}): {created}");
    let v = json::parse(&created)?;
    let id = v.get("id").and_then(Json::as_usize).unwrap_or(0);
    println!(
        "» created session {id}: n={}, backend={}",
        v.get("n").and_then(Json::as_usize).unwrap_or(0),
        v.get("backend").and_then(Json::as_str).unwrap_or("?")
    );

    // --- the stepper runs it in the background ----------------------------
    std::thread::sleep(Duration::from_millis(400));
    let iter_before = stat_usize(addr, id, "iter")?;
    println!("» {iter_before} iterations completed with zero client involvement");

    // --- steer mid-run: heavier tails, like the paper's α sweeps ----------
    let (status, _) = request(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"set_alpha\", \"value\": 0.5}"),
    )?;
    anyhow::ensure!(status == 202, "command rejected ({status})");
    wait_for(addr, id, |v| v.get("alpha").and_then(Json::as_f64) == Some(0.5))?;
    println!("» α → 0.5 applied between two iterations, optimisation uninterrupted");

    // --- dynamic data: stream new points into the running embedding -------
    let extra: Vec<String> = (0..10)
        .map(|i| {
            let cells: Vec<String> =
                ds.x.row(i).iter().map(|v| format!("{:.4}", v + 0.1)).collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    let (status, _) = request(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some(&format!("{{\"command\": \"insert_points\", \"rows\": [{}]}}", extra.join(","))),
    )?;
    anyhow::ensure!(status == 202);
    wait_for(addr, id, |v| v.get("n").and_then(Json::as_usize) == Some(310))?;
    println!("» inserted 10 points mid-run (n: 300 → 310)");

    // --- fetch the live embedding frame ------------------------------------
    let (status, frame) = request(addr, "GET", &format!("/sessions/{id}/embedding"), None)?;
    anyhow::ensure!(status == 200, "embedding fetch failed ({status})");
    let frame = json::parse(&frame)?;
    println!(
        "» live frame at iteration {}: {}×{} coordinates",
        frame.get("iter").and_then(Json::as_usize).unwrap_or(0),
        frame.get("n").and_then(Json::as_usize).unwrap_or(0),
        frame.get("d").and_then(Json::as_usize).unwrap_or(0),
    );

    // --- observability ------------------------------------------------------
    let (_, metrics) = request(addr, "GET", "/metrics", None)?;
    let steps = metrics
        .lines()
        .find(|l| l.starts_with("funcsne_steps_total"))
        .unwrap_or("funcsne_steps_total ?");
    println!("» /metrics: {steps}");

    // --- teardown -----------------------------------------------------------
    let (status, _) = request(addr, "DELETE", &format!("/sessions/{id}"), None)?;
    anyhow::ensure!(status == 200);
    handle.shutdown();
    server_thread.join().expect("server thread")?;
    println!("» session deleted, server drained cleanly");
    Ok(())
}

/// One `Connection: close` HTTP exchange; returns (status, body).
fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed response"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("no status code"))?
        .parse()?;
    Ok((status, body.to_string()))
}

fn stat_usize(addr: SocketAddr, id: usize, key: &str) -> anyhow::Result<usize> {
    let (status, body) = request(addr, "GET", &format!("/sessions/{id}/stats"), None)?;
    anyhow::ensure!(status == 200, "stats failed ({status}): {body}");
    let v = json::parse(&body)?;
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("stats missing {key:?}"))
}

/// Poll stats until `cond` holds (30 s deadline).
fn wait_for(
    addr: SocketAddr,
    id: usize,
    cond: impl Fn(&Json) -> bool,
) -> anyhow::Result<()> {
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let (status, body) = request(addr, "GET", &format!("/sessions/{id}/stats"), None)?;
        anyhow::ensure!(status == 200, "stats failed ({status}): {body}");
        if cond(&json::parse(&body)?) {
            return Ok(());
        }
        anyhow::ensure!(std::time::Instant::now() < deadline, "timed out polling stats");
        std::thread::sleep(Duration::from_millis(20));
    }
}
