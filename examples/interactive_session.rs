//! Headless replay of the paper's *interactive* workflow: a user changes
//! hyperparameters mid-optimisation — including HD-side ones — and the
//! engine keeps iterating without any recomputation phase.
//!
//! Every mid-run mutation goes through the session's **command queue**
//! (`Session::enqueue(Command::…)`), the single public mutation path:
//! commands drain FIFO between two iterations, exactly where a GUI or
//! network frontend would inject them. Telemetry flows back out through
//! an `EventSink`.
//!
//! Demonstrates: instant α changes, perplexity changes (incremental σ
//! recalibration with warm restarts), attraction/repulsion tuning at
//! heavy tails, and the "implosion button".
//!
//! ```sh
//! cargo run --release --example interactive_session
//! ```

use funcsne::coordinator::driver::dataset_by_name;
use funcsne::figures::common::figure_config;
use funcsne::session::{Command, Event, Session};
use funcsne::util::{plot, Stopwatch};
use std::cell::RefCell;
use std::rc::Rc;

fn snapshot(session: &Session, labels: &[usize], title: &str) {
    println!(
        "{}",
        plot::scatter_2d(title, session.embedding().data(), labels, session.n(), 70, 14)
    );
}

fn main() -> anyhow::Result<()> {
    let ds = dataset_by_name("mnist", 1500, 7)?;
    let labels = ds.coarse_labels.clone().unwrap();
    let mut cfg = figure_config(ds.n(), 2, 1.0);
    cfg.n_iters = 0;
    let mut session = Session::builder()
        .dataset(ds.x.clone())
        .config(cfg)
        .snapshot_stride(100)
        .snapshot_capacity(16)
        .build()?;

    // Watch the command stream like a frontend would.
    let command_log: Rc<RefCell<Vec<String>>> = Rc::new(RefCell::new(Vec::new()));
    let tap = Rc::clone(&command_log);
    session.add_sink(Box::new(move |e: &Event| {
        if let Event::CommandApplied { iter, description } = e {
            tap.borrow_mut().push(format!("iter {iter}: {description}"));
        }
    }));

    let sw = Stopwatch::new();
    println!("» optimisation starts immediately (no precompute phase)");
    session.run(250)?;
    println!("  [{:.2}s] 250 iterations", sw.elapsed_s());
    snapshot(&session, &labels, "t-SNE regime (α = 1)");

    println!("» user drags α down to 0.5 — instant, mid-run");
    session.enqueue(Command::SetAlpha(0.5));
    session.enqueue(Command::SetRepulsion(1.5));
    session.run(250)?;
    snapshot(&session, &labels, "heavy tails (α = 0.5): clusters fragment");

    println!("» user doubles the perplexity — an HD-side change that would");
    println!("  force a full re-preprocessing in two-phase methods");
    let recal_before = session.stats().recalibrated_points;
    session.enqueue(Command::SetPerplexity(session.config().perplexity * 2.0));
    session.run(150)?;
    println!(
        "  incremental σ recalibrations since change: {}",
        session.stats().recalibrated_points - recal_before
    );

    println!("» user hits the implosion button (embedding rescale)");
    session.enqueue(Command::Implode);
    session.run(150)?;
    snapshot(&session, &labels, "after implosion + 150 iterations");

    println!(
        "session total: {:.2}s for 800 iterations with 4 live hyperparameter events",
        sw.elapsed_s()
    );
    println!("command stream seen by the event sink:");
    for line in command_log.borrow().iter() {
        println!("  {line}");
    }
    println!(
        "snapshot ring: {} frames held (latest at iter {})",
        session.snapshots().len(),
        session.snapshots().latest().map(|s| s.iter).unwrap_or(0)
    );
    anyhow::ensure!(
        session.command_counts() == (4, 0),
        "expected 4 applied commands, got {:?}",
        session.command_counts()
    );
    anyhow::ensure!(
        session.embedding().data().iter().all(|v| v.is_finite()),
        "embedding diverged during the session"
    );
    println!("interactive_session OK");
    Ok(())
}
