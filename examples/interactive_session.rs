//! Headless replay of the paper's *interactive* workflow: a user changes
//! hyperparameters mid-optimisation — including HD-side ones — and the
//! engine keeps iterating without any recomputation phase.
//!
//! Demonstrates: instant α changes, perplexity changes (incremental σ
//! recalibration with warm restarts), attraction/repulsion tuning at
//! heavy tails, and the "implosion button".
//!
//! ```sh
//! cargo run --release --example interactive_session
//! ```

use funcsne::coordinator::driver::dataset_by_name;
use funcsne::engine::FuncSne;
use funcsne::figures::common::figure_config;
use funcsne::ld::NativeBackend;
use funcsne::util::{plot, Stopwatch};

fn snapshot(engine: &FuncSne, labels: &[usize], title: &str) {
    println!(
        "{}",
        plot::scatter_2d(title, engine.embedding().data(), labels, engine.n(), 70, 14)
    );
}

fn main() -> anyhow::Result<()> {
    let ds = dataset_by_name("mnist", 1500, 7)?;
    let labels = ds.coarse_labels.clone().unwrap();
    let mut cfg = figure_config(ds.n(), 2, 1.0);
    cfg.n_iters = 0;
    let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
    let mut backend = NativeBackend::new();
    let sw = Stopwatch::new();

    println!("» optimisation starts immediately (no precompute phase)");
    engine.run(250, &mut backend)?;
    println!("  [{:.2}s] 250 iterations", sw.elapsed_s());
    snapshot(&engine, &labels, "t-SNE regime (α = 1)");

    println!("» user drags α down to 0.5 — instant, mid-run");
    engine.set_alpha(0.5);
    engine.set_repulsion(1.5);
    engine.run(250, &mut backend)?;
    snapshot(&engine, &labels, "heavy tails (α = 0.5): clusters fragment");

    println!("» user doubles the perplexity — an HD-side change that would");
    println!("  force a full re-preprocessing in two-phase methods");
    let recal_before = engine.stats.recalibrated_points;
    engine.set_perplexity(engine.cfg.perplexity * 2.0);
    engine.run(150, &mut backend)?;
    println!(
        "  incremental σ recalibrations since change: {}",
        engine.stats.recalibrated_points - recal_before
    );

    println!("» user hits the implosion button (embedding rescale)");
    engine.implode();
    engine.run(150, &mut backend)?;
    snapshot(&engine, &labels, "after implosion + 150 iterations");

    println!(
        "session total: {:.2}s for 800 iterations with 4 live hyperparameter events",
        sw.elapsed_s()
    );
    anyhow::ensure!(
        engine.embedding().data().iter().all(|v| v.is_finite()),
        "embedding diverged during the session"
    );
    println!("interactive_session OK");
    Ok(())
}
