//! NE beyond visualisation (the §4.2 / Table 2 use case): embed deep
//! features into 32 dimensions with FUnc-SNE — *unsupervised* — and show
//! that a 1-NN classifier in the NE space does dramatically better in
//! the one-shot regime than in the raw or PCA representations.
//!
//! ```sh
//! cargo run --release --example oneshot_classifier
//! ```

use funcsne::coordinator::driver::{dataset_by_name, maybe_pca_reduce};
use funcsne::figures::common::figure_config;
use funcsne::figures::table2::{crossval_accuracy, one_shot_accuracy};
use funcsne::ld::NativeBackend;
use funcsne::util::Rng;

fn main() -> anyhow::Result<()> {
    let ds = dataset_by_name("deep_features", 1500, 8)?;
    println!(
        "deep-feature twin: n={}, ambient d={}, {} classes",
        ds.n(),
        ds.d(),
        ds.n_classes()
    );
    let pca = maybe_pca_reduce(ds.x.clone(), 48, 0);
    let mut cfg = figure_config(ds.n(), 32, 1.0);
    cfg.n_iters = 700;
    let mut engine = funcsne::engine::FuncSne::new(pca.clone(), cfg.clone())?;
    let mut backend = NativeBackend::new();
    engine.run(cfg.n_iters, &mut backend)?;
    let ne32 = engine.embedding().clone();

    let mut rng = Rng::new(77);
    println!("\n{:<12} {:>16} {:>16}", "repr", "one-shot top-1", "crossval top-1");
    let mut oneshots = Vec::new();
    for (name, x) in [("raw-256", &ds.x), ("pca-48", &pca), ("ne-32", &ne32)] {
        let os = one_shot_accuracy(x, &ds.labels, 8, 1, &mut rng);
        let cv = crossval_accuracy(x, &ds.labels, 5, &mut rng);
        println!("{:<12} {:>15.1}% {:>15.1}%", name, os * 100.0, cv * 100.0);
        oneshots.push(os);
    }
    anyhow::ensure!(
        oneshots[2] > oneshots[0] + 0.05,
        "NE one-shot should clearly beat raw ({:.3} vs {:.3})",
        oneshots[2],
        oneshots[0]
    );
    println!("\n(the paper's Table 2 analogue: 47.3 / 45.9 / 76.2 on ImageNet-EVA)");
    println!("oneshot_classifier OK");
    Ok(())
}
