//! Hierarchy extraction (the §4.2 algorithm): sweep α downward during a
//! continual optimisation of the rat-brain twin in 6-D, cluster each
//! snapshot with DBSCAN, link clusters across levels by overlap, and
//! render the resulting tree — then score it against the generator's
//! planted taxonomy.
//!
//! ```sh
//! cargo run --release --example hierarchy_graph
//! ```

use funcsne::cluster::hierarchy::{alpha_sweep, tree_agreement, SweepConfig};
use funcsne::cluster::layout::{layout, render_ascii};
use funcsne::coordinator::driver::dataset_by_name;
use funcsne::engine::FuncSne;
use funcsne::figures::common::figure_config;
use funcsne::ld::NativeBackend;

fn main() -> anyhow::Result<()> {
    let ds = dataset_by_name("rat_brain", 1500, 7)?;
    let planted = ds.hierarchy.clone().expect("generator plants a taxonomy");
    println!(
        "rat-brain twin: n={}, leaves={}, planted tree over {} subtypes",
        ds.n(),
        planted.len(),
        planted.iter().max().unwrap() + 1
    );

    let mut cfg = figure_config(ds.n(), 6, 1.0); // LD dim 6, as in Fig. 10
    cfg.n_iters = 0;
    let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
    let mut backend = NativeBackend::new();
    let sweep = SweepConfig {
        alphas: vec![1.0, 0.65, 0.45],
        iters_per_level: 300,
        ..SweepConfig::default()
    };
    let graph = alpha_sweep(&mut engine, &mut backend, &sweep)?;
    let pos = layout(&graph, 300, 1);
    println!("{}", render_ascii(&graph, &pos, 72, 22));

    let per_level: Vec<usize> = (0..graph.levels).map(|l| graph.nodes_at(l).count()).collect();
    println!("clusters per level (α = {:?}): {per_level:?}", sweep.alphas);
    let score = tree_agreement(&graph, graph.levels - 1, &ds.labels, &planted);
    println!("tree agreement vs planted dendrogram: {score:.3} (0.5 ≈ chance, 1 = perfect)");
    anyhow::ensure!(score > 0.5, "hierarchy should beat chance (got {score})");
    println!("hierarchy_graph OK");
    Ok(())
}
