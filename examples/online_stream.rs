//! Dynamic-dataset demo: points arrive, leave, and drift while the
//! embedding keeps optimising — the paper's "naturally adapts to
//! dynamical datasets with no computational overhead" claim.
//!
//! A stream of points from 4 clusters is fed in batches; midway, one
//! cluster is retired point by point and a brand-new cluster starts
//! streaming in; some points drift between clusters. Per-event cost is
//! reported to show there is no stop-the-world phase.
//!
//! ```sh
//! cargo run --release --example online_stream
//! ```

use funcsne::config::EmbedConfig;
use funcsne::data::datasets;
use funcsne::engine::FuncSne;
use funcsne::ld::NativeBackend;
use funcsne::util::{plot, Rng, Stopwatch};

fn main() -> anyhow::Result<()> {
    let full = datasets::blobs(3000, 16, 5, 0.6, 18.0, 11);
    // Start with clusters 0..4 only; cluster 4 streams in later.
    let initial: Vec<usize> = (0..full.n()).filter(|&i| full.labels[i] < 4).collect();
    let later: Vec<usize> = (0..full.n()).filter(|&i| full.labels[i] == 4).collect();
    let x0 = full.x.take_rows(&initial[..800.min(initial.len())]);
    let mut labels: Vec<usize> = initial[..800.min(initial.len())]
        .iter()
        .map(|&i| full.labels[i])
        .collect();

    let cfg = EmbedConfig {
        k_hd: 16,
        k_ld: 8,
        perplexity: 10.0,
        jumpstart_iters: 40,
        early_exag_iters: 80,
        n_iters: 0,
        ..EmbedConfig::default()
    };
    let mut engine = FuncSne::new(x0, cfg)?;
    let mut backend = NativeBackend::new();
    let mut rng = Rng::new(5);

    println!("» warm-up on the initial 4-cluster stream ({} points)", engine.n());
    engine.run(300, &mut backend)?;

    // --- streaming inserts ------------------------------------------------
    let sw = Stopwatch::new();
    let batch = 40;
    let mut inserted = 0;
    for chunk in later.chunks(batch).take(6) {
        for &i in chunk {
            engine.insert_point(full.x.row(i));
            labels.push(full.labels[i]);
            inserted += 1;
        }
        engine.run(30, &mut backend)?; // embedding absorbs the batch
    }
    println!(
        "» inserted {} points of an unseen cluster in {:.2}s (incl. 180 iterations)",
        inserted,
        sw.elapsed_s()
    );

    // --- retiring a cluster ------------------------------------------------
    let sw = Stopwatch::new();
    let mut removed = 0;
    let mut i = 0;
    while i < engine.n() {
        if labels[i] == 0 && removed < 150 {
            engine.remove_point(i);
            labels.swap_remove(i);
            removed += 1;
        } else {
            i += 1;
        }
    }
    engine.run(60, &mut backend)?;
    println!("» removed {removed} points of cluster 0 in {:.2}s", sw.elapsed_s());

    // --- drifting points ----------------------------------------------------
    let sw = Stopwatch::new();
    let mut drifted = 0;
    for _ in 0..60 {
        let i = rng.below(engine.n());
        // drift toward the data centroid: new = 0.5*(x_i + x_j) of a random pair
        let j = rng.below(engine.n());
        let mix: Vec<f32> = engine
            .x
            .row(i)
            .iter()
            .zip(engine.x.row(j))
            .map(|(a, b)| 0.5 * (a + b))
            .collect();
        engine.move_point(i, &mix);
        drifted += 1;
    }
    engine.run(120, &mut backend)?;
    println!("» drifted {drifted} points in {:.2}s", sw.elapsed_s());

    println!(
        "{}",
        plot::scatter_2d(
            "final embedding after insert/remove/drift (labels = clusters)",
            engine.embedding().data(),
            &labels,
            engine.n(),
            76,
            20,
        )
    );
    anyhow::ensure!(engine.embedding().data().iter().all(|v| v.is_finite()));
    // Table invariants after heavy dynamics.
    for i in 0..engine.n() {
        for &j in engine.knn.hd.neighbors(i) {
            anyhow::ensure!((j as usize) < engine.n(), "stale neighbour reference");
        }
    }
    println!("online_stream OK (n = {} at exit)", engine.n());
    Ok(())
}
