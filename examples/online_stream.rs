//! Dynamic-dataset demo: points arrive, leave, and drift while the
//! embedding keeps optimising — the paper's "naturally adapts to
//! dynamical datasets with no computational overhead" claim.
//!
//! All dataset mutations go through the session command queue
//! (`InsertPoints` / `RemovePoint` / `MovePoint`), applied FIFO between
//! iterations — exactly how a streaming frontend would feed a live
//! session. A stream of points from 4 clusters is fed in batches;
//! midway, one cluster is retired point by point and a brand-new
//! cluster starts streaming in; some points drift between clusters.
//! Per-event cost is reported to show there is no stop-the-world phase.
//!
//! ```sh
//! cargo run --release --example online_stream
//! ```

use funcsne::data::datasets;
use funcsne::session::{Command, Session};
use funcsne::util::{plot, Rng, Stopwatch};

fn main() -> anyhow::Result<()> {
    let full = datasets::blobs(3000, 16, 5, 0.6, 18.0, 11);
    // Start with clusters 0..4 only; cluster 4 streams in later.
    let initial: Vec<usize> = (0..full.n()).filter(|&i| full.labels[i] < 4).collect();
    let later: Vec<usize> = (0..full.n()).filter(|&i| full.labels[i] == 4).collect();
    let x0 = full.x.take_rows(&initial[..800.min(initial.len())]);
    let mut labels: Vec<usize> = initial[..800.min(initial.len())]
        .iter()
        .map(|&i| full.labels[i])
        .collect();

    let mut session = Session::builder()
        .dataset(x0)
        .k_hd(16)
        .k_ld(8)
        .perplexity(10.0)
        .jumpstart_iters(40)
        .early_exag_iters(80)
        .build()?;
    let mut rng = Rng::new(5);

    println!("» warm-up on the initial 4-cluster stream ({} points)", session.n());
    session.run(300)?;

    // --- streaming inserts (one InsertPoints command per batch) -----------
    let sw = Stopwatch::new();
    let batch = 40;
    let mut inserted = 0;
    for chunk in later.chunks(batch).take(6) {
        let rows = full.x.take_rows(chunk);
        inserted += rows.n();
        session.enqueue(Command::InsertPoints(rows));
        for &i in chunk {
            labels.push(full.labels[i]);
        }
        session.run(30)?; // queue drains before the first of these iterations
    }
    println!(
        "» inserted {} points of an unseen cluster in {:.2}s (incl. 180 iterations)",
        inserted,
        sw.elapsed_s()
    );

    // --- retiring a cluster -------------------------------------------------
    // RemovePoint is swap-remove (the last point takes the freed index),
    // so enqueue removals in descending index order and mirror the same
    // bookkeeping on our label vector.
    let sw = Stopwatch::new();
    let mut to_remove: Vec<usize> =
        (0..session.n()).filter(|&i| labels[i] == 0).take(150).collect();
    to_remove.sort_unstable_by(|a, b| b.cmp(a));
    let removed = to_remove.len();
    for &i in &to_remove {
        session.enqueue(Command::RemovePoint(i));
        labels.swap_remove(i);
    }
    session.run(60)?;
    println!("» removed {removed} points of cluster 0 in {:.2}s", sw.elapsed_s());
    anyhow::ensure!(session.n() == labels.len(), "label bookkeeping diverged");

    // --- drifting points ----------------------------------------------------
    let sw = Stopwatch::new();
    let mut drifted = 0;
    for _ in 0..60 {
        let i = rng.below(session.n());
        // drift toward the data centroid: new = 0.5*(x_i + x_j) of a random pair
        let j = rng.below(session.n());
        let x = &session.engine().x;
        let mix: Vec<f32> = x.row(i).iter().zip(x.row(j)).map(|(a, b)| 0.5 * (a + b)).collect();
        session.enqueue(Command::MovePoint(i, mix));
        drifted += 1;
        session.run(2)?; // apply, then let the embedding react
    }
    session.run(120)?;
    println!("» drifted {drifted} points in {:.2}s", sw.elapsed_s());

    let (applied, rejected) = session.command_counts();
    println!("» command queue: {applied} applied, {rejected} rejected");

    println!(
        "{}",
        plot::scatter_2d(
            "final embedding after insert/remove/drift (labels = clusters)",
            session.embedding().data(),
            &labels,
            session.n(),
            76,
            20,
        )
    );
    anyhow::ensure!(session.embedding().data().iter().all(|v| v.is_finite()));
    anyhow::ensure!(rejected == 0, "no command should have been rejected");
    // Table invariants after heavy dynamics.
    for i in 0..session.n() {
        for &j in session.engine().knn.hd.neighbors(i) {
            anyhow::ensure!((j as usize) < session.n(), "stale neighbour reference");
        }
    }
    println!("online_stream OK (n = {} at exit)", session.n());
    Ok(())
}
