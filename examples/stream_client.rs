//! Minimal streaming client for `GET /sessions/:id/stream`.
//!
//! Boots a server in-process on an ephemeral port (so the example is
//! self-contained), creates a session, then consumes the chunked
//! binary frame stream exactly as an external visualiser would: plain
//! `TcpStream`, hand-rolled chunked-transfer parsing, and the
//! [`FrameDecoder`] from `funcsne::server::frames` folding keyframes
//! and deltas back into f32 coordinates. See docs/wire-format.md for
//! the byte-level frame layout.
//!
//! ```sh
//! cargo run --release --example stream_client
//! ```
//!
//! Point `open_stream` at any running `funcsne serve` address to watch
//! a real deployment instead.

use funcsne::server::frames::{decode, FrameDecoder};
use funcsne::server::json::{self, Json};
use funcsne::server::{Server, ServerConfig};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // --- boot the service and a session to watch --------------------------
    let server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 3, // a streaming client pins one worker slot
        max_sessions: 4,
        ..ServerConfig::default()
    })?;
    let addr = server.local_addr();
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());
    println!("» service listening on http://{addr}");

    let ds = funcsne::data::datasets::blobs(500, 8, 4, 0.6, 10.0, 21);
    let rows: Vec<String> = (0..ds.x.n())
        .map(|i| {
            let cells: Vec<String> = ds.x.row(i).iter().map(|v| format!("{v:.4}")).collect();
            format!("[{}]", cells.join(","))
        })
        .collect();
    let spec = format!(
        "{{\"rows\": [{}], \"perplexity\": 12, \"k_hd\": 16, \"k_ld\": 8, \
          \"jumpstart_iters\": 10, \"seed\": 21}}",
        rows.join(",")
    );
    let (status, body) = request(addr, "POST", "/sessions", Some(&spec))?;
    anyhow::ensure!(status == 201, "create failed ({status}): {body}");
    let id = json::parse(&body)?.get("id").and_then(Json::as_usize).unwrap_or(0);
    println!("» session {id} created; subscribing to its frame stream");

    // --- subscribe and decode frames as they arrive -----------------------
    let mut stream = open_stream(addr, id)?;
    let mut dec = FrameDecoder::new();
    let mut bytes_total = 0usize;
    for i in 0..25 {
        let Some(bytes) = next_chunk(&mut stream)? else {
            println!("» server closed the stream");
            break;
        };
        bytes_total += bytes.len();
        let frame = decode(&bytes).map_err(|e| anyhow::anyhow!("bad frame: {e}"))?;
        // A delta that doesn't chain (frames were dropped for us) is
        // skipped; the server follows up with a keyframe resync.
        match dec.apply(&frame) {
            Ok(()) => {
                let coords = dec.coords();
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &c in &coords {
                    lo = lo.min(c);
                    hi = hi.max(c);
                }
                println!(
                    "frame {i:>2}: iter {:>5} {} {:>5} B  n={} d={}  coords in [{lo:.3}, {hi:.3}]",
                    frame.iter,
                    if frame.keyframe { "key  " } else { "delta" },
                    bytes.len(),
                    dec.n(),
                    dec.d(),
                );
            }
            Err(reason) => println!("frame {i:>2}: skipped ({reason})"),
        }
    }
    println!("» received {bytes_total} stream bytes total");

    // --- tear down ---------------------------------------------------------
    drop(stream);
    let (status, _) = request(addr, "DELETE", &format!("/sessions/{id}"), None)?;
    anyhow::ensure!(status == 200, "delete failed");
    handle.shutdown();
    server_thread.join().expect("server thread")?;
    println!("» done");
    Ok(())
}

/// Subscribe to a session's frame stream; returns the socket positioned
/// at the first chunk.
fn open_stream(addr: SocketAddr, id: usize) -> anyhow::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let req = format!(
        "GET /sessions/{id}/stream HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\r\n"
    );
    stream.write_all(req.as_bytes())?;
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte)?;
        head.push(byte[0]);
    }
    let head = String::from_utf8_lossy(&head);
    anyhow::ensure!(head.starts_with("HTTP/1.1 200"), "subscribe failed:\n{head}");
    anyhow::ensure!(head.contains("Transfer-Encoding: chunked"), "not a chunked stream");
    Ok(stream)
}

/// Read one chunked-transfer chunk (the server sends one frame per
/// chunk); `None` at the terminating zero-length chunk.
fn next_chunk(stream: &mut TcpStream) -> anyhow::Result<Option<Vec<u8>>> {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    while !line.ends_with(b"\r\n") {
        stream.read_exact(&mut byte)?;
        line.push(byte[0]);
    }
    let len = usize::from_str_radix(String::from_utf8_lossy(&line).trim(), 16)?;
    let mut payload = vec![0u8; len + 2]; // chunk body + trailing CRLF
    stream.read_exact(&mut payload)?;
    payload.truncate(len);
    Ok(if len == 0 { None } else { Some(payload) })
}

fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed response"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("no status code"))?
        .parse()?;
    Ok((status, body.to_string()))
}
