//! Integration: the α-sweep hierarchy recovers a planted 2-level tree.

use funcsne::cluster::hierarchy::{alpha_sweep, tree_agreement, SweepConfig};
use funcsne::data::datasets;
use funcsne::engine::FuncSne;
use funcsne::figures::common::figure_config;
use funcsne::ld::NativeBackend;

#[test]
fn recovers_planted_nested_structure() {
    // 3 super-clusters × 3 sub-clusters, well separated.
    let ds = datasets::nested_blobs(900, 12, 3, 3, 1);
    let planted = ds.hierarchy.clone().unwrap();
    let mut cfg = figure_config(ds.n(), 4, 1.0);
    cfg.n_iters = 0;
    let mut engine = FuncSne::new(ds.x.clone(), cfg).unwrap();
    let mut backend = NativeBackend::new();
    let sweep = SweepConfig {
        alphas: vec![1.0, 0.5],
        iters_per_level: 350,
        ..SweepConfig::default()
    };
    let graph = alpha_sweep(&mut engine, &mut backend, &sweep).unwrap();
    assert_eq!(graph.levels, 2);
    let coarse = graph.nodes_at(0).count();
    let fine = graph.nodes_at(1).count();
    assert!(coarse >= 2, "no coarse structure found ({coarse})");
    assert!(fine >= coarse, "deeper level should not be coarser: {fine} < {coarse}");
    let score = tree_agreement(&graph, 1, &ds.labels, &planted);
    assert!(score > 0.6, "tree agreement {score} too close to chance");
    // Every edge must connect adjacent levels with a valid weight.
    for e in &graph.edges {
        assert_eq!(graph.nodes[e.to].level, graph.nodes[e.from].level + 1);
        assert!(e.weight > 0.0 && e.weight <= 1.0 + 1e-9);
    }
}
