//! Integration: dynamic-dataset behaviour — the embedding must absorb
//! inserts/removals/drifts and still represent the *current* data well.
//! All mutations go through the session command queue (the public
//! mutation path; the raw engine mutators are crate-private).

use funcsne::config::EmbedConfig;
use funcsne::data::datasets;
use funcsne::metrics::rnx_auc;
use funcsne::session::{Command, Session};

fn cfg(n: usize) -> EmbedConfig {
    EmbedConfig {
        k_hd: 16.min(n - 1),
        k_ld: 8,
        perplexity: 8.0,
        jumpstart_iters: 40,
        early_exag_iters: 80,
        n_iters: 0,
        ..EmbedConfig::default()
    }
}

fn session_over(x: funcsne::data::Matrix) -> Session {
    let n = x.n();
    Session::builder().dataset(x).config(cfg(n)).build().unwrap()
}

#[test]
fn inserted_cluster_lands_near_itself() {
    // Train on 3 clusters, then stream in a 4th; after absorption its
    // points should be mutual LD neighbours (not scattered).
    let all = datasets::blobs(1200, 12, 4, 0.4, 16.0, 1);
    let keep: Vec<usize> = (0..all.n()).filter(|&i| all.labels[i] < 3).collect();
    let new: Vec<usize> = (0..all.n()).filter(|&i| all.labels[i] == 3).take(60).collect();
    let x0 = all.x.take_rows(&keep[..600]);
    let mut session = session_over(x0);
    session.run(350).unwrap();
    let base_n = session.n();
    session.enqueue(Command::InsertPoints(all.x.take_rows(&new)));
    session.run(250).unwrap();
    assert_eq!(session.n(), base_n + new.len());
    // Mean LD distance within the new cluster vs to the rest.
    let y = session.embedding();
    let mut intra = Vec::new();
    let mut inter = Vec::new();
    for a in base_n..session.n() {
        for b in (a + 1)..session.n() {
            intra.push((y.sqdist(a, b) as f64).sqrt());
        }
        for b in (0..base_n).step_by(13) {
            inter.push((y.sqdist(a, b) as f64).sqrt());
        }
    }
    let mi = funcsne::util::stats::mean(&intra);
    let mo = funcsne::util::stats::mean(&inter);
    assert!(
        mi < mo,
        "streamed-in cluster did not coalesce: intra {mi:.3} vs inter {mo:.3}"
    );
}

#[test]
fn removal_keeps_quality() {
    let ds = datasets::blobs(600, 12, 3, 0.4, 14.0, 2);
    let mut session = session_over(ds.x.clone());
    session.run(300).unwrap();
    // Remove 150 random points (drain between iterations, one per step
    // so the sampled index is always in range at apply time).
    let mut rng = funcsne::util::Rng::new(3);
    for k in 0..150 {
        let i = rng.below(600 - k);
        session.enqueue(Command::RemovePoint(i));
        session.run(1).unwrap();
    }
    session.run(149).unwrap();
    assert_eq!(session.n(), 450);
    let (_, rejected) = session.command_counts();
    assert_eq!(rejected, 0, "all removals must be valid");
    let engine = session.engine();
    let auc = rnx_auc(&engine.x, session.embedding(), 30);
    assert!(auc > 0.2, "post-removal quality collapsed: AUC {auc}");
    // No dangling references.
    for i in 0..session.n() {
        for &j in engine.knn.hd.neighbors(i) {
            assert!((j as usize) < session.n());
        }
        for &j in engine.knn.ld.neighbors(i) {
            assert!((j as usize) < session.n());
        }
    }
}

#[test]
fn drifting_point_follows_its_new_cluster() {
    // The paper's claim is about *drifting* values: move a cluster-0
    // point smoothly (10 interpolation steps) onto a cluster-1 point's
    // coordinates while the optimisation keeps running; the embedding
    // must carry it across.
    let ds = datasets::blobs(400, 8, 2, 0.3, 20.0, 4);
    let mut session = session_over(ds.x.clone());
    session.run(400).unwrap();
    let a = (0..400).find(|&i| ds.labels[i] == 0).unwrap();
    let b = (0..400).find(|&i| ds.labels[i] == 1).unwrap();
    let start: Vec<f32> = ds.x.row(a).to_vec();
    let target: Vec<f32> = ds.x.row(b).to_vec();
    for step in 1..=10 {
        let t = step as f32 / 10.0;
        let row: Vec<f32> =
            start.iter().zip(&target).map(|(s, e)| s + t * (e - s)).collect();
        session.enqueue(Command::MovePoint(a, row));
        session.run(80).unwrap();
    }
    session.run(200).unwrap();
    let y = session.embedding();
    let d_new = (y.sqdist(a, b) as f64).sqrt();
    // Distance to an arbitrary cluster-0 point it used to sit with:
    let c = (0..400).find(|&i| ds.labels[i] == 0 && i != a).unwrap();
    let d_old = (y.sqdist(a, c) as f64).sqrt();
    assert!(
        d_new < d_old,
        "drifted point did not migrate: to new cluster {d_new:.3}, to old {d_old:.3}"
    );
}
