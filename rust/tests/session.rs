//! Integration: the session facade — command-queue ordering guarantees,
//! builder validation, event stream, dynamic data under PCA
//! pre-reduction, and multi-session management.

use funcsne::data::{datasets, Matrix};
use funcsne::session::{Command, Event, Session, SessionManager};
use std::cell::RefCell;
use std::rc::Rc;

fn builder_for(n: usize, seed: u64) -> funcsne::session::SessionBuilder {
    let ds = datasets::blobs(n, 6, 3, 0.5, 10.0, seed);
    Session::builder()
        .dataset(ds.x)
        .k_hd(12)
        .k_ld(8)
        .perplexity(8.0)
        .n_neg(6)
        .jumpstart_iters(5)
        .early_exag_iters(10)
        .seed(seed)
}

#[test]
fn commands_drain_fifo_before_the_next_iteration() {
    let events: Rc<RefCell<Vec<Event>>> = Rc::new(RefCell::new(Vec::new()));
    let tap = Rc::clone(&events);
    let mut s = builder_for(120, 1).build().unwrap();
    s.add_sink(Box::new(move |e: &Event| tap.borrow_mut().push(e.clone())));
    s.run(3).unwrap();
    // Conflicting writes: FIFO means the *last* enqueued value wins.
    s.enqueue(Command::SetAlpha(0.3));
    s.enqueue(Command::SetAttraction(2.0));
    s.enqueue(Command::SetAlpha(0.8));
    s.run(1).unwrap();
    assert_eq!(s.config().alpha, 0.8, "later command must overwrite earlier (FIFO)");
    assert_eq!(s.config().attraction, 2.0);

    let ev = events.borrow();
    // The three CommandApplied events appear in enqueue order and all
    // precede the Iteration event of the step that drained them.
    let descriptions: Vec<(usize, String)> = ev
        .iter()
        .enumerate()
        .filter_map(|(pos, e)| match e {
            Event::CommandApplied { description, .. } => Some((pos, description.clone())),
            _ => None,
        })
        .collect();
    assert_eq!(descriptions.len(), 3);
    assert!(descriptions[0].1.contains("set_alpha(0.3)"));
    assert!(descriptions[1].1.contains("set_attraction(2)"));
    assert!(descriptions[2].1.contains("set_alpha(0.8)"));
    let fourth_iteration_pos = ev
        .iter()
        .position(|e| matches!(e, Event::Iteration { iter, .. } if *iter == 4))
        .expect("iteration 4 must be emitted");
    for (pos, _) in &descriptions {
        assert!(
            *pos < fourth_iteration_pos,
            "command events must precede the iteration that follows the drain"
        );
    }
    // All command events carry the pre-step iteration count (3).
    for e in ev.iter() {
        if let Event::CommandApplied { iter, .. } = e {
            assert_eq!(*iter, 3);
        }
    }
}

#[test]
fn insert_then_remove_in_one_batch_sees_inserted_points() {
    let extra = datasets::blobs(10, 6, 2, 0.5, 8.0, 99);
    let mut s = builder_for(100, 2).build().unwrap();
    s.run(20).unwrap();
    assert_eq!(s.n(), 100);
    // One batch: grow to 110, then remove an index that is only valid
    // *after* the insert has been applied — FIFO makes it valid.
    s.enqueue(Command::InsertPoints(extra.x.clone()));
    s.enqueue(Command::RemovePoint(105));
    s.run(1).unwrap();
    assert_eq!(s.n(), 109);
    let (applied, rejected) = s.command_counts();
    assert_eq!((applied, rejected), (2, 0));
    // Reversed order in a fresh batch: the removal of a not-yet-valid
    // index must be rejected, the insert still applies.
    s.enqueue(Command::RemovePoint(115));
    s.enqueue(Command::InsertPoints(extra.x.clone()));
    s.run(1).unwrap();
    assert_eq!(s.n(), 119);
    let (applied, rejected) = s.command_counts();
    assert_eq!((applied, rejected), (3, 1));
    // The embedding keeps optimising and stays finite after dynamics.
    s.run(30).unwrap();
    assert!(s.embedding().data().iter().all(|v| v.is_finite()));
    for i in 0..s.n() {
        for &j in s.engine().knn.hd.neighbors(i) {
            assert!((j as usize) < s.n(), "stale neighbour {j}");
        }
    }
}

#[test]
fn dynamic_rows_after_pca_project_through_the_retained_basis() {
    // Regression: the builder's PCA pre-reduction used to discard the
    // fitted basis, so original-dimension inserts/moves were rejected
    // with a misleading "insert dim != data dim" error.
    let ds = datasets::mnist_like(150, 64, 2);
    let extra = datasets::mnist_like(10, 64, 3);
    let mut s = Session::builder()
        .dataset(ds.x.clone())
        .pca_max_dim(16)
        .k_hd(12)
        .k_ld(8)
        .perplexity(8.0)
        .jumpstart_iters(0)
        .seed(4)
        .build()
        .unwrap();
    assert_eq!(s.engine().x.d(), 16, "data must be pre-reduced");
    let pca = s.pca().expect("fitted basis must be retained");
    assert_eq!((pca.input_dim(), pca.out_dim()), (64, 16));
    s.run(10).unwrap();

    // Insert 64-dim rows: accepted and projected into the 16-dim basis.
    s.enqueue(Command::InsertPoints(extra.x.clone()));
    s.run(1).unwrap();
    assert_eq!(s.n(), 160);
    let expect = s.pca().unwrap().transform(&extra.x);
    for r in 0..10 {
        assert_eq!(
            s.engine().x.row(150 + r),
            expect.row(r),
            "inserted row {r} not projected through the session's own basis"
        );
    }

    // Move a point with a 64-dim row: same projection.
    s.enqueue(Command::MovePoint(0, extra.x.row(3).to_vec()));
    s.run(1).unwrap();
    assert_eq!(s.engine().x.row(0), expect.row(3));
    let (_, rejected) = s.command_counts();
    assert_eq!(rejected, 0, "original-dimension dynamic rows must be accepted");

    // Already-reduced (16-dim) rows must be rejected with a message
    // naming the original dimension — not silently mixed into the basis.
    s.enqueue(Command::InsertPoints(Matrix::zeros(2, 16)));
    s.enqueue(Command::MovePoint(1, vec![0.0; 16]));
    s.run(1).unwrap();
    let (_, rejected) = s.command_counts();
    assert_eq!(rejected, 2);
    assert_eq!(s.n(), 160);

    // And the session keeps optimising fine afterwards.
    s.run(30).unwrap();
    assert!(s.embedding().data().iter().all(|v| v.is_finite()));
}

#[test]
fn builder_validation_errors() {
    let ds = datasets::blobs(100, 6, 2, 0.5, 8.0, 3);
    // Bad ld_dim.
    let err = Session::builder().dataset(ds.x.clone()).ld_dim(0).build().unwrap_err();
    assert!(format!("{err:?}").contains("ld_dim"), "{err:?}");
    // ld_dim beyond the native fast-path bound.
    let err = Session::builder().dataset(ds.x.clone()).ld_dim(65).build().unwrap_err();
    assert!(format!("{err:?}").contains("ld_dim"), "{err:?}");
    // Perplexity below 2.
    let err = Session::builder()
        .dataset(ds.x.clone())
        .perplexity(1.5)
        .build()
        .unwrap_err();
    assert!(format!("{err:?}").contains("perplexity"), "{err:?}");
    // Unknown backend name.
    let err = Session::builder()
        .dataset(ds.x.clone())
        .backend_name("tpu9000")
        .build()
        .unwrap_err();
    assert!(format!("{err:?}").contains("backend"), "{err:?}");
    // Missing dataset.
    let err = Session::builder().build().unwrap_err();
    assert!(format!("{err:?}").contains("dataset"), "{err:?}");
}

#[test]
fn manager_steps_three_concurrent_sessions_to_finite_embeddings() {
    let mut mgr = SessionManager::new();
    // Three independent sessions with different data, dims and tails.
    let a = mgr.create(builder_for(150, 10).ld_dim(2).alpha(1.0)).unwrap();
    let b = mgr.create(builder_for(120, 11).ld_dim(3).alpha(0.6)).unwrap();
    let c = mgr
        .create(builder_for(90, 12).ld_dim(4).alpha(1.4).perplexity(6.0))
        .unwrap();
    assert_eq!(mgr.len(), 3);

    // Round-robin: every sweep advances each session exactly once.
    mgr.run_all(120).unwrap();
    for (id, ld_dim) in [(a, 2), (b, 3), (c, 4)] {
        let s = mgr.get(id).unwrap();
        assert_eq!(s.iterations(), 120, "{id} fell behind the round-robin");
        assert_eq!(s.embedding().d(), ld_dim);
        assert!(
            s.embedding().data().iter().all(|v| v.is_finite()),
            "{id} diverged"
        );
    }

    // Steer one session mid-flight without touching the others.
    mgr.enqueue(b, Command::SetAlpha(0.4)).unwrap();
    mgr.enqueue(b, Command::Implode).unwrap();
    mgr.run_all(80).unwrap();
    assert_eq!(mgr.get(a).unwrap().config().alpha, 1.0);
    assert_eq!(mgr.get(b).unwrap().config().alpha, 0.4);
    assert!(mgr.get(b).unwrap().stats().implosions >= 1);
    for id in [a, b, c] {
        let s = mgr.get(id).unwrap();
        assert_eq!(s.iterations(), 200);
        assert!(s.embedding().data().iter().all(|v| v.is_finite()));
    }

    // Dropping one session leaves the rest running.
    assert!(mgr.remove(b).is_some());
    mgr.run_all(10).unwrap();
    assert_eq!(mgr.get(a).unwrap().iterations(), 210);
    assert_eq!(mgr.get(c).unwrap().iterations(), 210);
}
