//! Property-style tests for the binary streaming frame codec: random
//! shapes and grids round-trip within half a quantization cell, delta
//! chains survive quiet iterations and resync late joiners via
//! keyframes, corrupt or truncated buffers are rejected, and the
//! n=100k keyframe stays inside the size budget.

use funcsne::data::Matrix;
use funcsne::server::frames::codec::FIXED_HEADER;
use funcsne::server::frames::{decode, FrameDecoder, FrameEncoder};

/// Deterministic 64-bit LCG so every run explores the same shapes.
struct Lcg(u64);

impl Lcg {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform in [0, 1).
    fn unit(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / ((1u64 << 24) as f32)
    }

    /// Uniform integer in lo..=hi.
    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + ((self.next_u64() >> 33) as usize) % (hi - lo + 1)
    }
}

fn random_matrix(rng: &mut Lcg, n: usize, d: usize, scale: f32, offset: f32) -> Matrix {
    let mut m = Matrix::zeros(n, d);
    for r in 0..n {
        for c in 0..d {
            m.row_mut(r)[c] = offset + (rng.unit() - 0.5) * scale;
        }
    }
    m
}

#[test]
fn keyframes_round_trip_over_random_shapes_and_grids() {
    let mut rng = Lcg(0xFEED_5EED);
    for trial in 0..25 {
        let n = rng.range(1, 300);
        let d = rng.range(1, 6);
        let scale = [0.05_f32, 1.0, 40.0, 1000.0][rng.range(0, 3)];
        let offset = (rng.unit() - 0.5) * 1000.0;
        let y = random_matrix(&mut rng, n, d, scale, offset);

        let mut enc = FrameEncoder::new(30);
        let bytes = enc.encode(trial as u64, &y, 0).expect("first frame is a keyframe");
        let frame = decode(&bytes).expect("well-formed keyframe");
        assert!(frame.keyframe);
        assert_eq!((frame.n, frame.d), (n, d));
        assert_eq!(bytes.len(), FIXED_HEADER + 8 * d + n * d * 2, "exact wire size");

        let mut dec = FrameDecoder::new();
        dec.apply(&frame).expect("keyframe applies to a fresh decoder");
        let coords = dec.coords();
        for r in 0..n {
            for c in 0..d {
                let truth = y.row(r)[c];
                let got = coords[r * d + c];
                let ax = frame.bbox[c];
                // Half a grid cell, plus f32 slack proportional to the
                // grid's magnitude (dequantize does ~3 rounded ops).
                let tol = 0.5 * ax.cell() + ax.min.abs().max(ax.max.abs()) * 5e-6 + 1e-6;
                assert!(
                    (got - truth).abs() <= tol,
                    "trial {trial} point ({r},{c}): |{got} - {truth}| > {tol}"
                );
            }
        }
    }
}

#[test]
fn evolving_stream_resyncs_a_late_joiner_at_the_next_keyframe() {
    let mut rng = Lcg(0xA11CE);
    let n = 120;
    let mut y = random_matrix(&mut rng, n, 2, 20.0, 0.0);
    let mut enc = FrameEncoder::new(4);
    let mut full = FrameDecoder::new();
    let mut late = FrameDecoder::new();
    let mut late_synced = false;
    let mut frames_seen = 0usize;
    let mut keyframes_seen = 0usize;

    for iter in 0..40u64 {
        // Random-walk a random subset; some iterations move nothing at
        // all, which must not break the delta chain.
        if iter % 7 != 3 {
            for _ in 0..rng.range(1, 12) {
                let p = rng.range(0, n - 1);
                y.row_mut(p)[rng.range(0, 1)] += (rng.unit() - 0.5) * 0.8;
            }
        }
        let Some(bytes) = enc.encode(iter, &y, 0) else { continue };
        let frame = decode(&bytes).expect("encoder output decodes");
        frames_seen += 1;
        keyframes_seen += usize::from(frame.keyframe);
        full.apply(&frame).expect("uninterrupted stream always chains");

        // The late joiner tunes in from iteration 9: it must discard
        // deltas (they don't chain from nothing) until a keyframe, then
        // track the full decoder exactly.
        if iter >= 9 {
            if !late_synced && frame.keyframe {
                late_synced = true;
            }
            if late_synced {
                late.apply(&frame).expect("post-resync frames chain");
                assert_eq!(late.iter(), full.iter());
                assert_eq!(late.coords(), full.coords(), "late joiner diverged at iter {iter}");
            } else {
                assert!(!frame.keyframe);
                assert!(late.apply(&frame).is_err(), "orphan delta must be rejected");
            }
        }
    }
    assert!(frames_seen >= 10, "expected a real stream, saw {frames_seen} frames");
    assert!(keyframes_seen >= 2, "keyframe_every=4 must yield periodic resyncs");
    assert!(late_synced, "a keyframe must have arrived after iteration 9");
    assert_eq!(full.n(), n);
}

#[test]
fn quiet_iterations_do_not_break_the_delta_chain() {
    let mut rng = Lcg(77);
    let mut y = random_matrix(&mut rng, 50, 3, 10.0, 0.0);
    let mut enc = FrameEncoder::new(100);
    let mut dec = FrameDecoder::new();

    let key = enc.encode(1, &y, 0).expect("keyframe");
    dec.apply(&decode(&key).unwrap()).unwrap();

    // Iterations 2..=4 move nothing: the encoder emits no frames.
    for iter in 2..=4u64 {
        assert!(enc.encode(iter, &y, 0).is_none(), "no motion → no frame at iter {iter}");
    }

    // The next real delta must chain from the last *emitted* frame
    // (iter 1), not from the silently skipped iterations. The move is
    // many grid cells but stays inside the padded bbox.
    y.row_mut(13)[0] += 0.05;
    let delta = decode(&enc.encode(5, &y, 0).expect("motion → delta")).unwrap();
    assert!(!delta.keyframe);
    assert_eq!(delta.base_iter, 1);
    dec.apply(&delta).expect("delta after quiet iterations still chains");
    assert_eq!(dec.iter(), 5);
}

#[test]
fn truncated_and_corrupt_frames_are_rejected() {
    let mut rng = Lcg(0xBAD);
    let mut y = random_matrix(&mut rng, 40, 2, 8.0, 0.0);
    let mut enc = FrameEncoder::new(30);
    let key = enc.encode(1, &y, 0).expect("keyframe");
    y.row_mut(7)[1] += 0.05;
    let delta = enc.encode(2, &y, 0).expect("delta");
    assert!(!decode(&delta).unwrap().keyframe);

    for frame in [&key, &delta] {
        // Every strict prefix must fail: payload lengths are exact.
        for cut in 0..frame.len() {
            assert!(decode(&frame[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // Trailing garbage also breaks the exact-length contract.
        let mut padded = frame.clone();
        padded.extend_from_slice(&[0, 1, 2]);
        assert!(decode(&padded).is_err(), "oversized frame accepted");
    }

    let corrupt = |at: usize, val: &[u8]| {
        let mut bad = key.clone();
        bad[at..at + val.len()].copy_from_slice(val);
        bad
    };
    assert!(decode(&corrupt(0, b"XSNE")).is_err(), "bad magic");
    assert!(decode(&corrupt(4, &[9])).is_err(), "future version");
    assert!(decode(&corrupt(6, &0u16.to_le_bytes())).is_err(), "d = 0");
    assert!(decode(&corrupt(8, &9999u32.to_le_bytes())).is_err(), "inflated n");
    assert!(decode(&corrupt(12, &39u32.to_le_bytes())).is_err(), "keyframe changed != n");
    assert!(decode(&corrupt(24, &77u64.to_le_bytes())).is_err(), "keyframe base_iter != iter");
    assert!(
        decode(&corrupt(FIXED_HEADER, &f32::NAN.to_le_bytes())).is_err(),
        "NaN bbox min"
    );
    assert!(
        decode(&corrupt(FIXED_HEADER, &1.0e9f32.to_le_bytes())).is_err(),
        "inverted bbox (min > max)"
    );

    // A delta whose first changed index is out of 0..n.
    let d = 2usize;
    let payload_at = FIXED_HEADER + 8 * d;
    let mut bad = delta.clone();
    bad[payload_at..payload_at + 4].copy_from_slice(&1_000u32.to_le_bytes());
    assert!(decode(&bad).is_err(), "delta index out of range");
}

#[test]
fn keyframe_for_100k_points_fits_the_size_budget() {
    let mut rng = Lcg(0x100_000);
    let y = random_matrix(&mut rng, 100_000, 2, 50.0, 0.0);
    let mut enc = FrameEncoder::new(30);
    let bytes = enc.encode(0, &y, 0).expect("keyframe");
    // 32-byte header + 2 axes × 8 bytes + 100k × 2 × u16 = 400 048.
    assert_eq!(bytes.len(), FIXED_HEADER + 16 + 100_000 * 2 * 2);
    assert!(bytes.len() <= 500 * 1024, "keyframe {} bytes blows the ~500 KB budget", bytes.len());
    let frame = decode(&bytes).expect("decodes");
    assert_eq!((frame.n, frame.d), (100_000, 2));
}
