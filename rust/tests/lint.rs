//! The lint gate, as a test: the repo's own source tree must come out
//! clean under `lint.toml`, and every rule must fire on its violating
//! fixture and stay quiet on its conforming one.
//!
//! Fixtures live in `rust/tests/lint_fixtures/` and are plain text to
//! the linter — they are never compiled, so each pins rule behaviour
//! (including the shapes a rule must NOT flag) without having to build.

use funcsne::analysis::rules;
use funcsne::analysis::{lint_source, lint_tree, LintConfig};
use std::path::Path;

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> String {
    let path = repo_root().join("rust/tests/lint_fixtures").join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read fixture {path:?}: {e}"))
}

/// Lint a fixture pair at a virtual path inside the rule's scope:
/// the violation file must yield ≥ 1 finding, all of `rule`; the clean
/// file must yield none.
fn check_pair(rule: &'static str, virtual_path: &str) {
    let cfg = LintConfig::empty();
    let bad = fixture(&format!("{rule}_violation.rs"));
    let (findings, _) = lint_source(virtual_path, &bad, &cfg);
    assert!(!findings.is_empty(), "{rule}: violation fixture produced no findings");
    for f in &findings {
        assert_eq!(f.rule, rule, "unexpected rule in {rule} fixture: {f}");
        assert_eq!(f.path, virtual_path);
        assert!(f.line >= 1);
        let text = f.to_string();
        assert!(
            text.starts_with(&format!("{}:{}:", virtual_path, f.line)),
            "finding must lead with file:line, got {text:?}"
        );
    }
    let good = fixture(&format!("{rule}_clean.rs"));
    let (clean, _) = lint_source(virtual_path, &good, &cfg);
    assert!(clean.is_empty(), "{rule}: clean fixture flagged: {clean:?}");
}

#[test]
fn repo_tree_is_lint_clean() {
    let src = repo_root().join("rust/src");
    let cfg = LintConfig::load(&repo_root().join("lint.toml")).expect("parse lint.toml");
    let report = lint_tree(&src, &cfg).expect("lint the source tree");
    assert!(
        report.findings.is_empty(),
        "the crate's own tree must pass its lint:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("  {f}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 40, "walked the real tree, got {}", report.files_scanned);
    assert!(report.waived >= 1, "the rng.rs HashSet waiver should have been exercised");
}

#[test]
fn wall_clock_fixtures() {
    check_pair(rules::WALL_CLOCK, "engine/fixture.rs");
}

#[test]
fn hash_collections_fixtures() {
    check_pair(rules::HASH_COLLECTIONS, "knn/fixture.rs");
}

#[test]
fn safety_comment_fixtures() {
    check_pair(rules::SAFETY_COMMENT, "runtime/fixture.rs");
}

#[test]
fn raw_sync_fixtures() {
    check_pair(rules::RAW_SYNC, "server/frames/fixture.rs");
}

#[test]
fn server_panics_fixtures() {
    check_pair(rules::SERVER_PANICS, "server/fixture.rs");
}

#[test]
fn f32_reduction_fixtures() {
    check_pair(rules::F32_REDUCTION, "ld/fixture.rs");
}

/// The SIMD lane module is in rule 6's scope by exact path: its
/// horizontal folds must stay hand-ordered (`F32x8::hsum`), so an
/// `.sum()`/`.fold()` creeping in there must be flagged — while the
/// rest of `util/` stays out of scope as before.
#[test]
fn f32_reduction_covers_the_simd_lane_module() {
    let cfg = LintConfig::empty();
    let bad = fixture("f32_reduction_violation.rs");
    let (findings, _) = lint_source("util/simd.rs", &bad, &cfg);
    assert!(!findings.is_empty(), "f32_reduction must apply to util/simd.rs");
    assert!(findings.iter().all(|f| f.rule == rules::F32_REDUCTION), "{findings:?}");
    let (other, _) = lint_source("util/stats.rs", &bad, &cfg);
    assert!(other.is_empty(), "f32_reduction must not apply to the rest of util/: {other:?}");
}

/// `persist/` joined the deterministic scope: snapshot bytes must be
/// a pure function of session state and WAL replay thread-count-
/// invariant, so a stray clock or hash map in the durability codecs
/// is a finding exactly as it would be in the engine.
#[test]
fn deterministic_rules_cover_the_persist_module() {
    check_pair(rules::WALL_CLOCK, "persist/fixture.rs");
    check_pair(rules::HASH_COLLECTIONS, "persist/fixture.rs");
}

#[test]
fn deterministic_rules_do_not_fire_outside_their_scope() {
    let cfg = LintConfig::empty();
    for rule in [rules::WALL_CLOCK, rules::HASH_COLLECTIONS, rules::F32_REDUCTION] {
        let bad = fixture(&format!("{rule}_violation.rs"));
        let (findings, _) = lint_source("figures/fixture.rs", &bad, &cfg);
        assert!(findings.is_empty(), "{rule} must not apply to figures/: {findings:?}");
    }
    let bad = fixture("server_panics_violation.rs");
    let (findings, _) = lint_source("cli/fixture.rs", &bad, &cfg);
    assert!(findings.is_empty(), "server_panics must not apply to cli/: {findings:?}");
}

#[test]
fn runtime_sync_is_exempt_from_raw_sync() {
    let bad = fixture("raw_sync_violation.rs");
    let (findings, _) = lint_source("runtime/sync.rs", &bad, &LintConfig::empty());
    assert!(
        findings.iter().all(|f| f.rule != rules::RAW_SYNC),
        "runtime/sync.rs is where the raw primitives live: {findings:?}"
    );
}

#[test]
fn waiver_round_trip_suppresses_and_counts() {
    let bad = fixture("hash_collections_violation.rs");
    let cfg = LintConfig::from_text(
        "[allow.hash_collections]\nknn/fixture.rs = \"fixture waiver for the round-trip test\"\n",
    )
    .expect("valid waiver config");
    let (findings, waived) = lint_source("knn/fixture.rs", &bad, &cfg);
    assert!(findings.is_empty(), "waived findings must not surface: {findings:?}");
    assert!(waived >= 1, "suppressions must be counted");
    // The same waiver must not leak onto other files.
    let (other, _) = lint_source("knn/other.rs", &bad, &cfg);
    assert!(!other.is_empty());
}

#[test]
fn repo_lint_toml_justifications_are_present() {
    let cfg = LintConfig::load(&repo_root().join("lint.toml")).expect("parse lint.toml");
    for (rule, path, why) in cfg.entries() {
        assert!(
            why.trim().len() >= 10,
            "waiver ({rule}, {path}) needs a real justification, got {why:?}"
        );
    }
}
