//! Integration: every alternative backend must agree with the native
//! Rust reference on identical inputs.
//!
//! * PJRT — numerical agreement within tolerance (f32 fma/reassociation
//!   inside XLA): the strongest evidence that the L1 Pallas kernel, the
//!   L2 graph, the AOT pipeline, the runtime and the coordinator's
//!   tiling/padding all implement the same math. Skipped (with a
//!   notice) if `artifacts/` has not been built.
//! * Parallel (sharded threads) — **bitwise** agreement at any thread
//!   count: sharding must never change an embedding, only its
//!   wall-clock.
//! * SIMD (8-wide lane kernels) — agreement with native within
//!   lane-fold tolerance (horizontal sums reassociate f32 additions),
//!   plus **bitwise** self-agreement at any thread count: lane groups
//!   are a pure function of slot order, never of the shard partition.

use funcsne::config::EmbedConfig;
use funcsne::coordinator::driver::default_artifact_dir;
use funcsne::coordinator::PjrtBackend;
use funcsne::data::{datasets, Matrix};
use funcsne::engine::{ComputeBackend, FuncSne, NegSamples};
use funcsne::hd::Affinities;
use funcsne::knn::brute::brute_knn;
use funcsne::knn::iterative::IterativeKnn;
use funcsne::ld::{NativeBackend, ParallelBackend, SimdBackend};
use funcsne::session::{Event, Session};
use funcsne::util::Rng;

fn have_artifacts() -> bool {
    default_artifact_dir().join("manifest.txt").exists()
}

fn build_state(
    n: usize,
    d_ld: usize,
    k_hd: usize,
    k_ld: usize,
    seed: u64,
) -> (Matrix, Matrix, IterativeKnn, Affinities) {
    let ds = datasets::blobs(n, 16, 4, 0.8, 10.0, seed);
    let mut rng = Rng::new(seed ^ 7);
    let mut y = Matrix::zeros(n, d_ld);
    for v in y.data_mut() {
        *v = rng.gauss_ms(0.0, 1.0) as f32;
    }
    let mut knn = IterativeKnn::new(n, k_hd, k_ld);
    let hd_exact = brute_knn(&ds.x, k_hd);
    let ld_exact = brute_knn(&y, k_ld);
    for i in 0..n {
        for (j, d) in hd_exact.entries(i) {
            knn.hd.insert(i, j, d);
        }
        for (j, d) in ld_exact.entries(i) {
            knn.ld.insert(i, j, d);
        }
    }
    let mut aff = Affinities::new(n, k_hd);
    aff.recalibrate_all(&mut knn, (k_hd as f64 / 3.0).max(2.0));
    (ds.x, y, knn, aff)
}

#[test]
fn forces_and_sqdist_bitwise_parity_native_vs_parallel() {
    // n = 513 makes every multi-thread partition uneven; d = 3 exercises
    // the non-vectorised sqdist tail.
    let n = 513usize;
    let d_ld = 3usize;
    for &threads in &[1usize, 2, 4] {
        for &alpha in &[0.5f32, 1.0, 2.0] {
            let (x, y, knn, aff) = build_state(n, d_ld, 16, 8, 1000 + threads as u64);
            let mut rng = Rng::new(17);
            let neg = NegSamples::draw(n, 8, &mut rng);
            let far_scale = ((n - 1 - 20) as f32) / 8.0;

            let mut native = NativeBackend::new();
            let (mut a1, mut r1) = (Matrix::zeros(n, d_ld), Matrix::zeros(n, d_ld));
            let s1 = native
                .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut a1, &mut r1)
                .unwrap();

            // Floors dropped to (1, 1) so n = 513 genuinely fans out.
            let mut par = ParallelBackend::new(threads).with_shard_floors(1, 1);
            let (mut a2, mut r2) = (Matrix::zeros(n, d_ld), Matrix::zeros(n, d_ld));
            let s2 = par
                .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut a2, &mut r2)
                .unwrap();

            for (t, (u, v)) in a1.data().iter().zip(a2.data()).enumerate() {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "attr[{t}] native={u} parallel={v} (threads={threads}, α={alpha})"
                );
            }
            for (t, (u, v)) in r1.data().iter().zip(r2.data()).enumerate() {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "rep[{t}] native={u} parallel={v} (threads={threads}, α={alpha})"
                );
            }
            assert_eq!(
                s1.wsum.to_bits(),
                s2.wsum.to_bits(),
                "wsum native={} parallel={} (threads={threads}, α={alpha})",
                s1.wsum,
                s2.wsum
            );
            assert_eq!(s1.count, s2.count);
            assert_eq!(s1.covered, s2.covered);

            // Candidate scoring: same inputs, bitwise-equal outputs.
            let owners: Vec<u32> = (0..n as u32).collect();
            let cands: Vec<u32> = (0..n as u32).map(|i| (i + 7) % n as u32).collect();
            let (mut o1, mut o2) = (Vec::new(), Vec::new());
            native.sqdist_batch(&x, &owners, &cands, &mut o1).unwrap();
            par.sqdist_batch(&x, &owners, &cands, &mut o2).unwrap();
            assert_eq!(o1.len(), o2.len());
            for (t, (u, v)) in o1.iter().zip(&o2).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "sqdist[{t}] (threads={threads})");
            }
        }
    }
}

#[test]
fn engine_trajectory_is_thread_count_invariant() {
    // End-to-end consequence of bitwise backend parity: the same seed
    // must produce the same embedding regardless of --threads. n = 600
    // clears the production min-points-per-shard floor, so the 4-thread
    // run really does fork worker threads every force pass.
    let run = |threads: usize| {
        let ds = datasets::blobs(600, 8, 3, 0.6, 10.0, 5);
        let mut s = Session::builder()
            .dataset(ds.x)
            .k_hd(12)
            .k_ld(8)
            .perplexity(8.0)
            .n_neg(6)
            .jumpstart_iters(5)
            .early_exag_iters(10)
            .seed(7)
            .threads(threads)
            .build()
            .unwrap();
        s.run(60).unwrap();
        (s.backend_name(), s.embedding().data().to_vec())
    };
    let (name1, y1) = run(1);
    let (name4, y4) = run(4);
    assert_eq!(name1, "native");
    assert_eq!(name4, "parallel");
    assert_eq!(y1.len(), y4.len());
    for (t, (a, b)) in y1.iter().zip(&y4).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "embedding[{t}] diverged between 1 and 4 threads: {a} vs {b}"
        );
    }
}

/// Golden-trajectory regression: for a fixed seed, 50 iterations of
/// `blobs` and `scurve` must produce bitwise-identical embeddings AND
/// bitwise-identical quality-probe trajectories at every thread count —
/// the determinism contract the online probe (and every reproducible
/// experiment) relies on. The CI matrix additionally runs this whole
/// suite under `FUNCSNE_THREADS=1` and `=4`; the explicit
/// `.threads(...)` here pins the contract independently of the env.
#[test]
fn golden_trajectory_and_probe_bitwise_identical_across_threads() {
    use std::cell::RefCell;
    use std::rc::Rc;
    fn checksum(data: &[f32]) -> u64 {
        data.iter().fold(0xcbf2_9ce4_8422_2325u64, |h, v| {
            (h ^ v.to_bits() as u64).wrapping_mul(0x1000_0000_01b3)
        })
    }
    for dataset in ["blobs", "scurve"] {
        let run = |threads: usize| -> (u64, Vec<[u64; 5]>) {
            let x = match dataset {
                "blobs" => datasets::blobs(400, 8, 4, 0.6, 10.0, 21).x,
                _ => datasets::scurve(400, 0.02, false, 21).x,
            };
            let traj: Rc<RefCell<Vec<[u64; 5]>>> = Rc::new(RefCell::new(Vec::new()));
            let tap = Rc::clone(&traj);
            let mut s = Session::builder()
                .dataset(x)
                .k_hd(12)
                .k_ld(8)
                .perplexity(8.0)
                .n_neg(6)
                .jumpstart_iters(5)
                .early_exag_iters(10)
                .seed(13)
                .threads(threads)
                .probe_every(10)
                .probe_anchors(64)
                .build()
                .unwrap();
            s.add_sink(Box::new(move |e: &Event| {
                if let Event::Quality { iter, recall, trust, cont, knn_recall_hd } = e {
                    tap.borrow_mut().push([
                        *iter as u64,
                        recall.to_bits(),
                        trust.to_bits(),
                        cont.to_bits(),
                        knn_recall_hd.to_bits(),
                    ]);
                }
            }));
            s.run(50).unwrap();
            let sum = checksum(s.embedding().data());
            let traj = traj.borrow().clone();
            (sum, traj)
        };
        let (c1, t1) = run(1);
        assert_eq!(t1.len(), 5, "{dataset}: expected 5 probe reports over 50 iters");
        for &threads in &[2usize, 4] {
            let (c, t) = run(threads);
            assert_eq!(
                c1, c,
                "{dataset}: embedding checksum diverged between 1 and {threads} threads"
            );
            assert_eq!(
                t1, t,
                "{dataset}: probe trajectory diverged between 1 and {threads} threads"
            );
        }
    }
}

/// The stream-RNG determinism model end to end: a refinement-heavy run
/// must leave the embedding, the velocity-driven trajectory, BOTH
/// estimated neighbour tables (ids and stored distances), the dirty
/// flags and the engine counters bitwise-identical across threads
/// 1/2/4. n = 701 clears the 256-point refinement and force/update
/// floors, so those passes genuinely fork (with uneven partitions) at
/// every multi-thread width; negative sampling (floor 2048) and HD
/// pair scoring (floor 8192 pairs) stay single-shard here — their
/// sharded paths are pinned by the floor-1 unit tests in
/// `engine::backend` and `knn::iterative`.
#[test]
fn refinement_and_full_step_trajectories_bitwise_across_threads() {
    fn table_state(t: &funcsne::knn::NeighborTable) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for i in 0..t.n() {
            for (j, d) in t.entries(i) {
                out.push((j, d.to_bits()));
            }
            out.push((u32::MAX, 0)); // row separator
        }
        out
    }
    let run = |threads: usize| {
        let ds = datasets::blobs(701, 10, 4, 0.6, 10.0, 33);
        let mut s = Session::builder()
            .dataset(ds.x)
            .k_hd(16)
            .k_ld(8)
            .perplexity(10.0)
            .n_neg(8)
            .jumpstart_iters(4)
            .early_exag_iters(10)
            .seed(29)
            .threads(threads)
            .build()
            .unwrap();
        s.run(40).unwrap();
        let eng = s.engine();
        (
            s.embedding().data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            table_state(&eng.knn.hd),
            table_state(&eng.knn.ld),
            eng.knn.hd_dirty.clone(),
            (eng.stats.hd_refines, eng.stats.hd_new_last, eng.stats.implosions),
        )
    };
    let (y1, hd1, ld1, dirty1, counters1) = run(1);
    for threads in [2usize, 4] {
        let (y, hd, ld, dirty, counters) = run(threads);
        assert_eq!(y1, y, "embedding diverged at {threads} threads");
        assert_eq!(hd1, hd, "HD table diverged at {threads} threads");
        assert_eq!(ld1, ld, "LD table diverged at {threads} threads");
        assert_eq!(dirty1, dirty, "dirty flags diverged at {threads} threads");
        assert_eq!(counters1, counters, "engine counters diverged at {threads} threads");
    }
}

/// SIMD contract, integration-level: the lane backend must agree with
/// the native reference within lane-fold tolerance (8-wide horizontal
/// sums reassociate f32 additions, so bitwise equality vs native is not
/// promised) while staying **bitwise** identical to itself at any
/// thread count — lane groups are formed per point from slot order
/// alone, so sharding cannot change which values meet in a register.
#[test]
fn simd_forces_close_to_native_and_bitwise_thread_invariant() {
    // n = 513: uneven shard partitions AND a non-multiple-of-8 negative
    // pool per point; d = 3 exercises lane-tail handling end to end.
    let n = 513usize;
    for &d_ld in &[3usize, 8] {
        for &alpha in &[0.5f32, 1.0] {
            let (x, y, knn, aff) = build_state(n, d_ld, 16, 8, 2000 + d_ld as u64);
            let mut rng = Rng::new(23);
            let neg = NegSamples::draw(n, 8, &mut rng);
            let far_scale = ((n - 1 - 20) as f32) / 8.0;

            let mut native = NativeBackend::new();
            let (mut a0, mut r0) = (Matrix::zeros(n, d_ld), Matrix::zeros(n, d_ld));
            let s0 = native
                .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut a0, &mut r0)
                .unwrap();

            let mut runs = Vec::new();
            for &threads in &[1usize, 2, 4] {
                let mut simd = SimdBackend::new(threads).with_shard_floors(1, 1);
                let (mut a, mut r) = (Matrix::zeros(n, d_ld), Matrix::zeros(n, d_ld));
                let s = simd
                    .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut a, &mut r)
                    .unwrap();
                let owners: Vec<u32> = (0..n as u32).collect();
                let cands: Vec<u32> = (0..n as u32).map(|i| (i + 7) % n as u32).collect();
                let mut sq = Vec::new();
                simd.sqdist_batch(&x, &owners, &cands, &mut sq).unwrap();
                runs.push((threads, a, r, s, sq));
            }

            // Close to native everywhere the native reference is.
            let tol = 1e-3f32;
            let (_, a1, r1, s1, sq1) = &runs[0];
            for (t, (v0, v)) in a0.data().iter().zip(a1.data()).enumerate() {
                assert!(
                    (v0 - v).abs() <= tol * (1.0 + v0.abs()),
                    "attr[{t}] native={v0} simd={v} (d={d_ld}, α={alpha})"
                );
            }
            for (t, (v0, v)) in r0.data().iter().zip(r1.data()).enumerate() {
                assert!(
                    (v0 - v).abs() <= tol * (1.0 + v0.abs()),
                    "rep[{t}] native={v0} simd={v} (d={d_ld}, α={alpha})"
                );
            }
            assert!(
                (s0.wsum - s1.wsum).abs() <= 1e-3 * (1.0 + s0.wsum.abs()),
                "wsum native={} simd={}",
                s0.wsum,
                s1.wsum
            );
            assert_eq!(s0.count, s1.count);
            assert_eq!(s0.covered, s1.covered);

            // Bitwise identical to itself across thread counts.
            for (threads, a, r, s, sq) in &runs[1..] {
                for (t, (u, v)) in a1.data().iter().zip(a.data()).enumerate() {
                    assert_eq!(
                        u.to_bits(),
                        v.to_bits(),
                        "attr[{t}] simd t1={u} t{threads}={v} (d={d_ld}, α={alpha})"
                    );
                }
                for (t, (u, v)) in r1.data().iter().zip(r.data()).enumerate() {
                    assert_eq!(
                        u.to_bits(),
                        v.to_bits(),
                        "rep[{t}] simd t1={u} t{threads}={v} (d={d_ld}, α={alpha})"
                    );
                }
                assert_eq!(s1.wsum.to_bits(), s.wsum.to_bits(), "wsum at {threads} threads");
                assert_eq!((s1.count, s1.covered), (s.count, s.covered));
                for (t, (u, v)) in sq1.iter().zip(sq).enumerate() {
                    assert_eq!(u.to_bits(), v.to_bits(), "sqdist[{t}] at {threads} threads");
                }
            }
        }
    }
}

/// Golden SIMD trajectory: a full engine run on the SIMD backend must
/// be bitwise thread-count-invariant end to end — the same contract
/// [`golden_trajectory_and_probe_bitwise_identical_across_threads`]
/// pins for the scalar backends, at every SIMD thread width.
#[test]
fn simd_engine_trajectory_is_thread_count_invariant() {
    let run = |threads: usize| {
        let ds = datasets::blobs(600, 8, 3, 0.6, 10.0, 5);
        let mut s = Session::builder()
            .dataset(ds.x)
            .backend_name("simd")
            .k_hd(12)
            .k_ld(8)
            .perplexity(8.0)
            .n_neg(6)
            .jumpstart_iters(5)
            .early_exag_iters(10)
            .seed(7)
            .threads(threads)
            .build()
            .unwrap();
        s.run(60).unwrap();
        assert_eq!(s.backend_name(), "simd");
        s.embedding().data().to_vec()
    };
    let y1 = run(1);
    for threads in [2usize, 4] {
        let y = run(threads);
        assert_eq!(y1.len(), y.len());
        for (t, (a, b)) in y1.iter().zip(&y).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "SIMD embedding[{t}] diverged between 1 and {threads} threads: {a} vs {b}"
            );
        }
        assert!(y.iter().all(|v| v.is_finite()), "SIMD run diverged at {threads} threads");
    }
}

#[test]
fn forces_parity_native_vs_pjrt() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    // Sizes straddle the 512-point tile boundary to exercise padding.
    for &(n, d_ld, alpha) in &[(300usize, 2usize, 1.0f32), (700, 2, 0.5), (513, 8, 2.0)] {
        let (x, y, knn, aff) = build_state(n, d_ld, 16, 8, 42 + n as u64);
        let mut rng = Rng::new(9);
        let neg = NegSamples::draw(n, 8, &mut rng);
        let far_scale = ((n - 1 - 24) as f32) / 8.0;

        let mut native = NativeBackend::new();
        let (mut a1, mut r1) = (Matrix::zeros(n, d_ld), Matrix::zeros(n, d_ld));
        let s1 = native
            .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut a1, &mut r1)
            .unwrap();

        let mut pjrt = PjrtBackend::new(&default_artifact_dir()).unwrap();
        let (mut a2, mut r2) = (Matrix::zeros(n, d_ld), Matrix::zeros(n, d_ld));
        let s2 = pjrt
            .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut a2, &mut r2)
            .unwrap();

        let _ = x;
        let tol = 1e-3f32;
        for (t, (v1, v2)) in a1.data().iter().zip(a2.data()).enumerate() {
            assert!(
                (v1 - v2).abs() <= tol * (1.0 + v1.abs()),
                "attr[{t}] native={v1} pjrt={v2} (n={n}, d={d_ld}, α={alpha})"
            );
        }
        for (t, (v1, v2)) in r1.data().iter().zip(r2.data()).enumerate() {
            assert!(
                (v1 - v2).abs() <= tol * (1.0 + v1.abs()),
                "rep[{t}] native={v1} pjrt={v2} (n={n}, d={d_ld}, α={alpha})"
            );
        }
        assert!(
            (s1.wsum - s2.wsum).abs() <= 1e-3 * (1.0 + s1.wsum.abs()),
            "wsum native={} pjrt={}",
            s1.wsum,
            s2.wsum
        );
        assert_eq!(s1.count, s2.count);
    }
}

#[test]
fn sqdist_parity_native_vs_pjrt() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    // M = 16 hits an artifact exactly; M = 50 exercises column padding;
    // 5000 pairs exercise the T tail.
    for &(m_data, pairs) in &[(16usize, 1000usize), (50, 5000)] {
        let ds = datasets::blobs(400, m_data, 4, 1.0, 8.0, 3);
        let mut rng = Rng::new(4);
        let owners: Vec<u32> = (0..pairs).map(|_| rng.below(400) as u32).collect();
        let cands: Vec<u32> = (0..pairs).map(|_| rng.below(400) as u32).collect();
        let mut native = NativeBackend::new();
        let mut pjrt = PjrtBackend::new(&default_artifact_dir()).unwrap();
        let (mut o1, mut o2) = (Vec::new(), Vec::new());
        native.sqdist_batch(&ds.x, &owners, &cands, &mut o1).unwrap();
        pjrt.sqdist_batch(&ds.x, &owners, &cands, &mut o2).unwrap();
        assert_eq!(o1.len(), o2.len());
        for t in 0..o1.len() {
            assert!(
                (o1[t] - o2[t]).abs() <= 1e-3 * (1.0 + o1[t].abs()),
                "pair {t}: native={} pjrt={}",
                o1[t],
                o2[t]
            );
        }
    }
}

#[test]
fn full_engine_run_on_pjrt_backend() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let ds = datasets::blobs(400, 16, 4, 0.5, 12.0, 11);
    let labels = ds.labels.clone();
    let cfg = EmbedConfig {
        k_hd: 16,
        k_ld: 8,
        n_neg: 8,
        perplexity: 10.0,
        jumpstart_iters: 10,
        early_exag_iters: 30,
        backend: funcsne::config::Backend::Pjrt,
        ..EmbedConfig::default()
    };
    let mut backend = PjrtBackend::new(&default_artifact_dir()).unwrap();
    backend.warmup(cfg.k_hd, cfg.k_ld, cfg.n_neg, cfg.ld_dim, ds.x.d()).unwrap();
    let mut engine = FuncSne::new(ds.x, cfg).unwrap();
    engine.run(150, &mut backend).unwrap();
    let y = engine.embedding();
    assert!(y.data().iter().all(|v| v.is_finite()), "PJRT run diverged");
    // Same-label points should be closer on average than cross-label.
    let (mut same, mut diff) = (Vec::new(), Vec::new());
    for i in 0..y.n() {
        for j in (i + 1)..y.n().min(i + 30) {
            let d = y.sqdist(i, j) as f64;
            if labels[i] == labels[j] {
                same.push(d);
            } else {
                diff.push(d);
            }
        }
    }
    let ms = funcsne::util::stats::mean(&same);
    let md = funcsne::util::stats::mean(&diff);
    assert!(ms < md, "PJRT embedding did not separate clusters: {ms} vs {md}");
}
