//! End-to-end integration: the full engine on each synthetic dataset
//! must produce an embedding whose R_NX AUC clearly beats random and
//! approaches the exact-t-SNE reference at small N.

use funcsne::baselines::exact_tsne::{exact_tsne, TsneConfig};
use funcsne::config::EmbedConfig;
use funcsne::coordinator::driver::{dataset_by_name, maybe_pca_reduce};
use funcsne::engine::FuncSne;
use funcsne::ld::NativeBackend;
use funcsne::metrics::rnx_auc;

fn run_engine(x: funcsne::data::Matrix, ld_dim: usize, iters: usize) -> funcsne::data::Matrix {
    let cfg = EmbedConfig {
        ld_dim,
        k_hd: 24.min(x.n() - 1),
        k_ld: 12.min(x.n() - 1),
        perplexity: 8.0,
        n_iters: iters,
        jumpstart_iters: 60,
        early_exag_iters: 120,
        ..EmbedConfig::default()
    };
    let mut engine = FuncSne::new(x, cfg).unwrap();
    let mut backend = NativeBackend::new();
    engine.run(iters, &mut backend).unwrap();
    engine.y
}

#[test]
fn quality_beats_random_on_every_dataset() {
    for name in ["blobs", "coil", "mnist", "rat_brain", "scurve"] {
        let ds = dataset_by_name(name, 500, 3).unwrap();
        let x = maybe_pca_reduce(ds.x.clone(), 32, 0);
        let y = run_engine(x, 2, 400);
        let auc = rnx_auc(&ds.x, &y, 40);
        assert!(
            auc > 0.15,
            "{name}: AUC {auc} barely better than random placement"
        );
    }
}

#[test]
fn engine_approaches_exact_tsne_quality() {
    let ds = dataset_by_name("blobs", 400, 4).unwrap();
    let y_fast = run_engine(ds.x.clone(), 2, 600);
    let auc_fast = rnx_auc(&ds.x, &y_fast, 40);
    let y_exact = exact_tsne(
        &ds.x,
        &TsneConfig { n_iters: 300, perplexity: 10.0, ..TsneConfig::default() },
    );
    let auc_exact = rnx_auc(&ds.x, &y_exact, 40);
    assert!(
        auc_fast > auc_exact * 0.7,
        "accelerated engine too far below exact t-SNE: {auc_fast} vs {auc_exact}"
    );
}

#[test]
fn higher_ld_dims_preserve_more_structure() {
    // The "unconstrained dimensionality" claim: at equal budget, an 8-D
    // embedding should preserve neighbourhoods at least as well as 2-D.
    let ds = dataset_by_name("deep_features", 500, 5).unwrap();
    let x = maybe_pca_reduce(ds.x.clone(), 32, 0);
    let y2 = run_engine(x.clone(), 2, 400);
    let y8 = run_engine(x.clone(), 8, 400);
    let auc2 = rnx_auc(&ds.x, &y2, 40);
    let auc8 = rnx_auc(&ds.x, &y8, 40);
    assert!(
        auc8 > auc2 - 0.05,
        "8-D embedding should not lose to 2-D: {auc8} vs {auc2}"
    );
}

#[test]
fn seeds_are_reproducible() {
    let ds = dataset_by_name("blobs", 300, 6).unwrap();
    let y1 = run_engine(ds.x.clone(), 2, 100);
    let y2 = run_engine(ds.x.clone(), 2, 100);
    assert_eq!(y1.data(), y2.data(), "same seed must give identical embeddings");
}
