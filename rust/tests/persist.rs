//! Integration: durable sessions — snapshot codec round-trips, strict
//! corruption rejection, write-ahead logging, and bitwise-exact crash
//! recovery under injected faults at multiple thread counts.
//!
//! The contract under test (docs/persistence.md): restoring a snapshot
//! and replaying the WAL tail reproduces the interrupted trajectory
//! bit for bit, and no crash — torn write, failed rename, mid-append
//! power cut — can ever leave a state file that restores incorrectly
//! (it either restores exactly or is rejected/skipped).

use funcsne::coordinator::driver::default_artifact_dir;
use funcsne::data::datasets;
use funcsne::persist::{self, failpoint, snapshot, wal};
use funcsne::session::{Command, Session};
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

/// Failpoint state is process-global and the test harness runs tests
/// concurrently; every test that arms failpoints (or asserts none are
/// armed) takes this guard.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmpdir(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("funcsne_persist_test_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    std::fs::create_dir_all(&p).unwrap();
    p
}

fn cleanup(dir: &std::path::Path) {
    let _ = std::fs::remove_dir_all(dir);
}

/// A small deterministic session; `threads` shards the force passes
/// (the engine is bitwise thread-count-invariant, which is exactly
/// what lets recovery promise bitwise-identical trajectories).
fn small_session(threads: usize, seed: u64) -> Session {
    let ds = datasets::blobs(120, 6, 3, 0.5, 10.0, seed);
    Session::builder()
        .dataset(ds.x)
        .k_hd(12)
        .k_ld(8)
        .perplexity(8.0)
        .n_neg(6)
        .jumpstart_iters(5)
        .early_exag_iters(10)
        .threads(threads)
        .seed(seed)
        .build()
        .unwrap()
}

/// The scripted steering a "user" applies mid-run: every command kind
/// that changes the trajectory, including dynamic points and a
/// pause/resume pair drained in one batch.
fn schedule() -> Vec<(usize, Command)> {
    let extra = datasets::blobs(5, 6, 1, 0.4, 2.0, 77);
    vec![
        (5, Command::SetAlpha(0.8)),
        (9, Command::SetAttraction(1.5)),
        (11, Command::MovePoint(3, vec![0.5, -0.5, 1.0, 0.0, -1.0, 0.25])),
        (13, Command::SetRepulsion(0.9)),
        (15, Command::Pause),
        (15, Command::Resume),
        (17, Command::InsertPoints(extra.x)),
        (19, Command::RemovePoint(7)),
        (21, Command::SetPerplexity(6.0)),
        (25, Command::Implode),
        (27, Command::SetAlpha(1.2)),
    ]
}

/// Step `session` to iteration `upto`, enqueueing each scheduled
/// command at its iteration. Entries behind the session's current
/// iteration are skipped — after a restore they were already replayed
/// from the log.
fn drive(session: &mut Session, schedule: &[(usize, Command)], upto: usize) {
    while session.iterations() < upto {
        let it = session.iterations();
        for (at, cmd) in schedule {
            if *at == it {
                session.enqueue(cmd.clone());
            }
        }
        session.step().unwrap();
    }
}

fn embedding_bits(s: &Session) -> Vec<u32> {
    s.embedding().data().iter().map(|v| v.to_bits()).collect()
}

fn assert_same_trajectory(a: &Session, b: &Session, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration counts diverged");
    assert_eq!(a.n(), b.n(), "{what}: point counts diverged");
    assert_eq!(embedding_bits(a), embedding_bits(b), "{what}: embeddings not bitwise equal");
}

/// `SessionState` is deliberately not `Debug` (it is an engine image,
/// not a printable value), so failures are extracted via `.err()`.
fn decode_err(bytes: &[u8]) -> String {
    snapshot::decode(bytes).err().expect("decode of a damaged snapshot must fail")
}

// ------------------------------------------------------ codec round-trip

#[test]
fn snapshot_round_trip_continues_bitwise() {
    let mut live = small_session(1, 7);
    live.run(40).unwrap();
    let bytes = snapshot::encode(&live.export_state());
    let st = snapshot::decode(&bytes).expect("own snapshot must decode");
    let mut restored = Session::from_state(st, &default_artifact_dir()).unwrap();
    assert_eq!(restored.iterations(), live.iterations());
    live.run(25).unwrap();
    restored.run(25).unwrap();
    assert_same_trajectory(&live, &restored, "decode(encode(s))");
}

#[test]
fn corrupted_snapshots_are_rejected_never_partially_trusted() {
    let mut s = small_session(1, 3);
    s.run(10).unwrap();
    let good = snapshot::encode(&s.export_state());
    assert!(snapshot::decode(&good).is_ok());

    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    assert!(decode_err(&bad).contains("magic"));

    let mut bad = good.clone();
    bad[4] = snapshot::VERSION + 1;
    assert!(decode_err(&bad).contains("version"));

    for cut in [0, 4, 7, 8, 20, good.len() / 2, good.len() - 1] {
        assert!(snapshot::decode(&good[..cut]).is_err(), "truncation at {cut} must be rejected");
    }

    // Single bit flips anywhere past the (unchecked) reserved header
    // bytes: tag, length, payload or CRC — all must be detected.
    let step = (good.len() / 64).max(1);
    for pos in (8..good.len()).step_by(step) {
        let mut bad = good.clone();
        bad[pos] ^= 0x01;
        assert!(snapshot::decode(&bad).is_err(), "bit flip at byte {pos} went undetected");
    }

    let mut bad = good.clone();
    bad.push(0);
    assert!(decode_err(&bad).contains("trailing"));
}

// --------------------------------------------------- atomicity under fault

#[test]
fn torn_snapshot_write_never_replaces_the_published_image() {
    let _g = serial();
    failpoint::clear();
    let dir = tmpdir("torn_write");
    let paths = persist::session_paths(&dir, 1);
    let mut s = small_session(1, 5);
    s.run(10).unwrap();
    persist::checkpoint_session(&mut s, &paths).unwrap();
    let image_a = std::fs::read(&paths.snap).unwrap();

    s.run(10).unwrap();
    failpoint::arm("snapshot.write", failpoint::FailAction::Torn, Some(1));
    assert!(persist::checkpoint_session(&mut s, &paths).is_err());
    failpoint::clear();

    // The published snapshot is byte-identical to image A, and if any
    // torn temp debris survived, it must never decode.
    assert_eq!(std::fs::read(&paths.snap).unwrap(), image_a);
    if let Ok(bytes) = std::fs::read(snapshot::tmp_path(&paths.snap)) {
        assert!(snapshot::decode(&bytes).is_err(), "a torn temp file must not be acceptable");
    }
    let restored = persist::restore_session(&paths, &default_artifact_dir()).unwrap();
    assert_eq!(restored.session.iterations(), 10, "restore must land on image A");
    cleanup(&dir);
}

#[test]
fn crash_between_write_and_rename_keeps_the_old_image() {
    let _g = serial();
    failpoint::clear();
    let dir = tmpdir("rename_crash");
    let paths = persist::session_paths(&dir, 1);
    let mut s = small_session(1, 6);
    s.run(8).unwrap();
    persist::checkpoint_session(&mut s, &paths).unwrap();
    let image_a = std::fs::read(&paths.snap).unwrap();

    s.run(7).unwrap();
    // Crash after the temp file is complete but before the rename: a
    // real crash here leaves tmp debris next to the old image.
    failpoint::arm("snapshot.rename", failpoint::FailAction::Crash, Some(1));
    assert!(persist::checkpoint_session(&mut s, &paths).is_err());
    failpoint::clear();

    assert_eq!(std::fs::read(&paths.snap).unwrap(), image_a);
    assert!(snapshot::tmp_path(&paths.snap).exists(), "crash leaves the temp file behind");
    let restored = persist::restore_session(&paths, &default_artifact_dir()).unwrap();
    assert_eq!(restored.session.iterations(), 8);

    // A later checkpoint heals: publishes the new image over both.
    let mut s2 = restored.session;
    s2.run(2).unwrap();
    persist::checkpoint_session(&mut s2, &paths).unwrap();
    let restored = persist::restore_session(&paths, &default_artifact_dir()).unwrap();
    assert_eq!(restored.session.iterations(), 10);
    cleanup(&dir);
}

// ------------------------------------------------- crash-recovery property

/// Kill-and-restore is bitwise-identical to never crashing, across
/// thread counts and across injected checkpoint faults (torn snapshot
/// write, injected I/O error, crash between write and rename). The
/// durable run checkpoints mid-flight, keeps going, "crashes" (the
/// session is dropped, in-memory state gone), restores from disk and
/// finishes the scripted schedule — landing on the exact bits of an
/// uninterrupted reference run.
#[test]
fn kill_and_restore_matches_the_uninterrupted_run_bitwise() {
    let _g = serial();
    failpoint::clear();
    let sched = schedule();
    let total = 40usize;
    let faults: [Option<(&str, failpoint::FailAction)>; 4] = [
        None,
        Some(("snapshot.write", failpoint::FailAction::Torn)),
        Some(("snapshot.write", failpoint::FailAction::Error)),
        Some(("snapshot.rename", failpoint::FailAction::Crash)),
    ];
    for threads in [1usize, 4] {
        // Uninterrupted reference (no durability attached at all).
        let mut reference = small_session(threads, 11);
        drive(&mut reference, &sched, total);

        for (fi, fault) in faults.iter().enumerate() {
            let dir = tmpdir(&format!("kill_restore_t{threads}_f{fi}"));
            let paths = persist::session_paths(&dir, 0);

            let mut durable = small_session(threads, 11);
            durable.set_wal(Some(wal::WalWriter::create(&paths.wal, 1).unwrap()));
            drive(&mut durable, &sched, 12);
            persist::checkpoint_session(&mut durable, &paths).unwrap();
            drive(&mut durable, &sched, 29);
            if let Some((name, action)) = fault {
                // A second checkpoint dies at the injected fault; the
                // session keeps its trajectory either way.
                failpoint::arm(name, *action, Some(1));
                assert!(persist::checkpoint_session(&mut durable, &paths).is_err());
                failpoint::clear();
            }
            drop(durable); // the crash: everything in memory is gone

            let restored = persist::restore_session(&paths, &default_artifact_dir())
                .expect("state files must restore");
            assert!(
                restored.replayed > 0,
                "commands after the iteration-12 checkpoint must come from the WAL"
            );
            let mut recovered = restored.session;
            drive(&mut recovered, &sched, total);
            assert_same_trajectory(
                &reference,
                &recovered,
                &format!("threads={threads}, fault #{fi}"),
            );
            cleanup(&dir);
        }
    }
}

/// Write-ahead means write-ahead: a command whose log append fails is
/// refused (never applied), so the on-disk log can never be *behind*
/// the live trajectory — and a restore agrees with a reference run
/// that skipped the refused command.
#[test]
fn unloggable_commands_are_refused_and_recovery_agrees() {
    let _g = serial();
    failpoint::clear();
    let dir = tmpdir("unloggable");
    let paths = persist::session_paths(&dir, 0);
    let total = 36usize;

    // Reference: same schedule minus the final command (which the
    // durable run will fail to log, and must therefore never apply).
    let sched = schedule();
    let reference_sched: Vec<(usize, Command)> =
        sched.iter().filter(|(at, _)| *at != 27).cloned().collect();
    let mut reference = small_session(1, 13);
    drive(&mut reference, &reference_sched, total);

    let mut durable = small_session(1, 13);
    durable.set_wal(Some(wal::WalWriter::create(&paths.wal, 1).unwrap()));
    drive(&mut durable, &sched, 12);
    persist::checkpoint_session(&mut durable, &paths).unwrap();
    drive(&mut durable, &sched, 27);
    let (_, rejected_before) = durable.command_counts();
    failpoint::arm("wal.append", failpoint::FailAction::Error, Some(1));
    drive(&mut durable, &sched, total); // the iter-27 command fails to log
    failpoint::clear();
    let (_, rejected_after) = durable.command_counts();
    assert_eq!(rejected_after, rejected_before + 1, "the unlogged command must be refused");
    assert!(durable.wal_error().is_some(), "a failed append must poison the log");
    assert_same_trajectory(&reference, &durable, "live run with a refused command");
    drop(durable);

    // Restore replays only what the log durably holds — which is
    // exactly what the live session applied.
    let restored = persist::restore_session(&paths, &default_artifact_dir()).unwrap();
    let mut recovered = restored.session;
    drive(&mut recovered, &reference_sched, total);
    assert_same_trajectory(&reference, &recovered, "recovery after a refused command");
    cleanup(&dir);
}

// ------------------------------------------------------------ boot restore

#[test]
fn boot_restore_skips_corrupt_and_orphaned_state_files() {
    let _g = serial();
    failpoint::clear();
    let dir = tmpdir("boot_scan");

    // Session 0: healthy.
    let paths0 = persist::session_paths(&dir, 0);
    let mut s = small_session(1, 2);
    s.run(8).unwrap();
    persist::checkpoint_session(&mut s, &paths0).unwrap();

    // Session 1: a snapshot that is not a snapshot.
    let paths1 = persist::session_paths(&dir, 1);
    std::fs::write(&paths1.snap, b"FSNP but then garbage").unwrap();

    // Session 2: an orphaned WAL with no snapshot beside it.
    let paths2 = persist::session_paths(&dir, 2);
    drop(wal::WalWriter::create(&paths2.wal, 1).unwrap());

    let boot = persist::restore_all(&dir, &default_artifact_dir());
    assert_eq!(boot.sessions.len(), 1, "only the healthy session comes back");
    assert_eq!(boot.sessions[0].0, 0);
    assert_eq!(boot.sessions[0].1.session.iterations(), 8);
    assert_eq!(boot.skipped.len(), 2, "corrupt + orphaned files are skipped, not fatal");
    assert!(boot.skipped.iter().any(|sk| sk.path == paths1.snap));
    assert!(boot
        .skipped
        .iter()
        .any(|sk| sk.path == paths2.wal && sk.reason.contains("orphaned")));

    // The skipped files stay in place for post-mortem inspection.
    assert!(paths1.snap.exists() && paths2.wal.exists());
    cleanup(&dir);
}

#[test]
fn delete_removes_every_durable_artifact() {
    let _g = serial();
    failpoint::clear();
    let dir = tmpdir("delete");
    let paths = persist::session_paths(&dir, 4);
    let mut s = small_session(1, 9);
    s.run(6).unwrap();
    persist::checkpoint_session(&mut s, &paths).unwrap();
    // Leave tmp debris too, as a crash would.
    std::fs::write(snapshot::tmp_path(&paths.snap), b"debris").unwrap();
    assert!(paths.snap.exists() && paths.wal.exists());

    persist::remove_session_files(&paths).unwrap();
    assert!(!paths.snap.exists());
    assert!(!paths.wal.exists());
    assert!(!snapshot::tmp_path(&paths.snap).exists());
    // Idempotent: deleting an already-deleted session is fine.
    persist::remove_session_files(&paths).unwrap();
    cleanup(&dir);
}
