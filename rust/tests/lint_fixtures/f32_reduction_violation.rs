// Lint fixture (never compiled): an f32 `.sum()` in a sharded module —
// float addition is non-associative, so shard order changes the bits.

pub fn norm(xs: &[f32]) -> f32 {
    let total: f32 = xs.iter().map(|v| v * v).sum();
    total.sqrt()
}
