// Lint fixture (never compiled): the checked wrappers from
// runtime::sync, which rank locks and centralize poison recovery.
use crate::runtime::sync::{DebugCondvar, DebugMutex};

pub struct Queue {
    state: DebugMutex<Vec<u8>>,
    ready: DebugCondvar,
}
