// Lint fixture (never compiled): hash collections in a deterministic
// module — iteration order is randomized per process.
use std::collections::HashMap;

pub fn count(xs: &[u32]) -> usize {
    let mut m = HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0usize) += 1;
    }
    m.len()
}
