// Lint fixture (never compiled): both accepted SAFETY placements — a
// multi-line comment block directly above, and a same-line comment.

pub fn as_bytes(xs: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding and every bit pattern is a valid u8;
    // the pointer and length describe the slice's own allocation.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

pub fn first(xs: &[u32]) -> u32 {
    unsafe { *xs.get_unchecked(0) } // SAFETY: caller guarantees non-empty
}
