// Lint fixture (never compiled): failures map to errors, and the two
// shapes the rule must NOT flag — `.expect(` with a non-string
// argument (a parser method, not Option::expect) and unwrap_or_else.

pub fn handle(body: Option<&str>) -> Result<String, String> {
    let text = body.ok_or_else(|| "missing body".to_string())?;
    let n: usize = text.parse().map_err(|_| "non-numeric body".to_string())?;
    Ok(format!("{n}"))
}

pub fn parse_open(p: &mut Parser) -> Result<(), String> {
    p.expect(b'{')
}

pub struct Parser;

impl Parser {
    pub fn expect(&mut self, _b: u8) -> Result<(), String> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
