// Lint fixture (never compiled): reads the wall clock directly inside
// what the test presents as a deterministic module.
use std::time::Instant;

pub fn timed_step() -> u64 {
    let t = Instant::now();
    t.elapsed().as_nanos() as u64
}
