// Lint fixture (never compiled): timing routed through the PhaseClock
// shim, as the wall_clock rule requires. Mentioning Instant in this
// comment or in a "Instant::now()" string must not trip the rule.
use crate::util::timer::PhaseClock;

pub fn timed_step() -> u64 {
    let t = PhaseClock::start();
    let _label = "Instant::now()";
    t.elapsed_ns()
}
