// Lint fixture (never compiled): panicking extractors on a request
// path — a bad request would kill the worker instead of returning 4xx.

pub fn handle(body: Option<&str>) -> String {
    let text = body.unwrap();
    let n: usize = text.parse().expect("numeric body");
    format!("{n}")
}
