// Lint fixture (never compiled): ordered collections, plus a test-only
// HashSet that the cfg(test) mask must exempt.
use std::collections::BTreeMap;

pub fn count(xs: &[u32]) -> usize {
    let mut m = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0usize) += 1;
    }
    m.len()
}

#[cfg(test)]
mod tests {
    #[test]
    fn distinct() {
        let s: std::collections::HashSet<u32> = [1, 2, 2].into_iter().collect();
        assert_eq!(s.len(), 2);
    }
}
