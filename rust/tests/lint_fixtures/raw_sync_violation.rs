// Lint fixture (never compiled): raw std::sync primitives outside
// runtime/sync.rs — no lock ranking, no poison recovery.
use std::sync::{Condvar, Mutex, RwLock};

pub struct Queue {
    state: Mutex<Vec<u8>>,
    ready: Condvar,
    index: RwLock<u64>,
}
