// Lint fixture (never compiled): an unsafe block with no SAFETY
// justification anywhere near it.

pub fn as_bytes(xs: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}
