// Lint fixture (never compiled): the conforming reduction shapes — an
// ordered f64 accumulation, an integer sum, and a min/max fold (which
// is associative and commutative, so shard order cannot matter).

pub fn norm(xs: &[f32]) -> f64 {
    let mut total = 0.0f64;
    for &v in xs {
        total += (v as f64) * (v as f64);
    }
    total.sqrt()
}

pub fn count(xs: &[usize]) -> usize {
    xs.iter().sum()
}

pub fn lo(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::INFINITY, f32::min)
}
