//! End-to-end tests for the HTTP/JSON embedding service, using only
//! std's `TcpStream` as the client: bind an ephemeral port, create a
//! session over the wire, let the stepper advance it in the
//! background, change hyperparameters mid-run, fetch embeddings and
//! stats, and tear everything down.

use funcsne::server::json::{self, Json};
use funcsne::server::{Server, ServerConfig, ServerHandle};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// A server running on its own thread; shuts down (and joins) on drop.
struct TestServer {
    addr: SocketAddr,
    handle: ServerHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(max_sessions: usize) -> TestServer {
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            threads: 2,
            max_sessions,
            snapshot_every: 4,
        };
        let server = Server::bind(cfg).expect("bind ephemeral port");
        let addr = server.local_addr();
        let handle = server.handle();
        let join = std::thread::spawn(move || server.run().expect("server run"));
        TestServer { addr, handle, join: Some(join) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(join) = self.join.take() {
            join.join().expect("server thread");
        }
    }
}

/// One HTTP exchange on a fresh connection (`Connection: close`).
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("send request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> (u16, String) {
    let text = String::from_utf8_lossy(raw);
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    (status, body.to_string())
}

fn http_json(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let (status, body) = http(addr, method, path, body);
    let parsed = json::parse(&body).unwrap_or_else(|e| panic!("bad JSON {body:?}: {e}"));
    (status, parsed)
}

/// Deterministic pseudo-random rows: two displaced blobs, n × d.
fn rows_json(n: usize, d: usize) -> String {
    let mut state = 0x2545F4914F6CDD1Du64;
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let shift = if i % 2 == 0 { 0.0 } else { 4.0 };
        let mut row = Vec::with_capacity(d);
        for _ in 0..d {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let unit = ((state >> 33) as f64) / ((1u64 << 31) as f64); // [0, 1)
            row.push(format!("{:.4}", unit + shift));
        }
        rows.push(format!("[{}]", row.join(",")));
    }
    format!("[{}]", rows.join(","))
}

fn get_stats(addr: SocketAddr, id: u64) -> Json {
    let (status, v) = http_json(addr, "GET", &format!("/sessions/{id}/stats"), None);
    assert_eq!(status, 200, "stats failed: {v}");
    v
}

fn wait_until<F: FnMut() -> bool>(mut cond: F, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn http_round_trip_create_steer_fetch_delete() {
    let server = TestServer::start(8);
    let addr = server.addr;

    // --- create a session from inline rows ----------------------------
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 7}}",
        rows_json(60, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
    assert_eq!(created.get("n").and_then(Json::as_usize), Some(60));
    assert_eq!(created.get("ld_dim").and_then(Json::as_usize), Some(2));
    assert_eq!(created.get("alpha").and_then(Json::as_f64), Some(1.0));
    // The advertised resource url dereferences.
    let url = created.get("url").and_then(Json::as_str).expect("url").to_string();
    let (status, resource) = http_json(addr, "GET", &url, None);
    assert_eq!(status, 200, "GET {url} failed: {resource}");
    assert_eq!(resource.get("id").and_then(Json::as_usize), Some(id as usize));

    // --- the background stepper advances it with no further requests --
    wait_until(
        || get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap() >= 5,
        "background stepping",
    );

    // --- /healthz and /metrics respond while stepping ------------------
    let (status, health) = http_json(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(health.get("sessions").and_then(Json::as_usize), Some(1));
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("funcsne_sessions 1"), "{metrics}");
    assert!(metrics.contains("# TYPE funcsne_steps_total counter"), "{metrics}");
    assert!(metrics.contains(&format!("funcsne_session_iterations{{id=\"{id}\"}}")));
    assert!(
        metrics.contains(&format!("funcsne_phase_micros{{id=\"{id}\",phase=\"refine_ld\"}}")),
        "{metrics}"
    );

    // --- per-phase timing telemetry in the stats view ------------------
    let v = get_stats(addr, id);
    let phases = v.get("phase_micros").expect("stats must carry phase_micros");
    for key in ["refine_ld", "refine_hd", "recalibrate", "forces", "update"] {
        assert!(phases.get(key).is_some(), "phase_micros missing {key}: {phases}");
    }
    assert!(
        phases.get("refine_ld").and_then(Json::as_usize).unwrap() > 0,
        "refine_ld ran ≥5 iterations but reports zero µs: {phases}"
    );

    // --- mid-run hyperparameter change over the wire -------------------
    let (status, queued) = http_json(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"set_alpha\", \"value\": 0.5}"),
    );
    assert_eq!(status, 202, "command failed: {queued}");
    assert_eq!(queued.get("status").and_then(Json::as_str), Some("queued"));
    wait_until(
        || {
            let v = get_stats(addr, id);
            v.get("alpha").and_then(Json::as_f64) == Some(0.5)
                && v.get("commands_applied").and_then(Json::as_usize).unwrap() >= 1
        },
        "alpha change to drain between iterations",
    );

    // --- dynamic-dataset command: insert points mid-run ----------------
    let (status, _) = http_json(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"insert_points\", \"rows\": [[0.1,0.2,0.3,0.4],[4.1,4.2,4.3,4.4]]}"),
    );
    assert_eq!(status, 202);
    wait_until(
        || get_stats(addr, id).get("n").and_then(Json::as_usize) == Some(62),
        "insert to apply",
    );

    // --- live embedding reflects the grown dataset ---------------------
    let (status, frame) = http_json(addr, "GET", &format!("/sessions/{id}/embedding"), None);
    assert_eq!(status, 200, "embedding failed: {frame}");
    assert_eq!(frame.get("source").and_then(Json::as_str), Some("live"));
    assert_eq!(frame.get("n").and_then(Json::as_usize), Some(62));
    assert_eq!(frame.get("d").and_then(Json::as_usize), Some(2));
    let points = frame.get("points").and_then(Json::as_arr).expect("points");
    assert_eq!(points.len(), 62);
    assert_eq!(points[0].as_arr().unwrap().len(), 2);
    for p in points {
        for c in p.as_arr().unwrap() {
            assert!(c.as_f64().unwrap().is_finite());
        }
    }

    // --- snapshot lookup: nearest frame ≤ the requested iteration ------
    wait_until(
        || get_stats(addr, id).get("snapshots_total").and_then(Json::as_usize).unwrap() >= 2,
        "snapshots to record",
    );
    let (status, snap) =
        http_json(addr, "GET", &format!("/sessions/{id}/embedding?iter=999999"), None);
    assert_eq!(status, 200, "snapshot fetch failed: {snap}");
    assert_eq!(snap.get("source").and_then(Json::as_str), Some("snapshot"));
    let snap_iter = snap.get("iter").and_then(Json::as_usize).unwrap();
    assert_eq!(snap_iter % 4, 0, "snapshot_every=4 stride, got {snap_iter}");
    // A pre-history iteration has no snapshot at or before it.
    let (status, missing) =
        http_json(addr, "GET", &format!("/sessions/{id}/embedding?iter=1"), None);
    assert_eq!(status, 404, "unexpected: {missing}");

    // --- delete, then the session is gone ------------------------------
    let (status, deleted) = http_json(addr, "DELETE", &format!("/sessions/{id}"), None);
    assert_eq!(status, 200, "delete failed: {deleted}");
    let (status, _) = http_json(addr, "GET", &format!("/sessions/{id}/stats"), None);
    assert_eq!(status, 404);
    let (_, health) = http_json(addr, "GET", "/healthz", None);
    assert_eq!(health.get("sessions").and_then(Json::as_usize), Some(0));
}

#[test]
fn quality_probe_streams_through_stats_and_prometheus() {
    let server = TestServer::start(4);
    let addr = server.addr;

    // A session with the probe on (every 2 iterations, 16 anchors).
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 5, \"probe_every\": 2, \"probe_anchors\": 16}}",
        rows_json(60, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
    // Before the first probe iteration the field is null.
    assert!(
        created.get("quality").is_some(),
        "stats view must always carry a quality field: {created}"
    );

    // The background stepper produces a report within a few sweeps.
    wait_until(
        || get_stats(addr, id).get("quality").is_some_and(|q| q.get("iter").is_some()),
        "first probe report",
    );
    let v = get_stats(addr, id);
    let q = v.get("quality").expect("quality object");
    assert_eq!(q.get("anchors").and_then(Json::as_usize), Some(16));
    assert!(q.get("iter").and_then(Json::as_usize).unwrap() >= 2);
    for key in ["knn_recall", "trustworthiness", "continuity", "knn_recall_hd"] {
        let val = q
            .get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing {key} in {q}"));
        assert!(
            val.is_finite() && (0.0..=1.0).contains(&val),
            "{key} out of range: {val}"
        );
    }

    // The same numbers surface as per-session Prometheus gauges.
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    for name in [
        "funcsne_quality_recall",
        "funcsne_quality_trustworthiness",
        "funcsne_quality_continuity",
        "funcsne_knn_recall",
    ] {
        assert!(
            metrics.contains(&format!("# TYPE {name} gauge")),
            "missing TYPE line for {name}: {metrics}"
        );
        assert!(
            metrics.contains(&format!("{name}{{id=\"{id}\"}}")),
            "missing {name} gauge for session {id}: {metrics}"
        );
    }

    // Probe-less sessions coexist: no gauge lines for them, stats null.
    let spec2 = format!("{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5}}", rows_json(40, 3));
    let (status, other) = http_json(addr, "POST", "/sessions", Some(&spec2));
    assert_eq!(status, 201, "{other}");
    let oid = other.get("id").and_then(Json::as_usize).unwrap() as u64;
    let (_, metrics) = http(addr, "GET", "/metrics", None);
    assert!(
        !metrics.contains(&format!("funcsne_quality_recall{{id=\"{oid}\"}}")),
        "probe-less session must not export quality gauges: {metrics}"
    );
}

#[test]
fn keep_alive_serves_multiple_requests_per_connection() {
    let server = TestServer::start(8);
    let mut stream = TcpStream::connect(server.addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    for _ in 0..3 {
        stream.write_all(b"GET /healthz HTTP/1.1\r\nHost: funcsne\r\n\r\n").expect("send");
        let body = read_keep_alive_response(&mut stream);
        let v = json::parse(&body).expect("healthz JSON");
        assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
    }
}

/// Read exactly one `Content-Length`-framed keep-alive response.
fn read_keep_alive_response(stream: &mut TcpStream) -> String {
    let mut raw = Vec::new();
    let mut byte = [0u8; 1];
    // Headers end at the first CRLFCRLF.
    while !raw.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte).expect("read header byte");
        raw.push(byte[0]);
    }
    let head = String::from_utf8_lossy(&raw);
    assert!(head.contains("Connection: keep-alive"), "{head}");
    let len: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))
        .expect("Content-Length")
        .trim()
        .parse()
        .expect("length");
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).expect("read body");
    String::from_utf8(body).expect("utf8 body")
}

#[test]
fn session_capacity_and_error_handling() {
    let server = TestServer::start(1);
    let addr = server.addr;

    // Malformed JSON and unknown routes fail cleanly.
    let (status, err) = http_json(addr, "POST", "/sessions", Some("{not json"));
    assert_eq!(status, 400, "{err}");
    let (status, _) = http_json(addr, "GET", "/no/such/route", None);
    assert_eq!(status, 404);
    let (status, _) = http_json(addr, "PUT", "/sessions", None);
    assert_eq!(status, 405);
    let (status, _) = http_json(addr, "GET", "/sessions/999/stats", None);
    assert_eq!(status, 404);
    let (status, _) = http_json(addr, "GET", "/sessions/bogus/stats", None);
    assert_eq!(status, 400);

    // Unknown command names are rejected before touching the session.
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5, \"max_iters\": 3}}",
        rows_json(40, 3)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "{created}");
    let id = created.get("id").and_then(Json::as_usize).unwrap();
    let (status, err) = http_json(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"warp_speed\"}"),
    );
    assert_eq!(status, 400);
    assert!(err.get("error").and_then(Json::as_str).unwrap().contains("warp_speed"));

    // The capacity limit returns 429 without disturbing the live session.
    let spec2 = format!("{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5}}", rows_json(40, 3));
    let (status, err) = http_json(addr, "POST", "/sessions", Some(&spec2));
    assert_eq!(status, 429, "{err}");

    // The max_iters budget pauses the session by itself.
    wait_until(
        || {
            let v = get_stats(addr, id as u64);
            v.get("paused").and_then(Json::as_bool) == Some(true)
        },
        "max_iters budget pause",
    );
    let v = get_stats(addr, id as u64);
    assert_eq!(v.get("iter").and_then(Json::as_usize), Some(3));
}

#[test]
fn create_from_csv_path() {
    let server = TestServer::start(4);
    let addr = server.addr;

    // Write a small CSV (with header — the reader skips it).
    let mut path = std::env::temp_dir();
    path.push(format!("funcsne_server_test_{}.csv", std::process::id()));
    let mut text = String::from("x0,x1,x2\n");
    let mut state = 99u64;
    for i in 0..50 {
        let shift = if i % 2 == 0 { 0.0 } else { 5.0 };
        let mut cells = Vec::new();
        for _ in 0..3 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            cells.push(format!("{:.3}", ((state >> 33) as f64 / 2.0e9) + shift));
        }
        text.push_str(&cells.join(","));
        text.push('\n');
    }
    std::fs::write(&path, text).expect("write csv");

    let spec = format!(
        "{{\"path\": {:?}, \"k_hd\": 8, \"perplexity\": 5, \"seed\": 3}}",
        path.to_str().unwrap()
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "csv create failed: {created}");
    assert_eq!(created.get("n").and_then(Json::as_usize), Some(50));
    assert_eq!(created.get("hd_dim").and_then(Json::as_usize), Some(3));

    // A bad path is a clean 400, not a server failure.
    let (status, err) =
        http_json(addr, "POST", "/sessions", Some("{\"path\": \"/no/such/file.csv\"}"));
    assert_eq!(status, 400, "{err}");
    std::fs::remove_file(path).ok();
}
