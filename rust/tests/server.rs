//! End-to-end tests for the HTTP/JSON embedding service, using only
//! std's `TcpStream` as the client: bind an ephemeral port, create a
//! session over the wire, let the stepper advance it in the
//! background, change hyperparameters mid-run, fetch embeddings and
//! stats, and tear everything down.

use funcsne::obs::expo;
use funcsne::server::frames::{decode, FrameDecoder};
use funcsne::server::json::{self, Json};
use funcsne::server::{Server, ServerConfig, ServerHandle};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// A server running on its own thread; shuts down (and joins) on drop.
struct TestServer {
    addr: SocketAddr,
    handle: ServerHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(max_sessions: usize) -> TestServer {
        Self::start_cfg(ServerConfig {
            threads: 2,
            max_sessions,
            ..Self::base_cfg()
        })
    }

    /// Defaults shared by every test server: ephemeral port, fast
    /// snapshot stride so history assertions don't wait long, and
    /// observability pinned off regardless of the ambient
    /// `FUNCSNE_TRACE` env (the dedicated e2e turns it on explicitly).
    fn base_cfg() -> ServerConfig {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            snapshot_every: 4,
            trace: false,
            ..ServerConfig::default()
        }
    }

    fn start_cfg(cfg: ServerConfig) -> TestServer {
        let server = Server::bind(cfg).expect("bind ephemeral port");
        let addr = server.local_addr();
        let handle = server.handle();
        let join = std::thread::spawn(move || server.run().expect("server run"));
        TestServer { addr, handle, join: Some(join) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(join) = self.join.take() {
            join.join().expect("server thread");
        }
    }
}

/// One HTTP exchange on a fresh connection (`Connection: close`).
fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("send request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    parse_response(&raw)
}

fn parse_response(raw: &[u8]) -> (u16, String) {
    let text = String::from_utf8_lossy(raw);
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    (status, body.to_string())
}

fn http_json(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let (status, body) = http(addr, method, path, body);
    let parsed = json::parse(&body).unwrap_or_else(|e| panic!("bad JSON {body:?}: {e}"));
    (status, parsed)
}

/// Deterministic pseudo-random rows: two displaced blobs, n × d.
fn rows_json(n: usize, d: usize) -> String {
    let mut state = 0x2545F4914F6CDD1Du64;
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let shift = if i % 2 == 0 { 0.0 } else { 4.0 };
        let mut row = Vec::with_capacity(d);
        for _ in 0..d {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let unit = ((state >> 33) as f64) / ((1u64 << 31) as f64); // [0, 1)
            row.push(format!("{:.4}", unit + shift));
        }
        rows.push(format!("[{}]", row.join(",")));
    }
    format!("[{}]", rows.join(","))
}

fn get_stats(addr: SocketAddr, id: u64) -> Json {
    let (status, v) = http_json(addr, "GET", &format!("/sessions/{id}/stats"), None);
    assert_eq!(status, 200, "stats failed: {v}");
    v
}

fn wait_until<F: FnMut() -> bool>(mut cond: F, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn http_round_trip_create_steer_fetch_delete() {
    let server = TestServer::start(8);
    let addr = server.addr;

    // --- create a session from inline rows ----------------------------
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 7}}",
        rows_json(60, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
    assert_eq!(created.get("n").and_then(Json::as_usize), Some(60));
    assert_eq!(created.get("ld_dim").and_then(Json::as_usize), Some(2));
    assert_eq!(created.get("alpha").and_then(Json::as_f64), Some(1.0));
    // The advertised resource url dereferences.
    let url = created.get("url").and_then(Json::as_str).expect("url").to_string();
    let (status, resource) = http_json(addr, "GET", &url, None);
    assert_eq!(status, 200, "GET {url} failed: {resource}");
    assert_eq!(resource.get("id").and_then(Json::as_usize), Some(id as usize));

    // --- the background stepper advances it with no further requests --
    wait_until(
        || get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap() >= 5,
        "background stepping",
    );

    // --- /healthz and /metrics respond while stepping ------------------
    let (status, health) = http_json(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(health.get("sessions").and_then(Json::as_usize), Some(1));
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metrics.contains("funcsne_sessions 1"), "{metrics}");
    assert!(metrics.contains("# TYPE funcsne_steps_total counter"), "{metrics}");
    assert!(metrics.contains(&format!("funcsne_session_iterations{{id=\"{id}\"}}")));
    assert!(
        metrics.contains(&format!("funcsne_phase_micros{{id=\"{id}\",phase=\"refine_ld\"}}")),
        "{metrics}"
    );
    // Per-session lifecycle gauge: one stepping session, state running.
    assert!(
        metrics.contains(&format!("funcsne_session_state{{id=\"{id}\",state=\"running\"}} 1")),
        "{metrics}"
    );
    // The whole exposition stays machine-valid (labels escaped, HELP/
    // TYPE before samples, histograms complete) even with obs off.
    expo::check_exposition(&metrics)
        .unwrap_or_else(|errs| panic!("invalid exposition: {errs:?}\n{metrics}"));

    // --- per-phase timing telemetry in the stats view ------------------
    let v = get_stats(addr, id);
    let phases = v.get("phase_micros").expect("stats must carry phase_micros");
    for key in ["refine_ld", "refine_hd", "recalibrate", "forces", "update"] {
        assert!(phases.get(key).is_some(), "phase_micros missing {key}: {phases}");
    }
    assert!(
        phases.get("refine_ld").and_then(Json::as_usize).unwrap() > 0,
        "refine_ld ran ≥5 iterations but reports zero µs: {phases}"
    );

    // --- mid-run hyperparameter change over the wire -------------------
    let (status, queued) = http_json(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"set_alpha\", \"value\": 0.5}"),
    );
    assert_eq!(status, 202, "command failed: {queued}");
    assert_eq!(queued.get("status").and_then(Json::as_str), Some("queued"));
    wait_until(
        || {
            let v = get_stats(addr, id);
            v.get("alpha").and_then(Json::as_f64) == Some(0.5)
                && v.get("commands_applied").and_then(Json::as_usize).unwrap() >= 1
        },
        "alpha change to drain between iterations",
    );

    // --- dynamic-dataset command: insert points mid-run ----------------
    let (status, _) = http_json(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"insert_points\", \"rows\": [[0.1,0.2,0.3,0.4],[4.1,4.2,4.3,4.4]]}"),
    );
    assert_eq!(status, 202);
    wait_until(
        || get_stats(addr, id).get("n").and_then(Json::as_usize) == Some(62),
        "insert to apply",
    );

    // --- live embedding reflects the grown dataset ---------------------
    let (status, frame) = http_json(addr, "GET", &format!("/sessions/{id}/embedding"), None);
    assert_eq!(status, 200, "embedding failed: {frame}");
    assert_eq!(frame.get("source").and_then(Json::as_str), Some("live"));
    assert_eq!(frame.get("n").and_then(Json::as_usize), Some(62));
    assert_eq!(frame.get("d").and_then(Json::as_usize), Some(2));
    let points = frame.get("points").and_then(Json::as_arr).expect("points");
    assert_eq!(points.len(), 62);
    assert_eq!(points[0].as_arr().unwrap().len(), 2);
    for p in points {
        for c in p.as_arr().unwrap() {
            assert!(c.as_f64().unwrap().is_finite());
        }
    }

    // --- snapshot lookup: nearest frame ≤ the requested iteration ------
    wait_until(
        || get_stats(addr, id).get("snapshots_total").and_then(Json::as_usize).unwrap() >= 2,
        "snapshots to record",
    );
    let (status, snap) =
        http_json(addr, "GET", &format!("/sessions/{id}/embedding?iter=999999"), None);
    assert_eq!(status, 200, "snapshot fetch failed: {snap}");
    assert_eq!(snap.get("source").and_then(Json::as_str), Some("snapshot"));
    let snap_iter = snap.get("iter").and_then(Json::as_usize).unwrap();
    assert_eq!(snap_iter % 4, 0, "snapshot_every=4 stride, got {snap_iter}");
    // A pre-history iteration has no snapshot at or before it.
    let (status, missing) =
        http_json(addr, "GET", &format!("/sessions/{id}/embedding?iter=1"), None);
    assert_eq!(status, 404, "unexpected: {missing}");

    // --- delete, then the session is gone ------------------------------
    let (status, deleted) = http_json(addr, "DELETE", &format!("/sessions/{id}"), None);
    assert_eq!(status, 200, "delete failed: {deleted}");
    let (status, _) = http_json(addr, "GET", &format!("/sessions/{id}/stats"), None);
    assert_eq!(status, 404);
    let (_, health) = http_json(addr, "GET", "/healthz", None);
    assert_eq!(health.get("sessions").and_then(Json::as_usize), Some(0));
}

#[test]
fn quality_probe_streams_through_stats_and_prometheus() {
    let server = TestServer::start(4);
    let addr = server.addr;

    // A session with the probe on (every 2 iterations, 16 anchors).
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 5, \"probe_every\": 2, \"probe_anchors\": 16}}",
        rows_json(60, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
    // Before the first probe iteration the field is null.
    assert!(
        created.get("quality").is_some(),
        "stats view must always carry a quality field: {created}"
    );

    // The background stepper produces a report within a few sweeps.
    wait_until(
        || get_stats(addr, id).get("quality").is_some_and(|q| q.get("iter").is_some()),
        "first probe report",
    );
    let v = get_stats(addr, id);
    let q = v.get("quality").expect("quality object");
    assert_eq!(q.get("anchors").and_then(Json::as_usize), Some(16));
    assert!(q.get("iter").and_then(Json::as_usize).unwrap() >= 2);
    for key in ["knn_recall", "trustworthiness", "continuity", "knn_recall_hd"] {
        let val = q
            .get(key)
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing {key} in {q}"));
        assert!(
            val.is_finite() && (0.0..=1.0).contains(&val),
            "{key} out of range: {val}"
        );
    }

    // The same numbers surface as per-session Prometheus gauges.
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    for name in [
        "funcsne_quality_recall",
        "funcsne_quality_trustworthiness",
        "funcsne_quality_continuity",
        "funcsne_knn_recall",
    ] {
        assert!(
            metrics.contains(&format!("# TYPE {name} gauge")),
            "missing TYPE line for {name}: {metrics}"
        );
        assert!(
            metrics.contains(&format!("{name}{{id=\"{id}\"}}")),
            "missing {name} gauge for session {id}: {metrics}"
        );
    }

    // Probe-less sessions coexist: no gauge lines for them, stats null.
    let spec2 = format!("{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5}}", rows_json(40, 3));
    let (status, other) = http_json(addr, "POST", "/sessions", Some(&spec2));
    assert_eq!(status, 201, "{other}");
    let oid = other.get("id").and_then(Json::as_usize).unwrap() as u64;
    let (_, metrics) = http(addr, "GET", "/metrics", None);
    assert!(
        !metrics.contains(&format!("funcsne_quality_recall{{id=\"{oid}\"}}")),
        "probe-less session must not export quality gauges: {metrics}"
    );
}

#[test]
fn keep_alive_serves_multiple_requests_per_connection() {
    let server = TestServer::start(8);
    let mut stream = TcpStream::connect(server.addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    for _ in 0..3 {
        stream.write_all(b"GET /healthz HTTP/1.1\r\nHost: funcsne\r\n\r\n").expect("send");
        let body = read_keep_alive_response(&mut stream);
        let v = json::parse(&body).expect("healthz JSON");
        assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
    }
}

/// Read exactly one `Content-Length`-framed keep-alive response.
fn read_keep_alive_response(stream: &mut TcpStream) -> String {
    let mut raw = Vec::new();
    let mut byte = [0u8; 1];
    // Headers end at the first CRLFCRLF.
    while !raw.ends_with(b"\r\n\r\n") {
        stream.read_exact(&mut byte).expect("read header byte");
        raw.push(byte[0]);
    }
    let head = String::from_utf8_lossy(&raw);
    assert!(head.contains("Connection: keep-alive"), "{head}");
    let len: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))
        .expect("Content-Length")
        .trim()
        .parse()
        .expect("length");
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).expect("read body");
    String::from_utf8(body).expect("utf8 body")
}

#[test]
fn session_capacity_and_error_handling() {
    let server = TestServer::start(1);
    let addr = server.addr;

    // Malformed JSON and unknown routes fail cleanly.
    let (status, err) = http_json(addr, "POST", "/sessions", Some("{not json"));
    assert_eq!(status, 400, "{err}");
    let (status, _) = http_json(addr, "GET", "/no/such/route", None);
    assert_eq!(status, 404);
    let (status, _) = http_json(addr, "PUT", "/sessions", None);
    assert_eq!(status, 405);
    let (status, _) = http_json(addr, "GET", "/sessions/999/stats", None);
    assert_eq!(status, 404);
    let (status, _) = http_json(addr, "GET", "/sessions/bogus/stats", None);
    assert_eq!(status, 400);

    // Unknown command names are rejected before touching the session.
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5, \"max_iters\": 3}}",
        rows_json(40, 3)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "{created}");
    let id = created.get("id").and_then(Json::as_usize).unwrap();
    let (status, err) = http_json(
        addr,
        "POST",
        &format!("/sessions/{id}/commands"),
        Some("{\"command\": \"warp_speed\"}"),
    );
    assert_eq!(status, 400);
    assert!(err.get("error").and_then(Json::as_str).unwrap().contains("warp_speed"));

    // The capacity limit returns 429 without disturbing the live session.
    let spec2 = format!("{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5}}", rows_json(40, 3));
    let (status, err) = http_json(addr, "POST", "/sessions", Some(&spec2));
    assert_eq!(status, 429, "{err}");

    // The max_iters budget pauses the session by itself.
    wait_until(
        || {
            let v = get_stats(addr, id as u64);
            v.get("paused").and_then(Json::as_bool) == Some(true)
        },
        "max_iters budget pause",
    );
    let v = get_stats(addr, id as u64);
    assert_eq!(v.get("iter").and_then(Json::as_usize), Some(3));

    // The lifecycle gauge follows the session into the paused state.
    let (_, metrics) = http(addr, "GET", "/metrics", None);
    assert!(
        metrics.contains(&format!("funcsne_session_state{{id=\"{id}\",state=\"paused\"}} 1")),
        "{metrics}"
    );
}

/// One HTTP exchange with extra request headers; returns the raw
/// header block alongside the status and body so callers can inspect
/// response headers (ETag, Content-Type, ...).
fn http_with_headers(
    addr: SocketAddr,
    method: &str,
    path: &str,
    extra: &[(&str, &str)],
) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(30))).expect("read timeout");
    let mut req = format!("{method} {path} HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n");
    for (name, value) in extra {
        req.push_str(&format!("{name}: {value}\r\n"));
    }
    req.push_str("Content-Length: 0\r\n\r\n");
    stream.write_all(req.as_bytes()).expect("send request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8_lossy(&raw);
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    let status: u16 =
        head.split_whitespace().nth(1).expect("status code").parse().expect("numeric status");
    (status, head.to_string(), body.to_string())
}

/// A chunked-transfer binary frame stream from `GET /sessions/:id/stream`.
/// The server writes exactly one frame per HTTP chunk, so reading one
/// chunk yields one codec frame.
struct FrameStream {
    stream: TcpStream,
}

impl FrameStream {
    fn open(addr: SocketAddr, id: u64) -> FrameStream {
        match Self::try_open(addr, id) {
            (200, Some(fs)) => fs,
            (status, _) => panic!("stream subscribe failed with status {status}"),
        }
    }

    fn try_open(addr: SocketAddr, id: u64) -> (u16, Option<FrameStream>) {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(60))).expect("read timeout");
        let req = format!(
            "GET /sessions/{id}/stream HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\r\n"
        );
        stream.write_all(req.as_bytes()).expect("send request");
        let mut raw = Vec::new();
        let mut byte = [0u8; 1];
        while !raw.ends_with(b"\r\n\r\n") {
            stream.read_exact(&mut byte).expect("read header byte");
            raw.push(byte[0]);
        }
        let head = String::from_utf8_lossy(&raw);
        let status: u16 =
            head.split_whitespace().nth(1).expect("status code").parse().expect("numeric status");
        if status != 200 {
            // Error replies are ordinary Content-Length responses.
            let mut rest = Vec::new();
            stream.read_to_end(&mut rest).ok();
            return (status, None);
        }
        assert!(head.contains("Transfer-Encoding: chunked"), "{head}");
        assert!(head.contains("Content-Type: application/octet-stream"), "{head}");
        (200, Some(FrameStream { stream }))
    }

    /// Read one chunk (= one frame); `None` at the terminating
    /// zero-length chunk (stream closed by the server).
    fn next_frame(&mut self) -> Option<Vec<u8>> {
        let mut line = Vec::new();
        let mut byte = [0u8; 1];
        while !line.ends_with(b"\r\n") {
            self.stream.read_exact(&mut byte).expect("read chunk-size byte");
            line.push(byte[0]);
        }
        let text = String::from_utf8_lossy(&line);
        let len = usize::from_str_radix(text.trim(), 16).expect("hex chunk size");
        let mut payload = vec![0u8; len + 2]; // chunk body + trailing CRLF
        self.stream.read_exact(&mut payload).expect("read chunk body");
        assert_eq!(&payload[len..], b"\r\n", "chunk must end with CRLF");
        payload.truncate(len);
        if len == 0 {
            None
        } else {
            Some(payload)
        }
    }

    fn collect(&mut self, n: usize) -> Vec<Vec<u8>> {
        let mut frames = Vec::with_capacity(n);
        while frames.len() < n {
            match self.next_frame() {
                Some(f) => frames.push(f),
                None => break,
            }
        }
        frames
    }
}

/// Extract the value of an unlabelled Prometheus sample line.
fn metric_value(metrics: &str, name: &str) -> f64 {
    metrics
        .lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse::<f64>().ok()))
        .unwrap_or_else(|| panic!("metric {name} not found in:\n{metrics}"))
}

#[test]
fn stream_two_subscribers_receive_identical_frames() {
    // Two pinned stream workers + free slots for JSON polling.
    let server = TestServer::start_cfg(ServerConfig {
        threads: 4,
        max_sessions: 4,
        stream_queue: 64,
        ..TestServer::base_cfg()
    });
    let addr = server.addr;

    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 11}}",
        rows_json(60, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;

    // Subscribe A, then B; drain both concurrently so neither lags.
    // The streams are returned from the reader threads and kept open
    // so the subscriber gauge below still sees both clients.
    let mut sub_a = FrameStream::open(addr, id);
    let mut sub_b = FrameStream::open(addr, id);
    let reader_a = std::thread::spawn(move || {
        let frames = sub_a.collect(12);
        (sub_a, frames)
    });
    let reader_b = std::thread::spawn(move || {
        let frames = sub_b.collect(8);
        (sub_b, frames)
    });
    let (_keep_a, a_frames) = reader_a.join().expect("reader A");
    let (_keep_b, b_frames) = reader_b.join().expect("reader B");
    assert_eq!(a_frames.len(), 12);
    assert_eq!(b_frames.len(), 8);

    // B's first frame is a keyframe (forced on subscribe) that A also
    // received; from that point the byte sequences are identical.
    let first_b = decode(&b_frames[0]).expect("decode B's first frame");
    assert!(first_b.keyframe, "a new subscriber must start on a keyframe");
    assert_eq!(first_b.n, 60);
    assert_eq!(first_b.d, 2);
    let start = a_frames
        .iter()
        .rposition(|f| f == &b_frames[0])
        .expect("B's first keyframe must appear in A's stream");
    let overlap = (a_frames.len() - start).min(b_frames.len());
    assert!(overlap >= 3, "need overlapping frames to compare, got {overlap}");
    for i in 0..overlap {
        assert_eq!(a_frames[start + i], b_frames[i], "frame {i} after resync diverged");
    }

    // Every frame in each stream decodes and chains cleanly.
    let mut dec = FrameDecoder::new();
    for f in &b_frames {
        let frame = decode(f).expect("decode frame");
        dec.apply(&frame).expect("frames chain from the initial keyframe");
    }
    assert!(dec.ready());
    assert_eq!(dec.n(), 60);
    assert!(dec.coords().iter().all(|c| c.is_finite()));

    // Streaming observability: both subscribers and traffic visible.
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(metric_value(&metrics, "funcsne_stream_subscribers ") >= 2.0, "{metrics}");
    assert!(metric_value(&metrics, "funcsne_frames_sent_total ") > 0.0, "{metrics}");
    assert!(
        metrics.contains(&format!("funcsne_stream_session_subscribers{{id=\"{id}\"}}")),
        "{metrics}"
    );
    assert!(metrics.contains(&format!("funcsne_step_budget{{id=\"{id}\"}}")), "{metrics}");
}

#[test]
fn stream_stalled_subscriber_drops_frames_and_resyncs() {
    // A tiny per-subscriber queue so a stalled client overflows fast.
    let server = TestServer::start_cfg(ServerConfig {
        threads: 3,
        max_sessions: 4,
        stream_queue: 2,
        keyframe_every: 5,
        ..TestServer::base_cfg()
    });
    let addr = server.addr;

    // Enough points that frames (~8 KB keyframes) fill the OS socket
    // buffers quickly once the client stops reading.
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 13}}",
        rows_json(2000, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;

    // Subscribe but never read: the worker stalls once the socket
    // buffer fills, the bounded queue overflows, frames get dropped.
    let mut stalled = FrameStream::open(addr, id);
    wait_until(
        || {
            let (_, metrics) = http(addr, "GET", "/metrics", None);
            metric_value(&metrics, "funcsne_frames_dropped_total ") > 0.0
        },
        "stalled subscriber to overflow its queue",
    );

    // The optimisation is unaffected by the stalled client.
    let before = get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap();
    wait_until(
        || get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap() > before,
        "stepping to continue despite a stalled subscriber",
    );

    // Resume reading: within a bounded number of frames a keyframe
    // arrives (lag forces keyframes) and decodes standalone.
    let mut resynced = false;
    for _ in 0..20_000 {
        let Some(bytes) = stalled.next_frame() else { break };
        let frame = decode(&bytes).expect("every delivered frame is well-formed");
        if frame.keyframe {
            let mut dec = FrameDecoder::new();
            dec.apply(&frame).expect("keyframe decodes standalone");
            assert_eq!(dec.n(), 2000);
            resynced = true;
            break;
        }
    }
    assert!(resynced, "no keyframe arrived after queue overflow");
}

#[test]
fn stream_admission_control_limits_subscribers() {
    let server = TestServer::start_cfg(ServerConfig {
        threads: 3,
        max_sessions: 4,
        max_streams_per_session: 1,
        ..TestServer::base_cfg()
    });
    let addr = server.addr;

    let spec =
        format!("{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5}}", rows_json(40, 3));
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "{created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;

    // Unknown sessions are a 404, not an admission failure.
    let (status, none) = FrameStream::try_open(addr, 999);
    assert_eq!(status, 404);
    assert!(none.is_none());

    let _first = FrameStream::open(addr, id);
    let (status, none) = FrameStream::try_open(addr, id);
    assert_eq!(status, 429, "second subscriber must hit the per-session cap");
    assert!(none.is_none());
}

#[test]
fn stream_etag_revalidation_returns_304() {
    let server = TestServer::start(4);
    let addr = server.addr;

    // A session that pauses itself so the embedding stops changing.
    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 8, \"perplexity\": 5, \"max_iters\": 3}}",
        rows_json(40, 3)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "{created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
    wait_until(
        || get_stats(addr, id).get("paused").and_then(Json::as_bool) == Some(true),
        "max_iters pause",
    );

    let path = format!("/sessions/{id}/embedding");
    let (status, head, body) = http_with_headers(addr, "GET", &path, &[]);
    assert_eq!(status, 200, "{body}");
    let etag = head
        .lines()
        .find_map(|l| l.strip_prefix("ETag: "))
        .expect("embedding response must carry an ETag")
        .trim()
        .to_string();
    assert!(etag.starts_with('"') && etag.ends_with('"'), "strong quoted ETag: {etag}");

    // Same iteration, matching validator: 304 with an empty body.
    let (status, head, body) =
        http_with_headers(addr, "GET", &path, &[("If-None-Match", &etag)]);
    assert_eq!(status, 304, "{head}");
    assert!(body.is_empty(), "304 must not carry a body: {body:?}");
    assert!(head.contains(&format!("ETag: {etag}")), "304 repeats the validator: {head}");

    // Weak-compare and list forms also match.
    let weak = format!("W/{etag}");
    let (status, _, _) = http_with_headers(addr, "GET", &path, &[("If-None-Match", &weak)]);
    assert_eq!(status, 304);
    let list = format!("\"nope\", {etag}");
    let (status, _, _) = http_with_headers(addr, "GET", &path, &[("If-None-Match", &list)]);
    assert_eq!(status, 304);
    let (status, _, _) = http_with_headers(addr, "GET", &path, &[("If-None-Match", "*")]);
    assert_eq!(status, 304);

    // A stale validator misses and the body comes back.
    let (status, _, body) =
        http_with_headers(addr, "GET", &path, &[("If-None-Match", "\"stale\"")]);
    assert_eq!(status, 200);
    assert!(!body.is_empty());
}

#[test]
fn create_from_csv_path() {
    let server = TestServer::start(4);
    let addr = server.addr;

    // Write a small CSV (with header — the reader skips it).
    let mut path = std::env::temp_dir();
    path.push(format!("funcsne_server_test_{}.csv", std::process::id()));
    let mut text = String::from("x0,x1,x2\n");
    let mut state = 99u64;
    for i in 0..50 {
        let shift = if i % 2 == 0 { 0.0 } else { 5.0 };
        let mut cells = Vec::new();
        for _ in 0..3 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            cells.push(format!("{:.3}", ((state >> 33) as f64 / 2.0e9) + shift));
        }
        text.push_str(&cells.join(","));
        text.push('\n');
    }
    std::fs::write(&path, text).expect("write csv");

    let spec = format!(
        "{{\"path\": {:?}, \"k_hd\": 8, \"perplexity\": 5, \"seed\": 3}}",
        path.to_str().unwrap()
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "csv create failed: {created}");
    assert_eq!(created.get("n").and_then(Json::as_usize), Some(50));
    assert_eq!(created.get("hd_dim").and_then(Json::as_usize), Some(3));

    // A bad path is a clean 400, not a server failure.
    let (status, err) =
        http_json(addr, "POST", "/sessions", Some("{\"path\": \"/no/such/file.csv\"}"));
    assert_eq!(status, 400, "{err}");
    std::fs::remove_file(path).ok();
}

/// `(start, end)` of a Chrome `"ph":"X"` complete event, µs.
fn span(e: &Json) -> (f64, f64) {
    let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
    let dur = e.get("dur").and_then(Json::as_f64).expect("dur");
    (ts, ts + dur)
}

/// Events with the given `name` field.
fn by_name<'a>(events: &'a [Json], name: &str) -> Vec<&'a Json> {
    events.iter().filter(|e| e.get("name").and_then(Json::as_str) == Some(name)).collect()
}

/// A numeric tag from an event's `args` object.
fn arg(e: &Json, key: &str) -> Option<usize> {
    e.get("args").and_then(|a| a.get(key)).and_then(Json::as_usize)
}

#[test]
fn observability_histograms_quantiles_and_trace() {
    let server = TestServer::start_cfg(ServerConfig {
        threads: 2,
        max_sessions: 4,
        trace: true,
        ..TestServer::base_cfg()
    });
    let addr = server.addr;

    let spec = format!(
        "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
          \"jumpstart_iters\": 2, \"seed\": 19}}",
        rows_json(60, 4)
    );
    let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
    assert_eq!(status, 201, "create failed: {created}");
    let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
    wait_until(
        || get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap() >= 5,
        "background stepping",
    );

    // --- stats JSON: per-phase latency quantiles -----------------------
    let v = get_stats(addr, id);
    let latency = v.get("latency").expect("stats must carry latency");
    for phase in ["step", "refine_ld", "refine_hd", "recalibrate", "forces", "update"] {
        let q = latency
            .get(phase)
            .unwrap_or_else(|| panic!("latency missing {phase}: {latency}"));
        assert!(q.get("samples").and_then(Json::as_usize).unwrap() >= 5, "{q}");
        let p50 = q.get("p50_us").and_then(Json::as_f64).unwrap();
        let p95 = q.get("p95_us").and_then(Json::as_f64).unwrap();
        let p99 = q.get("p99_us").and_then(Json::as_f64).unwrap();
        assert!(p50.is_finite() && p50 >= 0.0, "{phase}: p50 {p50}");
        assert!(p50 <= p95 && p95 <= p99, "{phase}: {p50} {p95} {p99}");
    }

    // --- /metrics: histogram families, +Inf buckets, valid exposition --
    let (status, metrics) = http(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    expo::check_exposition(&metrics)
        .unwrap_or_else(|errs| panic!("invalid exposition: {errs:?}\n{metrics}"));
    for fam in [
        "funcsne_step_micros",
        "funcsne_step_phase_micros",
        "funcsne_sweep_micros",
        "funcsne_http_request_micros",
    ] {
        assert!(
            metrics.contains(&format!("# TYPE {fam} histogram")),
            "missing histogram TYPE for {fam}:\n{metrics}"
        );
        assert!(
            metrics
                .lines()
                .any(|l| l.starts_with(&format!("{fam}_bucket{{")) && l.contains("le=\"+Inf\"")),
            "missing +Inf bucket for {fam}:\n{metrics}"
        );
        assert!(metrics.contains(&format!("{fam}_sum")), "missing {fam}_sum:\n{metrics}");
        assert!(metrics.contains(&format!("{fam}_count")), "missing {fam}_count:\n{metrics}");
    }
    assert!(
        metrics.contains("funcsne_http_request_micros_bucket{route=\"GET /sessions/:id/stats\""),
        "per-route labels missing:\n{metrics}"
    );

    // --- /debug/trace: Chrome trace JSON with nested spans -------------
    let (status, body) = http(addr, "GET", "/debug/trace", None);
    assert_eq!(status, 200, "{body}");
    let doc = json::parse(&body).unwrap_or_else(|e| panic!("trace must parse: {e}\n{body}"));
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let other = doc.get("otherData").expect("otherData");
    assert_eq!(other.get("enabled").and_then(Json::as_bool), Some(true));
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    assert!(!events.is_empty(), "no trace events after 5+ iterations");

    let steps = by_name(events, "session_step");
    let sweeps = by_name(events, "sweep");
    let https = by_name(events, "http");
    assert!(!steps.is_empty(), "no session_step spans");
    assert!(!sweeps.is_empty(), "no sweep spans");
    assert!(!https.is_empty(), "no http spans");

    // A session_step nests inside the sweep span of the same number.
    let nested = steps.iter().any(|step| {
        sweeps.iter().any(|sw| {
            arg(sw, "sweep") == arg(step, "sweep")
                && span(sw).0 <= span(step).0
                && span(step).1 <= span(sw).1
        })
    });
    assert!(nested, "no session_step contained in its sweep");

    // An engine phase span nests inside a session_step of its sweep.
    let phase_nested = events
        .iter()
        .filter(|e| e.get("cat").and_then(Json::as_str) == Some("engine"))
        .any(|ph| {
            steps.iter().any(|step| {
                arg(step, "sweep") == arg(ph, "sweep")
                    && arg(step, "session") == arg(ph, "session")
                    && span(step).0 <= span(ph).0
                    && span(ph).1 <= span(step).1
            })
        });
    assert!(phase_nested, "no engine phase span inside a session_step");

    // HTTP spans carry request ids and the session where the path has one.
    assert!(https.iter().any(|e| arg(e, "request").is_some()));
    assert!(
        https.iter().any(|e| arg(e, "session") == Some(id as usize)),
        "no http span tagged with session {id}"
    );
}

/// Durable mode end to end: a server with a state dir checkpoints on
/// demand, persists everything at graceful shutdown, restores the
/// session at the next boot (same id, iterations preserved), and
/// `DELETE` scrubs the state files from disk.
#[test]
fn durable_server_survives_restart_and_delete_scrubs_state() {
    let state_dir =
        std::env::temp_dir().join(format!("funcsne_server_durable_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&state_dir);
    let durable_cfg = || ServerConfig {
        threads: 2,
        max_sessions: 4,
        state_dir: Some(state_dir.clone()),
        // Cadence checkpoints off: this test drives the explicit
        // endpoint and the shutdown path only.
        checkpoint_every: 1_000_000,
        ..TestServer::base_cfg()
    };

    // --- first life: create, checkpoint explicitly, steer, shut down --
    let (id, iter_before) = {
        let server = TestServer::start_cfg(durable_cfg());
        let addr = server.addr;
        let spec = format!(
            "{{\"rows\": {}, \"k_hd\": 10, \"k_ld\": 6, \"perplexity\": 6, \
              \"jumpstart_iters\": 2, \"seed\": 21}}",
            rows_json(60, 4)
        );
        let (status, created) = http_json(addr, "POST", "/sessions", Some(&spec));
        assert_eq!(status, 201, "create failed: {created}");
        let id = created.get("id").and_then(Json::as_usize).expect("id") as u64;
        wait_until(
            || get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap() >= 5,
            "background stepping before checkpoint",
        );

        let (status, ck) =
            http_json(addr, "POST", &format!("/sessions/{id}/checkpoint"), None);
        assert_eq!(status, 200, "checkpoint failed: {ck}");
        assert_eq!(ck.get("status").and_then(Json::as_str), Some("checkpointed"));
        assert!(ck.get("bytes").and_then(Json::as_usize).unwrap() > 0);
        assert!(state_dir.join(format!("session-{id}.snap")).exists());
        assert!(state_dir.join(format!("session-{id}.wal")).exists());

        // A steer after the checkpoint: it must survive the restart
        // via either the WAL tail or the shutdown checkpoint.
        let (status, _) = http_json(
            addr,
            "POST",
            &format!("/sessions/{id}/commands"),
            Some("{\"command\":\"set_alpha\",\"value\":0.5}"),
        );
        assert_eq!(status, 202);
        // Wait for the drain: only an *applied* command is in the WAL
        // (write-ahead happens at drain time, right before apply).
        wait_until(
            || get_stats(addr, id).get("alpha").and_then(Json::as_f64) == Some(0.5),
            "set_alpha draining into the log",
        );
        let iter_before = get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap();
        (id, iter_before)
        // Drop: graceful shutdown checkpoints every live session.
    };

    // --- second life: same state dir, the session is just *there* -----
    {
        let server = TestServer::start_cfg(durable_cfg());
        let addr = server.addr;
        let v = get_stats(addr, id);
        let restored_iter = v.get("iter").and_then(Json::as_usize).unwrap();
        assert!(
            restored_iter >= iter_before,
            "restored at iteration {restored_iter}, but {iter_before} was \
             already reached before shutdown"
        );
        assert_eq!(
            v.get("alpha").and_then(Json::as_f64),
            Some(0.5),
            "post-checkpoint steer lost across restart: {v}"
        );
        let (status, metrics) = http(addr, "GET", "/metrics", None);
        assert_eq!(status, 200);
        assert!(metrics.contains("funcsne_restored_sessions 1"), "{metrics}");
        // The restored session keeps stepping without any prompting.
        wait_until(
            || {
                get_stats(addr, id).get("iter").and_then(Json::as_usize).unwrap()
                    > restored_iter
            },
            "restored session resuming",
        );

        // --- delete scrubs the durable artifacts from disk ------------
        let (status, _) = http_json(addr, "DELETE", &format!("/sessions/{id}"), None);
        assert_eq!(status, 200);
        assert!(!state_dir.join(format!("session-{id}.snap")).exists());
        assert!(!state_dir.join(format!("session-{id}.wal")).exists());
    }

    // --- third life: nothing to restore after the delete --------------
    {
        let server = TestServer::start_cfg(durable_cfg());
        let (status, _) = http_json(server.addr, "GET", &format!("/sessions/{id}/stats"), None);
        assert_eq!(status, 404, "deleted session must not resurrect at boot");
    }
    let _ = std::fs::remove_dir_all(&state_dir);
}
