//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. the LD-neighbour close-range repulsion term (Eq. 6 term 2) —
//!     the paper's key approximation: sweep k_ld and measure embedding
//!     quality (k_ld = 1 ≈ negative-sampling-only);
//!  B. the cross-space candidate routes of the iterative KNN — full mix
//!     vs same-space-only (≈ NN-descent) vs random-only, measured as
//!     HD-KNN quality at a fixed iteration budget;
//!  C. the probabilistic HD-refinement policy — base probability 0.05
//!     (paper default) vs always-refine vs never-refine-after-warmup,
//!     measured as wall-clock *and* final quality.

use funcsne::config::EmbedConfig;
use funcsne::data::datasets;
use funcsne::knn::brute::brute_knn;
use funcsne::knn::iterative::CandidateRoutes;
use funcsne::metrics::rnx::{rnx_auc, rnx_curve_vs_table};
use funcsne::session::{Command, Session};
use funcsne::util::Stopwatch;

fn base_cfg(n: usize) -> EmbedConfig {
    EmbedConfig {
        k_hd: 24.min(n - 1),
        k_ld: 12,
        perplexity: 8.0,
        n_iters: 0,
        jumpstart_iters: 50,
        early_exag_iters: 100,
        ..EmbedConfig::default()
    }
}

fn main() {
    let full = std::env::var("FUNCSNE_FULL").map(|v| v == "1").unwrap_or(false);
    let n = if full { 3000 } else { 800 };
    let iters = if full { 1200 } else { 400 };
    println!("=== ablations (n={n}, {iters} iters each) ===");

    // ---- A: LD close-range repulsion term --------------------------------
    println!("\n[A] k_ld sweep (k_ld=1 ≈ negative sampling only):");
    let ds = datasets::rat_brain_like(n, 50, 7);
    for k_ld in [1usize, 4, 8, 16] {
        let mut cfg = base_cfg(n);
        cfg.k_ld = k_ld;
        let mut session = Session::builder().dataset(ds.x.clone()).config(cfg).build().unwrap();
        session.run(iters).unwrap();
        let auc = rnx_auc(&ds.x, session.embedding(), 50);
        println!("  k_ld = {k_ld:>2}: R_NX AUC {auc:.3}");
    }

    // ---- B: candidate routes ---------------------------------------------
    println!("\n[B] candidate routes (HD-KNN AUC after {iters} iters, always refine):");
    let ds = datasets::blobs_disjointed(if full { 400 } else { 60 }, 30, 32, 2);
    let truth = brute_knn(&ds.x, 16);
    let routes = [
        ("full mix (paper)", CandidateRoutes::default()),
        (
            "same-space only (≈NN-descent)",
            CandidateRoutes { same_space: true, cross_space: false, random: false },
        ),
        (
            "random only",
            CandidateRoutes { same_space: false, cross_space: false, random: true },
        ),
    ];
    for (name, r) in routes {
        let mut cfg = base_cfg(ds.n());
        cfg.k_hd = 16;
        cfg.refine_base_prob = 1.0;
        let mut session = Session::builder().dataset(ds.x.clone()).config(cfg).build().unwrap();
        session.enqueue(Command::SetRoutes(r));
        session.run(iters).unwrap();
        let c = rnx_curve_vs_table(&truth, &session.engine().knn.hd, 16);
        println!("  {name:<32}: HD-KNN AUC {:.3}", c.auc);
    }

    // ---- C: refinement policy ---------------------------------------------
    println!("\n[C] HD-refinement policy (time and quality):");
    let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 9);
    for (name, prob) in [("default p=0.05+0.95E", 0.05), ("always refine", 1.0)] {
        let mut cfg = base_cfg(n);
        cfg.refine_base_prob = prob;
        let mut session = Session::builder().dataset(ds.x.clone()).config(cfg).build().unwrap();
        let sw = Stopwatch::new();
        session.run(iters).unwrap();
        let secs = sw.elapsed_s();
        let auc = rnx_auc(&ds.x, session.embedding(), 50);
        println!(
            "  {name:<22}: {secs:>6.2}s, AUC {auc:.3}, {} HD sweeps",
            session.stats().hd_refines
        );
    }
    println!("\nablations done");
}
