//! §Perf micro/meso benchmarks of the hot paths, across backends.
//!
//! Reports (median of repeated runs):
//!   * force pass per iteration — native vs parallel (1/2/4/8 shards)
//!     vs SIMD (1/4 threads) vs PJRT, at several (N, d), with speedup
//!     over sequential native;
//!   * sqdist candidate scoring — native vs parallel vs SIMD vs PJRT;
//!   * full engine iteration breakdown (refine LD / refine HD / forces /
//!     update) across native_t1 / simd_t1 / parallel_t4 / simd_t4;
//!   * point-updates per second (the headline interactivity number).
//!
//! The EXPERIMENTS.md §Perf table is filled from this output, and the
//! step breakdown lands in `BENCH_step_blobs.json`. With
//! `FUNCSNE_PERF_GATE=1` the run compares its fresh medians against the
//! **committed** `BENCH_step_blobs.json` at the repo root and exits
//! non-zero on a >20% regression — the CI perf-smoke ratchet.

use funcsne::config::EmbedConfig;
use funcsne::coordinator::driver::default_artifact_dir;
use funcsne::coordinator::PjrtBackend;
use funcsne::data::{datasets, Matrix};
use funcsne::engine::{ComputeBackend, FuncSne, NegSamples};
use funcsne::hd::Affinities;
use funcsne::knn::brute::brute_knn;
use funcsne::knn::iterative::IterativeKnn;
use funcsne::ld::{NativeBackend, ParallelBackend, SimdBackend};
use funcsne::server::json;
use funcsne::util::timer::bench_fn;
use funcsne::util::{Rng, Stopwatch};
use std::path::Path;

fn state(n: usize, d_ld: usize, k_hd: usize, k_ld: usize, seed: u64) -> (Matrix, Matrix, IterativeKnn, Affinities) {
    let ds = datasets::blobs(n, 16, 8, 1.0, 16.0, seed);
    let mut rng = Rng::new(seed);
    let mut y = Matrix::zeros(n, d_ld);
    for v in y.data_mut() {
        *v = rng.gauss_ms(0.0, 1.0) as f32;
    }
    let mut knn = IterativeKnn::new(n, k_hd, k_ld);
    knn.seed_random(&ds.x, &y, &mut rng);
    let mut aff = Affinities::new(n, k_hd);
    aff.recalibrate_all(&mut knn, 10.0);
    (ds.x, y, knn, aff)
}

fn main() {
    let full = std::env::var("FUNCSNE_FULL").map(|v| v == "1").unwrap_or(false);
    let sizes: &[usize] = if full { &[5000, 20000, 50000] } else { &[2000, 8000] };
    let have_pjrt = default_artifact_dir().join("manifest.txt").exists();
    println!("=== perf_hotpath (backends: native{}) ===", if have_pjrt { " + pjrt" } else { "" });

    // ---- force pass ----------------------------------------------------
    for &n in sizes {
        for &d in &[2usize, 8] {
            let (x, y, knn, aff) = state(n, d, 32, 16, 1);
            let _ = x;
            let mut rng = Rng::new(2);
            let neg = NegSamples::draw(n, 8, &mut rng);
            let far_scale = ((n - 1 - 48) as f32) / 8.0;
            let mut attr = Matrix::zeros(n, d);
            let mut rep = Matrix::zeros(n, d);
            let mut native = NativeBackend::new();
            let stats = bench_fn(1, if full { 7 } else { 5 }, || {
                native
                    .forces(&y, &knn, &aff, &neg, 1.0, far_scale, &mut attr, &mut rep)
                    .unwrap()
            });
            let pts_per_s = n as f64 / stats.median_s;
            println!(
                "forces native  n={n:>6} d={d}: {:>9.3} ms/pass  ({:.2e} point-updates/s)",
                stats.median_s * 1e3,
                pts_per_s
            );
            let native_median = stats.median_s;
            // Sharded backend at 1/2/4/8 shards: same inputs, results
            // bitwise-identical to native — only wall-clock may differ.
            for &threads in &[1usize, 2, 4, 8] {
                let mut par = ParallelBackend::new(threads);
                let stats = bench_fn(1, if full { 7 } else { 5 }, || {
                    par.forces(&y, &knn, &aff, &neg, 1.0, far_scale, &mut attr, &mut rep)
                        .unwrap()
                });
                println!(
                    "forces par x{threads}  n={n:>6} d={d}: {:>9.3} ms/pass  \
                     ({:.2e} point-updates/s, {:.2}x vs native)",
                    stats.median_s * 1e3,
                    n as f64 / stats.median_s,
                    native_median / stats.median_s
                );
            }
            // Lane-vectorized kernels, same sharding: approximate vs
            // native (lane-fold tolerance), bitwise at any width.
            for &threads in &[1usize, 4] {
                let mut simd = SimdBackend::new(threads);
                let stats = bench_fn(1, if full { 7 } else { 5 }, || {
                    simd.forces(&y, &knn, &aff, &neg, 1.0, far_scale, &mut attr, &mut rep)
                        .unwrap()
                });
                println!(
                    "forces simd x{threads} n={n:>6} d={d}: {:>9.3} ms/pass  \
                     ({:.2e} point-updates/s, {:.2}x vs native)",
                    stats.median_s * 1e3,
                    n as f64 / stats.median_s,
                    native_median / stats.median_s
                );
            }
            if have_pjrt {
                let mut pjrt = PjrtBackend::new(&default_artifact_dir()).unwrap();
                pjrt.warmup(32, 16, 8, d, 16).unwrap();
                let stats = bench_fn(1, if full { 7 } else { 5 }, || {
                    pjrt.forces(&y, &knn, &aff, &neg, 1.0, far_scale, &mut attr, &mut rep)
                        .unwrap()
                });
                println!(
                    "forces pjrt    n={n:>6} d={d}: {:>9.3} ms/pass  ({:.2e} point-updates/s)",
                    stats.median_s * 1e3,
                    n as f64 / stats.median_s
                );
            }
        }
    }

    // ---- sqdist scoring --------------------------------------------------
    // 8192 pairs sits at the parallel backend's min-pairs-per-shard
    // floor (runs on one shard); 65536 fans out across all workers.
    for &(pairs, m) in &[(8192usize, 32usize), (8192, 128), (65536, 32)] {
        let ds = datasets::blobs(4096, m, 8, 1.0, 16.0, 3);
        let mut rng = Rng::new(4);
        let owners: Vec<u32> = (0..pairs).map(|_| rng.below(4096) as u32).collect();
        let cands: Vec<u32> = (0..pairs).map(|_| rng.below(4096) as u32).collect();
        let mut out = Vec::new();
        let mut native = NativeBackend::new();
        let s = bench_fn(1, 7, || {
            native.sqdist_batch(&ds.x, &owners, &cands, &mut out).unwrap()
        });
        println!(
            "sqdist native  T={pairs} M={m:>4}: {:>9.3} ms  ({:.2e} pairs/s)",
            s.median_s * 1e3,
            pairs as f64 / s.median_s
        );
        let native_median = s.median_s;
        for &threads in &[2usize, 4, 8] {
            let mut par = ParallelBackend::new(threads);
            let s = bench_fn(1, 7, || {
                par.sqdist_batch(&ds.x, &owners, &cands, &mut out).unwrap()
            });
            println!(
                "sqdist par x{threads}  T={pairs} M={m:>4}: {:>9.3} ms  \
                 ({:.2e} pairs/s, {:.2}x vs native)",
                s.median_s * 1e3,
                pairs as f64 / s.median_s,
                native_median / s.median_s
            );
        }
        let mut simd = SimdBackend::new(1);
        let s = bench_fn(1, 7, || {
            simd.sqdist_batch(&ds.x, &owners, &cands, &mut out).unwrap()
        });
        println!(
            "sqdist simd x1 T={pairs} M={m:>4}: {:>9.3} ms  \
             ({:.2e} pairs/s, {:.2}x vs native)",
            s.median_s * 1e3,
            pairs as f64 / s.median_s,
            native_median / s.median_s
        );
        if have_pjrt {
            let mut pjrt = PjrtBackend::new(&default_artifact_dir()).unwrap();
            let s = bench_fn(1, 7, || {
                pjrt.sqdist_batch(&ds.x, &owners, &cands, &mut out).unwrap()
            });
            println!(
                "sqdist pjrt    T={pairs} M={m:>4}: {:>9.3} ms  ({:.2e} pairs/s)",
                s.median_s * 1e3,
                pairs as f64 / s.median_s
            );
        }
    }

    // ---- full-step breakdown + BENCH artifact (4 backend configs) -------
    // Two acceptance checks on blobs n=8000, over the FULL step() wall
    // time — refinement, negative sampling, recalibration, forces AND
    // update, not just the force pass:
    //   * Amdahl (stream-RNG sharding): parallel_t4 ≥ 2× over native_t1;
    //   * SIMD (lane kernels): simd_t1 ≥ 2× over native_t1, and
    //     simd_t4 shows that lane and thread scaling compose.
    // The per-phase split comes from EngineStats::phase_micros; the
    // numbers land in BENCH_step_blobs.json, and under
    // FUNCSNE_PERF_GATE=1 they are checked against the committed
    // baseline at the repo root (exit 2 on a >20% median regression).
    {
        let n = 8000usize;
        let iters = if full { 100 } else { 40 };
        struct StepRun {
            key: &'static str,
            median_ms: f64,
            mean_ms: f64,
            /// (phase, µs per iteration) in execution order.
            phase_per_iter: Vec<(&'static str, f64)>,
            /// HD refinement sweeps actually run / total iterations
            /// (the probabilistic-skip heuristic in action).
            hd_refines: usize,
            iters_total: usize,
        }
        let run = |key: &'static str, threads: usize, simd: bool| -> StepRun {
            let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 5);
            let cfg = EmbedConfig {
                n_iters: 0,
                jumpstart_iters: 0,
                early_exag_iters: 0,
                threads,
                ..EmbedConfig::default()
            };
            let mut engine = FuncSne::new(ds.x, cfg).unwrap();
            let mut backend: Box<dyn ComputeBackend> = if simd {
                Box::new(SimdBackend::new(threads))
            } else if threads > 1 {
                Box::new(ParallelBackend::new(threads))
            } else {
                Box::new(NativeBackend::new())
            };
            engine.run(20, backend.as_mut()).unwrap(); // warm up the KNN state
            let phase0 = engine.stats.phase_micros;
            let mut per_step = Vec::with_capacity(iters);
            for _ in 0..iters {
                let sw = Stopwatch::new();
                engine.step(backend.as_mut()).unwrap();
                per_step.push(sw.elapsed_s() * 1e3);
            }
            let phase1 = engine.stats.phase_micros;
            per_step.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median_ms = per_step[per_step.len() / 2];
            let mean_ms = per_step.iter().sum::<f64>() / per_step.len() as f64;
            let phase_per_iter = phase1
                .named()
                .iter()
                .zip(phase0.named().iter())
                .map(|(&(name, after), &(_, before))| {
                    (name, (after - before) as f64 / iters as f64)
                })
                .collect();
            StepRun {
                key,
                median_ms,
                mean_ms,
                phase_per_iter,
                hd_refines: engine.stats.hd_refines,
                iters_total: engine.stats.iters,
            }
        };
        let runs = [
            run("native_t1", 1, false),
            run("simd_t1", 1, true),
            run("parallel_t4", 4, false),
            run("simd_t4", 4, true),
        ];
        for r in &runs {
            let split: Vec<String> = r
                .phase_per_iter
                .iter()
                .map(|(name, us)| format!("{name} {:.0}us", us))
                .collect();
            println!(
                "step blobs {:<11} n={n}: median {:>8.3} ms | mean {:>8.3} ms \
                 ({:.2e} point-updates/s; hd_refines {}/{}) | {}",
                r.key,
                r.median_ms,
                r.mean_ms,
                n as f64 / (r.median_ms * 1e-3),
                r.hd_refines,
                r.iters_total,
                split.join(" | ")
            );
        }
        let native_t1 = runs[0].median_ms;
        println!(
            "step blobs speedups vs native_t1: simd_t1 {:.2}x | parallel_t4 {:.2}x | \
             simd_t4 {:.2}x (medians)",
            native_t1 / runs[1].median_ms,
            native_t1 / runs[2].median_ms,
            native_t1 / runs[3].median_ms
        );
        // Minimal hand-rolled JSON (the repo is zero-dependency).
        let run_json = |r: &StepRun| -> String {
            let phases: Vec<String> = r
                .phase_per_iter
                .iter()
                .map(|(name, us)| format!("\"{name}\":{:.3}", us))
                .collect();
            format!(
                "\"{}\":{{\"median_step_ms\":{:.4},\"mean_step_ms\":{:.4},\
                 \"phase_micros_per_iter\":{{{}}}}}",
                r.key,
                r.median_ms,
                r.mean_ms,
                phases.join(",")
            )
        };
        let backends: Vec<String> = runs.iter().map(run_json).collect();
        let payload = format!(
            "{{\"bench\":\"step_blobs\",\"dataset\":\"blobs\",\"n\":{n},\
             \"iters\":{iters},\"backends\":{{{}}},\
             \"speedup_simd_vs_native_t1\":{:.3},\
             \"speedup_parallel_t4_vs_native_t1\":{:.3}}}\n",
            backends.join(","),
            native_t1 / runs[1].median_ms,
            native_t1 / runs[2].median_ms
        );

        // Regression ratchet: compare fresh medians against the
        // committed baseline BEFORE overwriting it. Enforced only under
        // FUNCSNE_PERF_GATE=1 (CI perf-smoke); local runs just report.
        let gate = std::env::var("FUNCSNE_PERF_GATE").map(|v| v == "1").unwrap_or(false);
        let baseline_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_step_blobs.json");
        let mut regressed = Vec::new();
        match std::fs::read_to_string(&baseline_path) {
            Ok(text) => match json::parse(&text) {
                Ok(doc) => {
                    for r in &runs {
                        // Missing keys (older schema) are not a regression.
                        let Some(base) = doc
                            .get("backends")
                            .and_then(|b| b.get(r.key))
                            .and_then(|e| e.get("median_step_ms"))
                            .and_then(|v| v.as_f64())
                        else {
                            continue;
                        };
                        let ratio = r.median_ms / base;
                        println!(
                            "perf gate {:<11}: {:.3} ms vs baseline {:.3} ms ({:.2}x)",
                            r.key, r.median_ms, base, ratio
                        );
                        if ratio > 1.2 {
                            regressed.push(format!(
                                "{}: {:.3} ms > 1.2x baseline {:.3} ms",
                                r.key, r.median_ms, base
                            ));
                        }
                    }
                }
                Err(e) => println!("(baseline BENCH_step_blobs.json unparsable, skipping gate: {e})"),
            },
            Err(e) => println!("(no committed baseline at {}: {e})", baseline_path.display()),
        }
        match std::fs::write("BENCH_step_blobs.json", &payload) {
            Ok(()) => println!("(wrote BENCH_step_blobs.json)"),
            Err(e) => println!("(could not write BENCH_step_blobs.json: {e})"),
        }
        if !regressed.is_empty() {
            if gate {
                eprintln!("PERF GATE FAILED (>20% median step regression):");
                for r in &regressed {
                    eprintln!("  {r}");
                }
                std::process::exit(2);
            }
            println!("(regressions vs baseline, gate off: {})", regressed.join("; "));
        }
    }

    // ---- online quality-probe overhead ----------------------------------
    // Acceptance: with probe_anchors=256 on blobs(n=5000) the probe adds
    // < 10% to the MEDIAN step time (the probe fires 1-in-probe_every
    // steps, so the median step is untouched by design; the mean and the
    // probe-step cost quantify the amortised and worst-case overhead).
    {
        let n = 5000usize;
        let iters = if full { 100 } else { 50 };
        let run = |probe_every: usize| -> Vec<f64> {
            let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 7);
            let cfg = EmbedConfig {
                n_iters: 0,
                jumpstart_iters: 0,
                early_exag_iters: 0,
                probe_every,
                probe_anchors: 256,
                ..EmbedConfig::default()
            };
            let mut engine = FuncSne::new(ds.x, cfg).unwrap();
            let mut backend = NativeBackend::new();
            engine.run(10, &mut backend).unwrap(); // warm up KNN state
            let mut per_step = Vec::with_capacity(iters);
            for _ in 0..iters {
                let sw = Stopwatch::new();
                engine.step(&mut backend).unwrap();
                per_step.push(sw.elapsed_s());
            }
            per_step
        };
        let stats = |mut v: Vec<f64>| -> (f64, f64, f64) {
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = v[v.len() / 2];
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            (median, mean, *v.last().unwrap())
        };
        let (off_med, off_mean, _) = stats(run(0));
        let (on_med, on_mean, on_max) = stats(run(25));
        println!(
            "probe overhead n={n} anchors=256 every=25 ({iters} steps):\n\
             \x20 median step  off {:.3} ms | on {:.3} ms ({:+.1}%)\n\
             \x20 mean   step  off {:.3} ms | on {:.3} ms ({:+.1}%)\n\
             \x20 worst (probe) step {:.3} ms",
            off_med * 1e3,
            on_med * 1e3,
            (on_med / off_med - 1.0) * 100.0,
            off_mean * 1e3,
            on_mean * 1e3,
            (on_mean / off_mean - 1.0) * 100.0,
            on_max * 1e3
        );
    }
    // ---- exact-KNN ground truth is the benchmark's own cost; note it ---
    let ds = datasets::blobs(2000, 32, 10, 1.0, 20.0, 6);
    let sw = Stopwatch::new();
    let _t = brute_knn(&ds.x, 32);
    println!("(reference: brute_knn n=2000 d=32 k=32: {:.1} ms)", sw.elapsed_ms());
}
