//! Observability smoke: run the real server with tracing on, drive it
//! over the wire, and report latency-histogram snapshots.
//!
//! Boots an in-process [`Server`] with `trace: true` on an ephemeral
//! port, creates a blobs n=8000 session via `POST /sessions`, lets the
//! stepper advance it for a window of iterations while hammering the
//! JSON endpoints, then snapshots `GET /debug/trace` to trace_obs.json
//! (Perfetto-loadable) and the step/sweep/HTTP histograms to
//! BENCH_obs.json for the CI artifact trail (the obs-smoke job).

use funcsne::data::datasets;
use funcsne::obs::HistSnapshot;
use funcsne::server::json::{self, Json};
use funcsne::server::{Server, ServerConfig};
use funcsne::util::{io, Stopwatch};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// One HTTP exchange on a fresh connection (`Connection: close`).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(60))).expect("read timeout");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: funcsne\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).expect("send request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8_lossy(&raw);
    let (head, payload) = text.split_once("\r\n\r\n").expect("header/body split");
    let status: u16 =
        head.split_whitespace().nth(1).expect("status code").parse().expect("numeric status");
    (status, payload.to_string())
}

/// Histogram snapshot as a JSON object for the bench payload.
fn hist_json(s: &HistSnapshot) -> Json {
    Json::obj(vec![
        ("count", s.count().into()),
        ("sum", s.sum.into()),
        ("p50", s.quantile(0.5).into()),
        ("p95", s.quantile(0.95).into()),
        ("p99", s.quantile(0.99).into()),
    ])
}

fn main() {
    let full = std::env::var("FUNCSNE_FULL").map(|v| v == "1").unwrap_or(false);
    let n = 8000usize;
    let iter_target = if full { 120 } else { 40 };
    println!("=== obs_smoke (blobs n={n}, {iter_target} traced iterations) ===");

    // The dataset goes to the server by path: 8000×32 rows inline
    // would be a multi-megabyte POST body for no extra coverage.
    let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 7);
    let mut npy = std::env::temp_dir();
    npy.push(format!("funcsne_obs_smoke_{}.npy", std::process::id()));
    io::write_npy_f32(&npy, ds.x.data(), &[ds.x.n(), ds.x.d()]).expect("write dataset");

    let server = Server::bind(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        threads: 2,
        trace: true,
        ..ServerConfig::default()
    })
    .expect("bind ephemeral port");
    let addr = server.local_addr();
    let obs = server.obs();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().expect("server run"));

    let spec = format!(
        "{{\"path\": {:?}, \"k_hd\": 16, \"perplexity\": 10, \"seed\": 7}}",
        npy.to_str().expect("utf8 temp path")
    );
    let (status, created) = http(addr, "POST", "/sessions", &spec);
    assert_eq!(status, 201, "create failed: {created}");
    let id = json::parse(&created)
        .expect("create reply parses")
        .get("id")
        .and_then(Json::as_usize)
        .expect("id");

    // Let the stepper trace real sweeps; poll stats (which also feeds
    // the HTTP histograms) until the iteration window has passed.
    let sw = Stopwatch::new();
    loop {
        let (status, stats) = http(addr, "GET", &format!("/sessions/{id}/stats"), "");
        assert_eq!(status, 200, "stats failed: {stats}");
        let iter = json::parse(&stats)
            .expect("stats parse")
            .get("iter")
            .and_then(Json::as_usize)
            .expect("iter");
        if iter >= iter_target {
            break;
        }
        assert!(sw.elapsed_s() < 300.0, "stuck at iter {iter}/{iter_target}");
        std::thread::sleep(Duration::from_millis(10));
    }
    for _ in 0..25 {
        assert_eq!(http(addr, "GET", "/healthz", "").0, 200);
        assert_eq!(http(addr, "GET", "/metrics", "").0, 200);
    }

    let (status, trace) = http(addr, "GET", "/debug/trace", "");
    assert_eq!(status, 200, "debug/trace failed");
    // Round-trip through the in-repo codec before anything lands on
    // disk: the artifact is guaranteed-parseable JSON.
    let doc = json::parse(&trace).expect("trace JSON parses");
    let events = doc.get("traceEvents").and_then(Json::as_arr).map_or(0, <[Json]>::len);
    match std::fs::write("trace_obs.json", doc.encode()) {
        Ok(()) => println!("(wrote trace_obs.json, {events} events)"),
        Err(e) => println!("(could not write trace_obs.json: {e})"),
    }

    let step = obs.step.snapshot();
    let sweep = obs.sweep.snapshot();
    let http_total = obs.http_total();
    println!(
        "steps {} (p50 {:.0} µs, p99 {:.0} µs) | sweeps {} (p50 {:.0} µs) | \
         http {} requests (p50 {:.0} µs, p99 {:.0} µs) | {events} trace events",
        step.count(),
        step.quantile(0.5),
        step.quantile(0.99),
        sweep.count(),
        sweep.quantile(0.5),
        http_total.count(),
        http_total.quantile(0.5),
        http_total.quantile(0.99),
    );
    assert!(step.count() > 0, "traced run must record step latency");
    assert!(http_total.count() > 0, "traced run must record HTTP latency");

    let payload = Json::obj(vec![
        ("bench", "obs_smoke".into()),
        ("dataset", "blobs".into()),
        ("n", n.into()),
        ("iters", iter_target.into()),
        ("step_us", hist_json(&step)),
        ("sweep_us", hist_json(&sweep)),
        ("http_us", hist_json(&http_total)),
        ("frame_encode_us", hist_json(&obs.frame_encode.snapshot())),
        ("trace_events", events.into()),
    ]);
    match std::fs::write("BENCH_obs.json", payload.encode() + "\n") {
        Ok(()) => println!("(wrote BENCH_obs.json)"),
        Err(e) => println!("(could not write BENCH_obs.json: {e})"),
    }

    handle.shutdown();
    join.join().expect("server thread");
    std::fs::remove_file(&npy).ok();
}
