//! Bench target regenerating the paper's fig1 output.
//! Quick scale by default; FUNCSNE_FULL=1 for paper-sized runs.
use funcsne::figures::common::Scale;

fn main() {
    let scale = Scale::from_env();
    let summary = funcsne::figures::fig1::run(scale).expect("fig1 driver failed");
    let _ = summary;
}
