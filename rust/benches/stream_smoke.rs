//! Streaming-frame throughput smoke: how fast can the codec turn live
//! embedding state into wire frames, and how big are they?
//!
//! Runs blobs n=8000 for a window of real engine iterations, encodes
//! every one through a [`FrameEncoder`] exactly as the server's
//! broadcast path does, and reports encode frames/sec, mean bytes per
//! frame and the keyframe size. The numbers land in BENCH_stream.json
//! for the CI artifact trail (uploaded by the stream-smoke job).

use funcsne::config::EmbedConfig;
use funcsne::data::datasets;
use funcsne::engine::{ComputeBackend, FuncSne};
use funcsne::ld::NativeBackend;
use funcsne::server::frames::{decode, FrameEncoder};
use funcsne::util::Stopwatch;

fn main() {
    let full = std::env::var("FUNCSNE_FULL").map(|v| v == "1").unwrap_or(false);
    let n = 8000usize;
    let iters = if full { 120 } else { 40 };
    println!("=== stream_smoke (blobs n={n}, {iters} encoded iterations) ===");

    let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 7);
    let cfg = EmbedConfig {
        n_iters: 0,
        jumpstart_iters: 0,
        early_exag_iters: 0,
        ..EmbedConfig::default()
    };
    let mut engine = FuncSne::new(ds.x, cfg).unwrap();
    let mut backend = NativeBackend::new();
    let b: &mut dyn ComputeBackend = &mut backend;
    engine.run(20, &mut *b).unwrap(); // settle the KNN state first

    let mut enc = FrameEncoder::new(30);
    let mut frames = 0usize;
    let mut keyframes = 0usize;
    let mut bytes_total = 0usize;
    let mut keyframe_bytes = 0usize;
    let mut delta_bytes = 0usize;
    let mut encode_s = 0.0f64;
    let mut step_s = 0.0f64;
    for _ in 0..iters {
        let sw = Stopwatch::new();
        engine.step(&mut *b).unwrap();
        step_s += sw.elapsed_s();
        let sw = Stopwatch::new();
        let emitted = enc.encode(engine.iter as u64, &engine.y, engine.structure_version());
        encode_s += sw.elapsed_s();
        if let Some(bytes) = emitted {
            let frame = decode(&bytes).expect("encoder output decodes");
            frames += 1;
            bytes_total += bytes.len();
            if frame.keyframe {
                keyframes += 1;
                keyframe_bytes = bytes.len();
            } else {
                delta_bytes += bytes.len();
            }
        }
    }

    let deltas = frames - keyframes;
    let mean_bytes = bytes_total as f64 / frames.max(1) as f64;
    let mean_delta_bytes = delta_bytes as f64 / deltas.max(1) as f64;
    let encode_fps = frames as f64 / encode_s.max(1e-12);
    let end_to_end_fps = frames as f64 / (encode_s + step_s).max(1e-12);
    println!(
        "frames {frames} ({keyframes} key / {deltas} delta) | \
         encode {encode_fps:.0} frames/s | mean {mean_bytes:.0} B/frame \
         (keyframe {keyframe_bytes} B, delta mean {mean_delta_bytes:.0} B) | \
         step+encode {end_to_end_fps:.1} frames/s"
    );

    // Minimal hand-rolled JSON (the repo is zero-dependency).
    let payload = format!(
        "{{\"bench\":\"stream_smoke\",\"dataset\":\"blobs\",\"n\":{n},\
         \"iters\":{iters},\"frames\":{frames},\"keyframes\":{keyframes},\
         \"encode_frames_per_sec\":{encode_fps:.1},\
         \"end_to_end_frames_per_sec\":{end_to_end_fps:.2},\
         \"mean_bytes_per_frame\":{mean_bytes:.1},\
         \"keyframe_bytes\":{keyframe_bytes},\
         \"mean_delta_bytes\":{mean_delta_bytes:.1}}}\n"
    );
    match std::fs::write("BENCH_stream.json", &payload) {
        Ok(()) => println!("(wrote BENCH_stream.json)"),
        Err(e) => println!("(could not write BENCH_stream.json: {e})"),
    }
}
