//! Bench target regenerating the paper's table2 output.
//! Quick scale by default; FUNCSNE_FULL=1 for paper-sized runs.
use funcsne::figures::common::Scale;

fn main() {
    let scale = Scale::from_env();
    let summary = funcsne::figures::table2::run(scale).expect("table2 driver failed");
    let _ = summary;
}
