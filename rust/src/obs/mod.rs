//! Zero-dependency observability: latency histograms + span tracing.
//!
//! The [`Obs`] registry is one shared [`std::sync::Arc`] holding every
//! [`hist::Hist`] family and the bounded [`trace::Tracer`] ring. The
//! server creates it once ([`crate::server::Server::bind`]) and hands
//! clones to the stepper thread, the [`crate::server::frames::FrameHub`]
//! and each HTTP worker, so all recording lands in one place and both
//! export surfaces — `/metrics` histogram families and `GET
//! /debug/trace` Chrome trace JSON — read a consistent view.
//!
//! Everything is **off by default** and gated on a single `enabled`
//! bool fixed at construction (config `trace` / env `FUNCSNE_TRACE`):
//! every record method early-returns when disabled, so the deterministic
//! hot path pays one predictable branch and no clock reads. All timing
//! goes through [`crate::util::timer::PhaseClock`] — the `wall_clock`
//! lint rule gates this module like the engine.

pub mod expo;
pub mod hist;
pub mod trace;

pub use hist::{Hist, HistSnapshot};
pub use trace::{TraceEvent, Tracer};

use crate::engine::PhaseMicros;
use crate::util::timer::PhaseClock;
use std::sync::atomic::{AtomicU64, Ordering};
use trace::{HTTP_TID_BASE, STEPPER_TID};

/// HTTP route families for per-route latency histograms. Fixed at
/// compile time so label cardinality is bounded; unmatched paths land
/// in `other`.
pub const ROUTES: [&str; 13] = [
    "GET /healthz",
    "GET /metrics",
    "GET /debug/trace",
    "POST /sessions",
    "GET /sessions",
    "GET /sessions/:id",
    "GET /sessions/:id/stats",
    "GET /sessions/:id/embedding",
    "GET /sessions/:id/stream",
    "POST /sessions/:id/commands",
    "POST /sessions/:id/checkpoint",
    "DELETE /sessions/:id",
    "other",
];

/// Status-class labels for HTTP latency histograms.
pub const STATUS_CLASSES: [&str; 4] = ["2xx", "3xx", "4xx", "5xx"];

/// Map `(method, path)` to an index into [`ROUTES`].
pub fn route_index(method: &str, path: &str) -> usize {
    let segments: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    let idx = match (method, segments.as_slice()) {
        ("GET", ["healthz"]) => 0,
        ("GET", ["metrics"]) => 1,
        ("GET", ["debug", "trace"]) => 2,
        ("POST", ["sessions"]) => 3,
        ("GET", ["sessions"]) => 4,
        ("GET", ["sessions", _]) => 5,
        ("GET", ["sessions", _, "stats"]) => 6,
        ("GET", ["sessions", _, "embedding"]) => 7,
        ("GET", ["sessions", _, "stream"]) => 8,
        ("POST", ["sessions", _, "commands"]) => 9,
        ("POST", ["sessions", _, "checkpoint"]) => 10,
        ("DELETE", ["sessions", _]) => 11,
        _ => 12,
    };
    debug_assert!(idx < ROUTES.len());
    idx
}

/// Map an HTTP status code to an index into [`STATUS_CLASSES`].
pub fn status_class(status: u16) -> usize {
    match status {
        200..=299 => 0,
        300..=399 => 1,
        400..=499 => 2,
        _ => 3,
    }
}

/// Per-step timing sample handed from the stepper's sweep loop to
/// [`Obs::record_step`] and [`SessionLatency::record`].
#[derive(Clone, Copy, Debug)]
pub struct StepTrace {
    /// Engine iteration number after the step.
    pub iter: usize,
    /// Step start, µs on the [`Obs`] epoch clock.
    pub ts_us: u64,
    /// Wall time of the whole step, µs.
    pub wall_us: u64,
    /// Per-phase engine-side split of this step (delta, not
    /// cumulative).
    pub phases: PhaseMicros,
}

/// p50/p95/p99 for one phase of one session, as reported in
/// `GET /sessions/:id/stats`.
#[derive(Clone, Debug)]
pub struct PhaseQuantiles {
    pub phase: &'static str,
    pub samples: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Per-session step-latency histograms backing the stats-JSON
/// `latency` object: whole-step wall time plus one histogram per
/// engine phase. Lives in the stepper's `SessionMeta`, dropped with
/// the session.
#[derive(Default)]
pub struct SessionLatency {
    step: Hist,
    phases: [Hist; 5],
}

impl SessionLatency {
    pub fn record(&self, st: &StepTrace) {
        self.step.record(st.wall_us);
        for (i, (_, us)) in st.phases.named().iter().enumerate() {
            self.phases[i].record(*us);
        }
    }

    /// Quantiles per phase (whole-step `step` first), skipping phases
    /// with no samples. Empty when nothing was recorded.
    pub fn quantiles(&self) -> Vec<PhaseQuantiles> {
        let mut out = Vec::with_capacity(1 + self.phases.len());
        let mut push = |phase: &'static str, s: HistSnapshot| {
            let samples = s.count();
            if samples > 0 {
                out.push(PhaseQuantiles {
                    phase,
                    samples,
                    p50_us: s.quantile(0.5),
                    p95_us: s.quantile(0.95),
                    p99_us: s.quantile(0.99),
                });
            }
        };
        push("step", self.step.snapshot());
        for (i, name) in PhaseMicros::NAMES.iter().enumerate() {
            push(name, self.phases[i].snapshot());
        }
        out
    }
}

/// The shared observability registry. All fields are atomics or
/// internally locked, so recording needs only `&Obs` from any thread.
pub struct Obs {
    enabled: bool,
    /// Epoch for every trace timestamp: one clock started at
    /// construction, shared by stepper and HTTP workers.
    epoch: PhaseClock,
    next_request: AtomicU64,
    /// Whole-step wall time, µs (all sessions).
    pub step: Hist,
    /// Engine-phase split of step time, µs; indexed like
    /// [`PhaseMicros::NAMES`].
    pub step_phase: [Hist; 5],
    /// Sweep duration, µs.
    pub sweep: Hist,
    /// Frame encode time, µs.
    pub frame_encode: Hist,
    /// Encoded frame size, bytes.
    pub frame_bytes: Hist,
    /// Subscriber queue depth after a successful enqueue.
    pub queue_depth: Hist,
    /// Session checkpoint (snapshot publish + WAL truncate) wall time,
    /// µs. Unlike the step histograms this is **always** recorded —
    /// checkpoints are rare, off the per-iteration hot path, and their
    /// latency is the durability signal operators care about.
    pub checkpoint_micros: Hist,
    /// Published snapshot size, bytes (same always-on rationale).
    pub checkpoint_bytes: Hist,
    /// HTTP request latency, µs, by `[route][status_class]`.
    http: Box<[[Hist; 4]; 13]>,
    tracer: Tracer,
}

impl Obs {
    pub fn new(enabled: bool) -> Obs {
        Obs {
            enabled,
            epoch: PhaseClock::start(),
            next_request: AtomicU64::new(1),
            step: Hist::new(),
            step_phase: Default::default(),
            sweep: Hist::new(),
            frame_encode: Hist::new(),
            frame_bytes: Hist::new(),
            queue_depth: Hist::new(),
            checkpoint_micros: Hist::new(),
            checkpoint_bytes: Hist::new(),
            http: Box::new(std::array::from_fn(|_| Default::default())),
            tracer: Tracer::new(),
        }
    }

    /// `FUNCSNE_TRACE` truthiness: `1`/`true`/`yes`/`on`,
    /// case-insensitive.
    pub fn env_enabled() -> bool {
        std::env::var("FUNCSNE_TRACE")
            .map(|v| {
                let v = v.trim().to_ascii_lowercase();
                matches!(v.as_str(), "1" | "true" | "yes" | "on")
            })
            .unwrap_or(false)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds since this registry was created — the trace
    /// timeline.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed_ns() / 1_000
    }

    /// Record one finished HTTP request: latency histogram by
    /// route/status class plus an `http` trace span on the worker's
    /// tid. `micros` is the handler wall time; the span is backdated
    /// so it ends "now".
    pub fn observe_http(&self, method: &str, path: &str, status: u16, micros: u64, worker: usize) {
        if !self.enabled {
            return;
        }
        let route = route_index(method, path);
        self.http[route][status_class(status)].record(micros);
        let request = self.next_request.fetch_add(1, Ordering::Relaxed);
        // `/sessions/:id/...` — tag the span with the session when the
        // id segment parses.
        let session = path
            .split('/')
            .filter(|s| !s.is_empty())
            .nth(1)
            .and_then(|s| s.parse::<u64>().ok());
        self.tracer.record(TraceEvent {
            name: "http",
            cat: "http",
            ph: 'X',
            ts_us: self.now_us().saturating_sub(micros),
            dur_us: micros,
            tid: HTTP_TID_BASE + worker as u32,
            session,
            sweep: None,
            request: Some(request),
            detail: format!("{} -> {status}", ROUTES[route]),
        });
    }

    /// Record one stepper sweep: duration histogram plus a `sweep`
    /// span enclosing the sweep's `session_step` spans.
    pub fn record_sweep(&self, sweep_no: u64, steps: u64, ts_us: u64, dur_us: u64) {
        if !self.enabled {
            return;
        }
        self.sweep.record(dur_us);
        self.tracer.record(TraceEvent {
            name: "sweep",
            cat: "stepper",
            ph: 'X',
            ts_us,
            dur_us,
            tid: STEPPER_TID,
            session: None,
            sweep: Some(sweep_no),
            request: None,
            detail: format!("{steps} steps"),
        });
    }

    /// Record one engine step: global step + per-phase histograms, a
    /// `session_step` span, and per-phase child spans laid out
    /// sequentially in execution order (the engine reports per-phase
    /// durations, not timestamps; phases do run in this order inside
    /// the step, so containment is faithful).
    pub fn record_step(&self, session: u64, sweep_no: u64, st: &StepTrace) {
        if !self.enabled {
            return;
        }
        self.step.record(st.wall_us);
        let named = st.phases.named();
        for (i, (_, us)) in named.iter().enumerate() {
            self.step_phase[i].record(*us);
        }
        self.tracer.record(TraceEvent {
            name: "session_step",
            cat: "stepper",
            ph: 'X',
            ts_us: st.ts_us,
            dur_us: st.wall_us,
            tid: STEPPER_TID,
            session: Some(session),
            sweep: Some(sweep_no),
            request: None,
            detail: format!("iter {}", st.iter),
        });
        let mut cursor = st.ts_us;
        for (name, us) in named {
            if us == 0 {
                continue;
            }
            self.tracer.record(TraceEvent {
                name,
                cat: "engine",
                ph: 'X',
                ts_us: cursor,
                dur_us: us,
                tid: STEPPER_TID,
                session: Some(session),
                sweep: Some(sweep_no),
                request: None,
                detail: String::new(),
            });
            cursor = cursor.saturating_add(us);
        }
    }

    /// Record one encoded frame (encode wall time + wire size).
    pub fn record_frame(&self, encode_us: u64, bytes: u64) {
        if !self.enabled {
            return;
        }
        self.frame_encode.record(encode_us);
        self.frame_bytes.record(bytes);
    }

    /// Record a subscriber's queue depth after an enqueue.
    pub fn record_queue_depth(&self, depth: u64) {
        if !self.enabled {
            return;
        }
        self.queue_depth.record(depth);
    }

    /// Record one successful session checkpoint. Always on (no
    /// `enabled` gate): checkpoints happen at most every
    /// `--checkpoint-every` iterations, so the cost is negligible and
    /// the signal matters even when tracing is off.
    pub fn record_checkpoint(&self, micros: u64, bytes: u64) {
        self.checkpoint_micros.record(micros);
        self.checkpoint_bytes.record(bytes);
    }

    /// Non-empty HTTP latency snapshots as
    /// `(route, status_class, snapshot)`.
    pub fn http_snapshots(&self) -> Vec<(&'static str, &'static str, HistSnapshot)> {
        let mut out = Vec::new();
        for (r, route) in ROUTES.iter().enumerate() {
            for (c, class) in STATUS_CLASSES.iter().enumerate() {
                let snap = self.http[r][c].snapshot();
                if snap.count() > 0 {
                    out.push((*route, *class, snap));
                }
            }
        }
        out
    }

    /// All HTTP latency merged into one snapshot (bench summaries).
    pub fn http_total(&self) -> HistSnapshot {
        let mut total = HistSnapshot::default();
        for row in self.http.iter() {
            for h in row {
                total.merge(&h.snapshot());
            }
        }
        total
    }

    /// Copy out the trace ring: `(events oldest-first, dropped)`.
    pub fn tracer_snapshot(&self) -> (Vec<TraceEvent>, u64) {
        self.tracer.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_table_covers_the_api() {
        assert_eq!(route_index("GET", "/healthz"), 0);
        assert_eq!(route_index("GET", "/metrics"), 1);
        assert_eq!(route_index("GET", "/debug/trace"), 2);
        assert_eq!(route_index("POST", "/sessions"), 3);
        assert_eq!(route_index("GET", "/sessions"), 4);
        assert_eq!(route_index("GET", "/sessions/17"), 5);
        assert_eq!(route_index("GET", "/sessions/17/stats"), 6);
        assert_eq!(route_index("GET", "/sessions/17/embedding"), 7);
        assert_eq!(route_index("GET", "/sessions/17/stream"), 8);
        assert_eq!(route_index("POST", "/sessions/17/commands"), 9);
        assert_eq!(route_index("POST", "/sessions/17/checkpoint"), 10);
        assert_eq!(route_index("DELETE", "/sessions/17"), 11);
        assert_eq!(route_index("PUT", "/sessions/17"), 12);
        assert_eq!(route_index("GET", "/nope"), 12);
        assert_eq!(ROUTES[12], "other");
    }

    #[test]
    fn status_classes_partition_codes() {
        assert_eq!(STATUS_CLASSES[status_class(200)], "2xx");
        assert_eq!(STATUS_CLASSES[status_class(301)], "3xx");
        assert_eq!(STATUS_CLASSES[status_class(404)], "4xx");
        assert_eq!(STATUS_CLASSES[status_class(500)], "5xx");
        assert_eq!(STATUS_CLASSES[status_class(101)], "5xx", "odd codes land in 5xx");
    }

    #[test]
    fn disabled_obs_records_nothing() {
        let obs = Obs::new(false);
        obs.observe_http("GET", "/healthz", 200, 42, 0);
        obs.record_sweep(1, 3, 0, 100);
        let st = StepTrace { iter: 1, ts_us: 0, wall_us: 9, phases: PhaseMicros::default() };
        obs.record_step(1, 1, &st);
        obs.record_frame(5, 400);
        obs.record_queue_depth(2);
        assert!(!obs.enabled());
        assert_eq!(obs.step.snapshot().count(), 0);
        assert_eq!(obs.sweep.snapshot().count(), 0);
        assert_eq!(obs.http_total().count(), 0);
        assert!(obs.http_snapshots().is_empty());
        assert_eq!(obs.tracer_snapshot().0.len(), 0);
    }

    #[test]
    fn enabled_obs_builds_nested_spans() {
        let obs = Obs::new(true);
        let phases = PhaseMicros { forces: 30, update: 10, ..Default::default() };
        let st = StepTrace { iter: 4, ts_us: 100, wall_us: 50, phases };
        obs.record_step(7, 2, &st);
        obs.record_sweep(2, 1, 90, 80);
        let (events, dropped) = obs.tracer_snapshot();
        assert_eq!(dropped, 0);
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["session_step", "forces", "update", "sweep"]);
        let step = &events[0];
        let forces = &events[1];
        let update = &events[2];
        let sweep = &events[3];
        // Time containment: sweep ⊇ step ⊇ phases, phases sequential.
        assert!(sweep.ts_us <= step.ts_us);
        assert!(step.ts_us + step.dur_us <= sweep.ts_us + sweep.dur_us);
        assert_eq!(forces.ts_us, step.ts_us);
        assert_eq!(update.ts_us, forces.ts_us + forces.dur_us);
        assert!(update.ts_us + update.dur_us <= step.ts_us + step.dur_us);
        assert_eq!(step.session, Some(7));
        assert_eq!(step.sweep, Some(2));
        assert_eq!(obs.step.snapshot().count(), 1);
        assert_eq!(obs.step_phase[3].snapshot().count(), 1, "forces phase hist");
    }

    #[test]
    fn http_observation_tags_route_status_and_session() {
        let obs = Obs::new(true);
        obs.observe_http("GET", "/sessions/5/stats", 200, 120, 2);
        obs.observe_http("GET", "/sessions/5/stats", 404, 10, 2);
        obs.observe_http("GET", "/metrics", 200, 50, 0);
        let snaps = obs.http_snapshots();
        assert_eq!(snaps.len(), 3);
        assert!(snaps
            .iter()
            .any(|(r, c, s)| *r == "GET /sessions/:id/stats" && *c == "2xx" && s.count() == 1));
        assert!(snaps.iter().any(|(r, c, _)| *r == "GET /sessions/:id/stats" && *c == "4xx"));
        assert_eq!(obs.http_total().count(), 3);
        let (events, _) = obs.tracer_snapshot();
        assert_eq!(events[0].session, Some(5));
        assert_eq!(events[0].tid, trace::HTTP_TID_BASE + 2);
        assert_eq!(events[2].session, None);
        assert_eq!(events[0].request, Some(1));
        assert_eq!(events[1].request, Some(2));
        assert!(events[0].detail.contains("-> 200"), "{}", events[0].detail);
    }

    #[test]
    fn session_latency_reports_phase_quantiles() {
        let lat = SessionLatency::default();
        let phases = PhaseMicros { forces: 40, ..Default::default() };
        for _ in 0..10 {
            lat.record(&StepTrace { iter: 0, ts_us: 0, wall_us: 90, phases });
        }
        let qs = lat.quantiles();
        let names: Vec<&str> = qs.iter().map(|q| q.phase).collect();
        // Zero-duration phases are recorded (le="1" bucket) so every
        // phase reports once any step ran.
        assert_eq!(
            names,
            vec!["step", "refine_ld", "refine_hd", "recalibrate", "forces", "update"]
        );
        let step = &qs[0];
        assert_eq!(step.samples, 10);
        assert_eq!(step.p50_us, 100.0, "90µs lands in the le=100 bucket");
        let forces = qs.iter().find(|q| q.phase == "forces").expect("forces");
        assert_eq!(forces.p95_us, 50.0);
        assert!(SessionLatency::default().quantiles().is_empty());
    }
}
