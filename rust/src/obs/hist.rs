//! A log-linear fixed-bucket latency histogram with lock-free
//! recording.
//!
//! Buckets follow the classic 1-2-5 decade ladder from 1 to 10⁹
//! (microseconds in practice, but the histogram is unit-agnostic), so
//! boundaries are **deterministic**: every process, thread and run
//! agrees on them, snapshots from different servers merge bucket-by-
//! bucket, and Prometheus `le` labels are stable across restarts.
//! Recording is two relaxed `fetch_add`s — no locks, no allocation —
//! cheap enough to sit on the step hot path when observability is on
//! and to cost exactly one branch when it is off (the caller gates on
//! [`super::Obs::enabled`]).

use std::sync::atomic::{AtomicU64, Ordering};

/// Upper bucket bounds (inclusive), 1-2-5 per decade over 1..=10⁹.
/// Values above the last bound land in the implicit `+Inf` bucket.
pub const BOUNDS: [u64; 28] = [
    1,
    2,
    5,
    10,
    20,
    50,
    100,
    200,
    500,
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    50_000_000,
    100_000_000,
    200_000_000,
    500_000_000,
    1_000_000_000,
];

/// Bucket count including the `+Inf` overflow slot.
const SLOTS: usize = BOUNDS.len() + 1;

/// Fixed-bucket histogram: one atomic counter per bucket plus a sum.
/// Readers take [`Hist::snapshot`]; writers call [`Hist::record`] from
/// any thread.
pub struct Hist {
    counts: [AtomicU64; SLOTS],
    sum: AtomicU64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist { counts: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }

    /// Record one observation. Relaxed ordering is enough: counters are
    /// monotonic telemetry, never synchronisation.
    pub fn record(&self, v: u64) {
        let idx = BOUNDS.partition_point(|&b| b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// A point-in-time copy of the counters. Individual loads are
    /// relaxed, so a snapshot taken mid-record may be off by one
    /// in-flight observation — fine for telemetry.
    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        HistSnapshot { counts, sum: self.sum.load(Ordering::Relaxed) }
    }
}

/// An owned copy of a [`Hist`]'s counters, with quantile estimation and
/// Prometheus rendering.
#[derive(Clone, Debug, Default)]
pub struct HistSnapshot {
    /// Per-bucket (non-cumulative) counts; the last slot is `+Inf`.
    pub counts: Vec<u64>,
    /// Sum of every recorded value.
    pub sum: u64,
}

impl HistSnapshot {
    /// Total observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Merge another snapshot into this one (deterministic bounds mean
    /// buckets align by construction).
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, &c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.sum += other.sum;
    }

    /// Quantile estimate: the upper bound of the bucket holding the
    /// sample at [`quantile_position`]. Saturates at the last finite
    /// bound for observations in the `+Inf` bucket; 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = quantile_position(total as usize, q).floor() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > target {
                let bound = if i < BOUNDS.len() { BOUNDS[i] } else { BOUNDS[BOUNDS.len() - 1] };
                return bound as f64;
            }
        }
        BOUNDS[BOUNDS.len() - 1] as f64
    }

    /// Prometheus text-format sample lines for one histogram label set:
    /// cumulative `_bucket{le=...}` lines ending with `le="+Inf"`, then
    /// `_sum` and `_count`. `labels` is a pre-escaped `k="v",...`
    /// fragment (empty for an unlabelled family).
    pub fn prometheus_lines(&self, name: &str, labels: &str) -> String {
        let mut out = String::new();
        let mut cum = 0u64;
        for i in 0..self.counts.len().max(SLOTS) {
            cum += self.counts.get(i).copied().unwrap_or(0);
            let le = if i < BOUNDS.len() { BOUNDS[i].to_string() } else { "+Inf".to_string() };
            if labels.is_empty() {
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            } else {
                out.push_str(&format!("{name}_bucket{{{labels},le=\"{le}\"}} {cum}\n"));
            }
        }
        let sel = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        out.push_str(&format!("{name}_sum{sel} {}\n", self.sum));
        out.push_str(&format!("{name}_count{sel} {cum}\n"));
        out
    }
}

/// 0-based position of quantile `q` among `count` ordered samples —
/// the single definition shared by [`HistSnapshot::quantile`] and
/// [`quantile_sorted`] (which `util::timer::bench_fn` uses), so a
/// bench median and a histogram p50 mean the same thing.
pub fn quantile_position(count: usize, q: f64) -> f64 {
    q.clamp(0.0, 1.0) * count.saturating_sub(1) as f64
}

/// Linear-interpolation quantile over an ascending-sorted slice:
/// `q=0.5` on an even-length input averages the two middle elements.
/// Returns 0 for empty input.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = quantile_position(sorted.len(), q);
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_strictly_increasing() {
        for w in BOUNDS.windows(2) {
            assert!(w[0] < w[1], "{} !< {}", w[0], w[1]);
        }
        assert_eq!(BOUNDS[0], 1);
        assert_eq!(BOUNDS[BOUNDS.len() - 1], 1_000_000_000);
    }

    #[test]
    fn record_lands_in_the_right_bucket() {
        let h = Hist::new();
        h.record(1); // le="1"
        h.record(3); // le="5"
        h.record(1_000_000_001); // +Inf
        let s = h.snapshot();
        assert_eq!(s.count(), 3);
        assert_eq!(s.sum, 1_000_000_005);
        assert_eq!(s.counts[0], 1);
        assert_eq!(s.counts[2], 1);
        assert_eq!(s.counts[BOUNDS.len()], 1, "+Inf bucket");
    }

    #[test]
    fn quantiles_track_bucket_bounds() {
        let h = Hist::new();
        for _ in 0..90 {
            h.record(40); // le="50"
        }
        for _ in 0..10 {
            h.record(9_000); // le="10000"
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 50.0);
        assert_eq!(s.quantile(0.99), 10_000.0);
        assert_eq!(HistSnapshot::default().quantile(0.5), 0.0);
    }

    #[test]
    fn quantile_sorted_interpolates_median() {
        // Even length: the old bench_fn bug took 3.0 here.
        assert_eq!(quantile_sorted(&[1.0, 2.0, 3.0, 4.0], 0.5), 2.5);
        assert_eq!(quantile_sorted(&[1.0, 2.0, 3.0], 0.5), 2.0);
        assert_eq!(quantile_sorted(&[7.0], 0.95), 7.0);
        assert_eq!(quantile_sorted(&[], 0.5), 0.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((quantile_sorted(&xs, 0.95) - 95.05).abs() < 1e-9);
    }

    #[test]
    fn prometheus_lines_are_cumulative_with_inf() {
        let h = Hist::new();
        h.record(1);
        h.record(3);
        let text = h.snapshot().prometheus_lines("x_micros", "phase=\"forces\"");
        assert!(text.contains("x_micros_bucket{phase=\"forces\",le=\"1\"} 1\n"), "{text}");
        assert!(text.contains("x_micros_bucket{phase=\"forces\",le=\"5\"} 2\n"), "{text}");
        assert!(text.contains("x_micros_bucket{phase=\"forces\",le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("x_micros_sum{phase=\"forces\"} 4\n"), "{text}");
        assert!(text.contains("x_micros_count{phase=\"forces\"} 2\n"), "{text}");
        let bare = h.snapshot().prometheus_lines("y", "");
        assert!(bare.contains("y_bucket{le=\"+Inf\"} 2\n"), "{bare}");
        assert!(bare.contains("y_sum 4\n"), "{bare}");
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = Hist::new();
        let b = Hist::new();
        a.record(10);
        b.record(10);
        b.record(2_000_000_000);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count(), 3);
        assert_eq!(s.counts[3], 2, "both le=10 observations");
    }
}
