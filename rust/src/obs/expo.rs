//! A Prometheus text-exposition-format checker.
//!
//! `/metrics` is hand-rendered (`server/api.rs`), so nothing enforced
//! its grammar until now. [`check_exposition`] validates every line of
//! a scrape body: metric-name and label syntax, label-value escaping,
//! `# HELP` / `# TYPE` preceding their samples, sample names matching
//! the declared family (histograms may only emit `_bucket`/`_sum`/
//! `_count`), and histogram completeness — cumulative, non-decreasing
//! buckets ending in `le="+Inf"` whose value equals `_count`. Tests
//! run it over both the unit-rendered and the live end-to-end scrape.

use std::collections::BTreeMap;

/// Escape a label value per the exposition format (`\\`, `\"`, `\n`).
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

#[derive(Default)]
struct Family {
    help_seen: bool,
    type_seen: bool,
    typ: String,
    samples_seen: bool,
}

/// One parsed `_bucket`/`_sum`/`_count` sample of a histogram family,
/// keyed by its label set minus `le`.
#[derive(Default)]
struct HistogramSeries {
    /// `(le, cumulative count)` in emission order.
    buckets: Vec<(f64, f64)>,
    count: Option<f64>,
    sum_seen: bool,
}

/// Validate a full text-format exposition. Returns every problem found
/// (with 1-based line numbers), or `Ok(())` for a clean scrape.
pub fn check_exposition(text: &str) -> Result<(), Vec<String>> {
    let mut errors = Vec::new();
    let mut families: BTreeMap<String, Family> = BTreeMap::new();
    let mut series: BTreeMap<(String, String), HistogramSeries> = BTreeMap::new();

    for (idx, line) in text.lines().enumerate() {
        let lineno = idx + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            check_comment(rest, lineno, &mut families, &mut errors);
            continue;
        }
        if line.starts_with('#') {
            // Any other comment form is tolerated by scrapers.
            continue;
        }
        check_sample(line, lineno, &mut families, &mut series, &mut errors);
    }

    for ((family, labels), s) in &series {
        let what = if labels.is_empty() {
            family.clone()
        } else {
            format!("{family}{{{labels}}}")
        };
        match s.buckets.last() {
            None => errors.push(format!("histogram {what} has no _bucket samples")),
            Some(&(le, last)) => {
                if le.is_finite() {
                    errors.push(format!("histogram {what} is missing the le=\"+Inf\" bucket"));
                }
                if let Some(count) = s.count {
                    if count != last {
                        errors.push(format!(
                            "histogram {what}: _count {count} != +Inf bucket {last}"
                        ));
                    }
                }
            }
        }
        let mut prev = f64::NEG_INFINITY;
        let mut prev_cum = -1.0f64;
        for &(le, cum) in &s.buckets {
            if le <= prev {
                errors.push(format!("histogram {what}: le buckets not strictly increasing"));
            }
            if cum < prev_cum {
                errors.push(format!("histogram {what}: bucket counts decrease at le={le}"));
            }
            prev = le;
            prev_cum = cum;
        }
        if s.count.is_none() {
            errors.push(format!("histogram {what} has no _count sample"));
        }
        if !s.sum_seen {
            errors.push(format!("histogram {what} has no _sum sample"));
        }
    }

    if errors.is_empty() {
        Ok(())
    } else {
        Err(errors)
    }
}

fn check_comment(
    rest: &str,
    lineno: usize,
    families: &mut BTreeMap<String, Family>,
    errors: &mut Vec<String>,
) {
    let mut parts = rest.splitn(3, ' ');
    let keyword = parts.next().unwrap_or("");
    if keyword != "HELP" && keyword != "TYPE" {
        return; // free-form comment
    }
    let Some(name) = parts.next() else {
        errors.push(format!("line {lineno}: # {keyword} without a metric name"));
        return;
    };
    if !valid_metric_name(name) {
        errors.push(format!("line {lineno}: invalid metric name {name:?} in # {keyword}"));
        return;
    }
    let fam = families.entry(name.to_string()).or_default();
    if fam.samples_seen {
        errors.push(format!("line {lineno}: # {keyword} for {name} after its samples"));
    }
    if keyword == "HELP" {
        fam.help_seen = true;
    } else {
        let typ = parts.next().unwrap_or("").trim();
        if !matches!(typ, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
            errors.push(format!("line {lineno}: unknown metric type {typ:?} for {name}"));
        }
        if fam.type_seen {
            errors.push(format!("line {lineno}: duplicate # TYPE for {name}"));
        }
        fam.type_seen = true;
        fam.typ = typ.to_string();
    }
}

fn check_sample(
    line: &str,
    lineno: usize,
    families: &mut BTreeMap<String, Family>,
    series: &mut BTreeMap<(String, String), HistogramSeries>,
    errors: &mut Vec<String>,
) {
    let (name, rest) = split_name(line);
    if !valid_metric_name(name) {
        errors.push(format!("line {lineno}: invalid sample name in {line:?}"));
        return;
    }
    let (labels, value_text) = match parse_labels(rest) {
        Ok(pair) => pair,
        Err(e) => {
            errors.push(format!("line {lineno}: {e}"));
            return;
        }
    };
    let value_text = value_text.trim();
    // A trailing timestamp is legal; the value is the first field.
    let value_field = value_text.split_whitespace().next().unwrap_or("");
    let Some(value) = parse_value(value_field) else {
        errors.push(format!("line {lineno}: unparseable sample value {value_field:?}"));
        return;
    };

    // Resolve the family: histogram children map to their base name.
    let (family_name, suffix) = match_family(name, families);
    let Some(fam) = families.get_mut(&family_name) else {
        errors.push(format!("line {lineno}: sample {name} has no # HELP/# TYPE"));
        return;
    };
    if !fam.help_seen || !fam.type_seen {
        errors.push(format!(
            "line {lineno}: sample {name} must be preceded by both # HELP and # TYPE"
        ));
    }
    fam.samples_seen = true;
    let is_histogram = fam.typ == "histogram";
    if is_histogram && suffix.is_none() {
        errors.push(format!(
            "line {lineno}: histogram {family_name} may only emit _bucket/_sum/_count"
        ));
        return;
    }
    if !is_histogram && suffix.is_some() {
        // `match_family` only strips suffixes for declared histograms,
        // so this cannot happen; keep the invariant explicit.
        errors.push(format!("line {lineno}: unexpected suffixed sample {name}"));
        return;
    }

    let mut le: Option<f64> = None;
    let mut bare: Vec<String> = Vec::new();
    for (k, v) in &labels {
        if k == "le" {
            le = parse_value(v);
            if le.is_none() {
                errors.push(format!("line {lineno}: unparseable le value {v:?}"));
            }
        } else {
            bare.push(format!("{k}=\"{}\"", escape_label(v)));
        }
    }
    let key = (family_name.clone(), bare.join(","));
    match suffix {
        Some("_bucket") => match le {
            Some(le) => series.entry(key).or_default().buckets.push((le, value)),
            None => errors.push(format!("line {lineno}: _bucket sample without an le label")),
        },
        Some("_count") => series.entry(key).or_default().count = Some(value),
        Some("_sum") => series.entry(key).or_default().sum_seen = true,
        _ => {}
    }
}

/// Split a sample line at the end of the metric name.
fn split_name(line: &str) -> (&str, &str) {
    let end = line
        .char_indices()
        .find(|&(_, c)| !(c.is_ascii_alphanumeric() || c == '_' || c == ':'))
        .map_or(line.len(), |(i, _)| i);
    (&line[..end], &line[end..])
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse an optional `{k="v",...}` block; returns the labels and the
/// remainder of the line (the value).
#[allow(clippy::type_complexity)]
fn parse_labels(rest: &str) -> Result<(Vec<(String, String)>, &str), String> {
    let mut labels = Vec::new();
    let Some(body) = rest.strip_prefix('{') else {
        return Ok((labels, rest));
    };
    let bytes = body.as_bytes();
    let mut i = 0usize;
    loop {
        // Label name.
        let start = i;
        while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
            i += 1;
        }
        let name = &body[start..i];
        if !valid_label_name(name) {
            return Err(format!("invalid label name {name:?}"));
        }
        if bytes.get(i) != Some(&b'=') || bytes.get(i + 1) != Some(&b'"') {
            return Err(format!("label {name} is not followed by =\"...\""));
        }
        i += 2;
        // Quoted value with escapes.
        let mut value = String::new();
        loop {
            match bytes.get(i) {
                None => return Err(format!("unterminated value for label {name}")),
                Some(b'"') => {
                    i += 1;
                    break;
                }
                Some(b'\\') => {
                    match bytes.get(i + 1) {
                        Some(b'\\') => value.push('\\'),
                        Some(b'"') => value.push('"'),
                        Some(b'n') => value.push('\n'),
                        other => {
                            return Err(format!(
                                "bad escape {:?} in label {name}",
                                other.map(|&b| b as char)
                            ))
                        }
                    }
                    i += 2;
                }
                Some(_) => {
                    // Multi-byte UTF-8 is legal in label values; step
                    // one char, not one byte.
                    let c = body[i..].chars().next().ok_or("label value is not UTF-8")?;
                    value.push(c);
                    i += c.len_utf8();
                }
            }
        }
        if labels.iter().any(|(n, _)| n == name) {
            return Err(format!("duplicate label {name}"));
        }
        labels.push((name.to_string(), value));
        match bytes.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => {
                i += 1;
                break;
            }
            other => {
                return Err(format!(
                    "expected ',' or '}}' after label {name}, got {:?}",
                    other.map(|&b| b as char)
                ))
            }
        }
    }
    Ok((labels, &body[i..]))
}

fn parse_value(text: &str) -> Option<f64> {
    match text {
        "+Inf" => Some(f64::INFINITY),
        "-Inf" => Some(f64::NEG_INFINITY),
        "NaN" => Some(f64::NAN),
        _ => text.parse::<f64>().ok(),
    }
}

/// Map a sample name to its declared family. Histogram child suffixes
/// are stripped only when the stripped base is a declared histogram.
fn match_family(name: &str, families: &BTreeMap<String, Family>) -> (String, Option<&'static str>) {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if families.get(base).is_some_and(|f| f.typ == "histogram") {
                return (base.to_string(), Some(suffix));
            }
        }
    }
    (name.to_string(), None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn errs(text: &str) -> Vec<String> {
        check_exposition(text).expect_err("should be rejected")
    }

    #[test]
    fn accepts_a_well_formed_exposition() {
        let text = "\
# HELP x_total Things.\n\
# TYPE x_total counter\n\
x_total 5\n\
# HELP lat_us Latency.\n\
# TYPE lat_us histogram\n\
lat_us_bucket{route=\"GET /a\",le=\"1\"} 1\n\
lat_us_bucket{route=\"GET /a\",le=\"+Inf\"} 3\n\
lat_us_sum{route=\"GET /a\"} 40\n\
lat_us_count{route=\"GET /a\"} 3\n\
# HELP g A gauge.\n\
# TYPE g gauge\n\
g{id=\"1\",state=\"running\"} 1\n";
        assert_eq!(check_exposition(text), Ok(()));
    }

    #[test]
    fn rejects_samples_before_help_and_type() {
        let text = "x_total 5\n# HELP x_total Things.\n# TYPE x_total counter\n";
        let es = errs(text);
        assert!(es.iter().any(|e| e.contains("no # HELP")), "{es:?}");
        assert!(es.iter().any(|e| e.contains("after its samples")), "{es:?}");
    }

    #[test]
    fn rejects_bad_names_labels_and_values() {
        assert!(errs("# HELP 9bad x\n# TYPE 9bad gauge\n").iter().any(|e| e.contains("invalid")));
        let text = "# HELP g x\n# TYPE g gauge\ng{id=\"1\" 2\n";
        assert!(errs(text).iter().any(|e| e.contains("expected ',' or '}'")));
        let text = "# HELP g x\n# TYPE g gauge\ng{id=\"a\\q\"} 2\n";
        assert!(errs(text).iter().any(|e| e.contains("bad escape")));
        let text = "# HELP g x\n# TYPE g gauge\ng nope\n";
        assert!(errs(text).iter().any(|e| e.contains("unparseable sample value")));
        let text = "# HELP g x\n# TYPE g gauge\ng{id=\"1\",id=\"2\"} 2\n";
        assert!(errs(text).iter().any(|e| e.contains("duplicate label")));
    }

    #[test]
    fn escaped_label_values_round_trip() {
        let text = format!(
            "# HELP g x\n# TYPE g gauge\ng{{path=\"{}\"}} 1\n",
            escape_label("a\\b\"c\nd")
        );
        assert_eq!(check_exposition(&text), Ok(()));
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
    }

    #[test]
    fn histogram_must_be_complete_and_cumulative() {
        let head = "# HELP h x\n# TYPE h histogram\n";
        let text = format!("{head}h_bucket{{le=\"1\"}} 1\nh_sum 1\nh_count 1\n");
        assert!(errs(&text).iter().any(|e| e.contains("+Inf")), "missing +Inf");
        let text = format!(
            "{head}h_bucket{{le=\"1\"}} 5\nh_bucket{{le=\"+Inf\"}} 3\nh_sum 1\nh_count 3\n"
        );
        assert!(errs(&text).iter().any(|e| e.contains("decrease")), "non-cumulative");
        let text = format!("{head}h_bucket{{le=\"1\"}} 1\nh_bucket{{le=\"+Inf\"}} 2\nh_sum 3\n");
        assert!(errs(&text).iter().any(|e| e.contains("no _count")), "missing count");
        let text = format!(
            "{head}h_bucket{{le=\"1\"}} 1\nh_bucket{{le=\"+Inf\"}} 2\nh_sum 3\nh_count 9\n"
        );
        assert!(errs(&text).iter().any(|e| e.contains("!= +Inf")), "count mismatch");
        let text = format!("{head}h 3\n");
        assert!(errs(&text).iter().any(|e| e.contains("only emit")), "bare histogram sample");
    }
}
