//! A bounded span tracer with Chrome trace-event export.
//!
//! Events are recorded into a fixed-capacity ring buffer behind a
//! [`DebugMutex`] (its own lock class; never held while taking any
//! other lock) and exported as Chrome trace-event JSON — the
//! `{"traceEvents": [...]}` format Perfetto and `chrome://tracing`
//! load directly. Timestamps are microseconds on a single epoch
//! [`crate::util::timer::PhaseClock`] owned by the [`super::Obs`]
//! registry, so spans recorded by the stepper thread and the HTTP
//! workers share one timeline.
//!
//! Nesting is by time containment per `tid`, the Chrome model for
//! `"ph":"X"` complete events: the stepper thread emits `sweep` spans
//! containing `session_step` spans containing per-phase spans, and
//! each HTTP worker emits one `http` span per request on its own tid.

use crate::runtime::sync::DebugMutex;
use crate::server::json::Json;
use std::collections::VecDeque;

/// `tid` of the stepper thread in exported traces.
pub const STEPPER_TID: u32 = 1;
/// `tid` base for HTTP workers (worker `i` exports as `HTTP_TID_BASE + i`).
pub const HTTP_TID_BASE: u32 = 100;
/// Ring capacity: ~1 MB of events; old events are dropped (and
/// counted) once full, so tracing can stay on indefinitely.
const TRACE_CAPACITY: usize = 16_384;

/// One trace event. `ph` is the Chrome phase: `'X'` for a complete
/// span (`ts` + `dur`), `'i'` for an instant.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Category: `stepper`, `engine` or `http`.
    pub cat: &'static str,
    pub ph: char,
    /// Microseconds since the tracer epoch.
    pub ts_us: u64,
    /// Span duration, µs (0 for instants).
    pub dur_us: u64,
    pub tid: u32,
    /// Session id tag, when the event belongs to one.
    pub session: Option<u64>,
    /// Sweep number tag (stepper-side events).
    pub sweep: Option<u64>,
    /// Request id tag (HTTP events).
    pub request: Option<u64>,
    /// Free-form detail (route, status, iteration, step count).
    pub detail: String,
}

struct Ring {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// The bounded event sink. Callers gate on [`super::Obs::enabled`]
/// before building an event, so a disabled tracer is never touched.
pub struct Tracer {
    ring: DebugMutex<Ring>,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            ring: DebugMutex::new(
                "obs.trace_ring",
                Ring { events: VecDeque::new(), dropped: 0 },
            ),
        }
    }

    /// Append one event, evicting the oldest when full.
    pub fn record(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock();
        if ring.events.len() >= TRACE_CAPACITY {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(ev);
    }

    /// Copy out the buffered events (oldest first) and the count of
    /// events evicted by the ring bound.
    pub fn snapshot(&self) -> (Vec<TraceEvent>, u64) {
        let ring = self.ring.lock();
        (ring.events.iter().cloned().collect(), ring.dropped)
    }
}

/// Render events as a Chrome trace-event JSON document (the "JSON
/// object format": a `traceEvents` array plus metadata). Load it in
/// Perfetto (ui.perfetto.dev) or `chrome://tracing` as-is.
pub fn chrome_trace_json(events: &[TraceEvent], enabled: bool, dropped: u64) -> Json {
    let items: Vec<Json> = events.iter().map(event_json).collect();
    Json::obj(vec![
        ("traceEvents", items.into()),
        ("displayTimeUnit", "ms".into()),
        (
            "otherData",
            Json::obj(vec![
                ("tool", "funcsne".into()),
                ("enabled", enabled.into()),
                ("dropped", dropped.into()),
            ]),
        ),
    ])
}

fn event_json(ev: &TraceEvent) -> Json {
    let mut args: Vec<(&str, Json)> = Vec::new();
    if let Some(s) = ev.session {
        args.push(("session", s.into()));
    }
    if let Some(s) = ev.sweep {
        args.push(("sweep", s.into()));
    }
    if let Some(r) = ev.request {
        args.push(("request", r.into()));
    }
    if !ev.detail.is_empty() {
        args.push(("detail", ev.detail.as_str().into()));
    }
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", ev.name.into()),
        ("cat", ev.cat.into()),
        ("ph", ev.ph.to_string().into()),
        ("ts", (ev.ts_us as f64).into()),
        ("dur", (ev.dur_us as f64).into()),
        ("pid", 1u64.into()),
        ("tid", u64::from(ev.tid).into()),
        ("args", Json::obj(args)),
    ];
    if ev.ph == 'i' {
        // Instant scope: thread-local, the narrowest marker.
        fields.push(("s", "t".into()));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json;

    fn ev(name: &'static str, ts: u64) -> TraceEvent {
        TraceEvent {
            name,
            cat: "stepper",
            ph: 'X',
            ts_us: ts,
            dur_us: 5,
            tid: STEPPER_TID,
            session: Some(3),
            sweep: Some(9),
            request: None,
            detail: String::new(),
        }
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::new();
        for i in 0..(TRACE_CAPACITY as u64 + 10) {
            t.record(ev("sweep", i));
        }
        let (events, dropped) = t.snapshot();
        assert_eq!(events.len(), TRACE_CAPACITY);
        assert_eq!(dropped, 10);
        assert_eq!(events[0].ts_us, 10, "oldest events evicted first");
    }

    #[test]
    fn chrome_json_round_trips_through_the_codec() {
        let t = Tracer::new();
        t.record(ev("sweep", 100));
        t.record(TraceEvent { request: Some(7), cat: "http", ..ev("http", 120) });
        let (events, dropped) = t.snapshot();
        let doc = chrome_trace_json(&events, true, dropped);
        let text = doc.encode();
        let parsed = json::parse(&text).expect("self-encoded trace must parse");
        let items = parsed.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(items.len(), 2);
        let first = &items[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("sweep"));
        assert_eq!(first.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(first.get("ts").and_then(Json::as_f64), Some(100.0));
        let session = first.get("args").and_then(|a| a.get("session"));
        assert_eq!(session.and_then(Json::as_usize), Some(3));
        let request = items[1].get("args").and_then(|a| a.get("request"));
        assert_eq!(request.and_then(Json::as_usize), Some(7));
        let dropped = parsed.get("otherData").and_then(|o| o.get("dropped"));
        assert_eq!(dropped.and_then(Json::as_usize), Some(0));
    }
}
