//! A TOML-subset parser (no external crates available offline).
//!
//! Supported: `[section]` headers, `key = value` with string ("..."),
//! bool, integer, float values, `#` comments, blank lines. Keys are
//! namespaced as `section.key` in the flat map (`key` alone before any
//! section header).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse TOML-subset text into a flat `section.key -> Value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.insert(full, val);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a single scalar value.
pub fn parse_value(s: &str) -> Result<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string {s:?}");
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // Bare strings are accepted (CLI convenience): dataset names etc.
    if !s.is_empty() && s.chars().all(|c| c.is_alphanumeric() || "_-./".contains(c)) {
        return Ok(Value::Str(s.to_string()));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let text = r#"
# top comment
seed = 42
[embed]
alpha = 0.5      # heavy tails
n_iters = 3000
backend = "native"
verbose = true
name = bare_string-ok
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["seed"], Value::Int(42));
        assert_eq!(m["embed.alpha"], Value::Float(0.5));
        assert_eq!(m["embed.n_iters"], Value::Int(3000));
        assert_eq!(m["embed.backend"], Value::Str("native".into()));
        assert_eq!(m["embed.verbose"], Value::Bool(true));
        assert_eq!(m["embed.name"], Value::Str("bare_string-ok".into()));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let m = parse("k = \"a#b\"").unwrap();
        assert_eq!(m["k"], Value::Str("a#b".into()));
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn value_accessors() {
        assert_eq!(parse_value("1").unwrap().as_f64(), Some(1.0));
        assert_eq!(parse_value("1.5").unwrap().as_f64(), Some(1.5));
        assert_eq!(parse_value("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse_value("\"x\"").unwrap().as_str(), Some("x"));
        assert_eq!(parse_value("7").unwrap().as_i64(), Some(7));
    }
}
