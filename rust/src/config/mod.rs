//! Configuration system: typed configs for the engine / KNN / run,
//! loadable from a TOML-subset file and overridable from the CLI.

pub mod toml_lite;
pub mod types;

pub use types::{Backend, EmbedConfig, Init, KnnConfig, RunConfig};
