//! Typed configuration for the engine, KNN subsystem, and run driver.
//!
//! Defaults follow the paper's recommended settings (§3, §4): α = 1
//! (t-SNE-equivalent), perplexity 30, probabilistic HD refinement with
//! base probability 0.05, separated attraction/repulsion with ratio 1,
//! optional early exaggeration and linear-projection jump-start.

use super::toml_lite::{self, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which force-computation backend the coordinator dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust scalar forces (reference + performance baseline).
    Native,
    /// Lane-vectorized pure-Rust forces ([`crate::ld::SimdBackend`]):
    /// bitwise-reproducible at any thread count, approximate (not
    /// bitwise) vs `Native` because lane folds reorder f32 sums.
    Simd,
    /// AOT-compiled XLA executables via PJRT (the three-layer hot path).
    Pjrt,
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "simd" => Ok(Backend::Simd),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown backend {other:?} (native|simd|pjrt)"),
        }
    }
}

/// Embedding initialisation strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Init {
    /// Small random Gaussian.
    Random,
    /// First `ld_dim` principal components (scaled down).
    Pca,
}

/// Hyperparameters of the FUnc-SNE engine.
#[derive(Clone, Debug)]
pub struct EmbedConfig {
    /// Target dimensionality — unconstrained (the paper's headline).
    pub ld_dim: usize,
    /// LD kernel tail-heaviness α (Eq. 4). 1.0 ≡ t-SNE; < 1 heavier.
    pub alpha: f64,
    /// HD Gaussian perplexity (Eq. 1).
    pub perplexity: f64,
    /// Estimated HD neighbour set size.
    pub k_hd: usize,
    /// Estimated LD neighbour set size.
    pub k_ld: usize,
    /// Negative samples per point per iteration (far-field term).
    pub n_neg: usize,
    /// Gradient-descent step size.
    pub lr: f64,
    /// Momentum coefficient.
    pub momentum: f64,
    /// Attraction multiplier (the paper's separated aggregation).
    pub attraction: f64,
    /// Repulsion multiplier.
    pub repulsion: f64,
    /// Early-exaggeration factor applied to attraction.
    pub early_exag: f64,
    /// Iterations during which early exaggeration is active.
    pub early_exag_iters: usize,
    /// Total gradient iterations.
    pub n_iters: usize,
    /// Base probability of running an HD refinement pass
    /// (p = base + (1-base)·E[N_new/N], paper uses 0.05).
    pub refine_base_prob: f64,
    /// EWMA retention for the E[N_new/N] tracker.
    pub refine_ewma_beta: f64,
    /// Candidates proposed per point per refinement, per route
    /// (HD→HD, LD→HD cross, random).
    pub n_candidates: usize,
    /// Iterations of linear-projection jump-start before NE gradients.
    pub jumpstart_iters: usize,
    /// Embedding RMS radius that triggers an automatic "implosion".
    pub implosion_radius: f64,
    /// Scale-down factor applied on implosion.
    pub implosion_factor: f64,
    /// Initialisation strategy.
    pub init: Init,
    /// Force backend. The default honours the `FUNCSNE_BACKEND`
    /// environment variable (`native` / `simd` / `pjrt`, falling back
    /// to `native`), mirroring `FUNCSNE_THREADS` so CI and ad-hoc runs
    /// can flip the whole binary onto the SIMD kernels without code
    /// changes.
    pub backend: Backend,
    /// RNG seed.
    pub seed: u64,
    /// σ_i recalibration cadence (iterations between flag sweeps).
    pub recalibrate_every: usize,
    /// Worker threads for the native compute path. `1` runs everything
    /// sequentially ([`crate::ld::NativeBackend`] + inline engine
    /// passes); `> 1` selects the sharded
    /// [`crate::ld::ParallelBackend`] *and* widens the engine's own
    /// pool, which shards the per-iteration KNN refinement and
    /// negative sampling from counter-based RNG streams; `0`
    /// auto-detects the machine's parallelism. Results are
    /// bitwise-identical at any setting — the knob only changes
    /// wall-clock. The default honours the `FUNCSNE_THREADS`
    /// environment variable (falling back to 1), which is how the CI
    /// matrix runs the whole test suite under both configurations.
    pub threads: usize,
    /// Iterations between online quality-probe measurements
    /// ([`crate::metrics::probe`]); `0` disables the probe entirely
    /// (no anchor state is allocated). The default honours the
    /// `FUNCSNE_PROBE` environment variable (falling back to 0 = off).
    pub probe_every: usize,
    /// Anchor-subset size for the sampled quality probe (clamped to N).
    pub probe_anchors: usize,
}

/// Default worker-thread count: `FUNCSNE_THREADS` if set and parseable,
/// else 1 (sequential).
fn default_threads() -> usize {
    std::env::var("FUNCSNE_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Default quality-probe cadence: `FUNCSNE_PROBE` if set and parseable,
/// else 0 (probe off).
fn default_probe_every() -> usize {
    std::env::var("FUNCSNE_PROBE").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Default force backend: `FUNCSNE_BACKEND` if set and parseable, else
/// [`Backend::Native`].
fn default_backend() -> Backend {
    std::env::var("FUNCSNE_BACKEND").ok().and_then(|v| v.parse().ok()).unwrap_or(Backend::Native)
}

impl Default for EmbedConfig {
    fn default() -> Self {
        EmbedConfig {
            ld_dim: 2,
            alpha: 1.0,
            perplexity: 30.0,
            k_hd: 32,
            k_ld: 16,
            n_neg: 8,
            lr: 0.1,
            momentum: 0.8,
            attraction: 1.0,
            repulsion: 1.0,
            early_exag: 4.0,
            early_exag_iters: 250,
            n_iters: 1500,
            refine_base_prob: 0.05,
            refine_ewma_beta: 0.9,
            n_candidates: 8,
            jumpstart_iters: 100,
            implosion_radius: 50.0,
            implosion_factor: 0.25,
            init: Init::Random,
            backend: default_backend(),
            seed: 42,
            recalibrate_every: 10,
            threads: default_threads(),
            probe_every: default_probe_every(),
            probe_anchors: 256,
        }
    }
}

impl EmbedConfig {
    /// Validate invariants; call after construction / overrides.
    pub fn validate(&self) -> Result<()> {
        if self.ld_dim == 0 {
            bail!("ld_dim must be >= 1");
        }
        if self.ld_dim > 64 {
            bail!("ld_dim must be <= 64 (native fast-path stack buffers)");
        }
        if !(self.alpha > 0.0) {
            bail!("alpha must be > 0 (got {})", self.alpha);
        }
        if !(self.perplexity >= 2.0) {
            bail!("perplexity must be >= 2 (got {})", self.perplexity);
        }
        if self.k_hd < 2 || self.k_ld < 1 {
            bail!("neighbour set sizes too small (k_hd={}, k_ld={})", self.k_hd, self.k_ld);
        }
        if (self.k_hd as f64) < self.perplexity {
            bail!(
                "k_hd ({}) must be >= perplexity ({}) for calibration to succeed",
                self.k_hd,
                self.perplexity
            );
        }
        if !(0.0..=1.0).contains(&self.refine_base_prob) {
            bail!("refine_base_prob must be in [0,1]");
        }
        if !(0.0..1.0).contains(&self.momentum) {
            bail!("momentum must be in [0,1)");
        }
        if self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if self.implosion_factor <= 0.0 || self.implosion_factor >= 1.0 {
            bail!("implosion_factor must be in (0,1)");
        }
        if self.threads > 4096 {
            bail!("threads must be <= 4096 (0 = auto-detect; got {})", self.threads);
        }
        if self.probe_every > 0 && self.probe_anchors == 0 {
            bail!("probe_anchors must be >= 1 when probe_every > 0");
        }
        if self.probe_anchors > 16384 {
            // Anchors are clamped to N at probe construction, so an
            // unbounded request on a large dataset would turn the
            // "sampled" probe into O(N²·d) work on whatever thread owns
            // the session (the server's shared stepper, for one).
            bail!("probe_anchors must be <= 16384 (got {})", self.probe_anchors);
        }
        Ok(())
    }

    /// The worker-thread count with `0` (auto) resolved against the
    /// machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::runtime::pool::available_threads()
        } else {
            self.threads
        }
    }

    /// Apply `section.key` overrides from a parsed TOML-subset map.
    pub fn apply(&mut self, map: &BTreeMap<String, Value>, section: &str) -> Result<()> {
        for (key, val) in map {
            let Some(name) = key.strip_prefix(&format!("{section}.")) else {
                continue;
            };
            self.set(name, val).with_context(|| format!("config key {key}"))?;
        }
        Ok(())
    }

    /// Set a single field by name.
    pub fn set(&mut self, name: &str, val: &Value) -> Result<()> {
        macro_rules! f64_field {
            ($field:ident) => {{
                self.$field = val.as_f64().context("expected number")?;
            }};
        }
        macro_rules! usize_field {
            ($field:ident) => {{
                let v = val.as_i64().context("expected integer")?;
                if v < 0 {
                    bail!("expected non-negative integer");
                }
                self.$field = v as usize;
            }};
        }
        match name {
            "ld_dim" => usize_field!(ld_dim),
            "alpha" => f64_field!(alpha),
            "perplexity" => f64_field!(perplexity),
            "k_hd" => usize_field!(k_hd),
            "k_ld" => usize_field!(k_ld),
            "n_neg" => usize_field!(n_neg),
            "lr" => f64_field!(lr),
            "momentum" => f64_field!(momentum),
            "attraction" => f64_field!(attraction),
            "repulsion" => f64_field!(repulsion),
            "early_exag" => f64_field!(early_exag),
            "early_exag_iters" => usize_field!(early_exag_iters),
            "n_iters" => usize_field!(n_iters),
            "refine_base_prob" => f64_field!(refine_base_prob),
            "refine_ewma_beta" => f64_field!(refine_ewma_beta),
            "n_candidates" => usize_field!(n_candidates),
            "jumpstart_iters" => usize_field!(jumpstart_iters),
            "implosion_radius" => f64_field!(implosion_radius),
            "implosion_factor" => f64_field!(implosion_factor),
            "recalibrate_every" => usize_field!(recalibrate_every),
            "threads" => usize_field!(threads),
            "probe_every" => usize_field!(probe_every),
            "probe_anchors" => usize_field!(probe_anchors),
            "seed" => {
                self.seed = val.as_i64().context("expected integer")? as u64;
            }
            "init" => {
                self.init = match val.as_str().context("expected string")? {
                    "random" => Init::Random,
                    "pca" => Init::Pca,
                    other => bail!("unknown init {other:?} (random|pca)"),
                };
            }
            "backend" => {
                self.backend = val.as_str().context("expected string")?.parse()?;
            }
            other => bail!("unknown embed config key {other:?}"),
        }
        Ok(())
    }
}

/// Configuration of the standalone KNN subsystems (NN-descent and the
/// paper's iterative finder when run outside the engine).
#[derive(Clone, Debug)]
pub struct KnnConfig {
    /// Neighbours per point.
    pub k: usize,
    /// NN-descent sample rate ρ.
    pub rho: f64,
    /// Max NN-descent rounds.
    pub max_rounds: usize,
    /// Convergence threshold: stop when updates < delta·N·K.
    pub delta: f64,
    pub seed: u64,
}

impl Default for KnnConfig {
    fn default() -> Self {
        KnnConfig { k: 32, rho: 0.5, max_rounds: 30, delta: 0.001, seed: 42 }
    }
}

impl KnnConfig {
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            bail!("k must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.rho) {
            bail!("rho must be in [0,1]");
        }
        Ok(())
    }
}

/// Top-level run configuration (dataset + output locations).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub n: usize,
    pub out_dir: String,
    pub verbose: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig { dataset: "blobs".into(), n: 2000, out_dir: "results".into(), verbose: false }
    }
}

/// Load an [`EmbedConfig`] from a TOML-subset file's `[embed]` section.
pub fn load_embed_config(path: &Path) -> Result<EmbedConfig> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let map = toml_lite::parse(&text)?;
    let mut cfg = EmbedConfig::default();
    cfg.apply(&map, "embed")?;
    cfg.validate()?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        EmbedConfig::default().validate().unwrap();
        KnnConfig::default().validate().unwrap();
    }

    #[test]
    fn overrides_apply_from_map() {
        let map = toml_lite::parse(
            "[embed]\nalpha = 0.5\nld_dim = 8\nbackend = \"pjrt\"\ninit = \"pca\"\n",
        )
        .unwrap();
        let mut cfg = EmbedConfig::default();
        cfg.apply(&map, "embed").unwrap();
        assert_eq!(cfg.alpha, 0.5);
        assert_eq!(cfg.ld_dim, 8);
        assert_eq!(cfg.backend, Backend::Pjrt);
        assert_eq!(cfg.init, Init::Pca);
    }

    #[test]
    fn invalid_values_rejected() {
        let mut cfg = EmbedConfig::default();
        cfg.alpha = 0.0;
        assert!(cfg.validate().is_err());
        cfg = EmbedConfig::default();
        cfg.k_hd = 4; // < perplexity
        assert!(cfg.validate().is_err());
        cfg = EmbedConfig::default();
        cfg.momentum = 1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let mut cfg = EmbedConfig::default();
        let v = Value::Int(1);
        assert!(cfg.set("does_not_exist", &v).is_err());
    }

    #[test]
    fn threads_knob_parses_and_resolves() {
        let map = toml_lite::parse("[embed]\nthreads = 4\n").unwrap();
        let mut cfg = EmbedConfig::default();
        cfg.apply(&map, "embed").unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.resolved_threads(), 4);
        cfg.threads = 0; // auto
        cfg.validate().unwrap();
        assert!(cfg.resolved_threads() >= 1);
        cfg.threads = 5000;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn probe_knobs_parse_and_validate() {
        let map = toml_lite::parse("[embed]\nprobe_every = 25\nprobe_anchors = 128\n").unwrap();
        let mut cfg = EmbedConfig::default();
        cfg.apply(&map, "embed").unwrap();
        assert_eq!(cfg.probe_every, 25);
        assert_eq!(cfg.probe_anchors, 128);
        cfg.validate().unwrap();
        cfg.probe_anchors = 0; // invalid only while the probe is on
        assert!(cfg.validate().is_err());
        cfg.probe_every = 0;
        cfg.validate().unwrap();
        cfg.probe_anchors = 1_000_000; // capped even while the probe is off
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn backend_parses() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("simd".parse::<Backend>().unwrap(), Backend::Simd);
        assert_eq!("pjrt".parse::<Backend>().unwrap(), Backend::Pjrt);
        assert!("cuda".parse::<Backend>().is_err());
    }

    #[test]
    fn simd_backend_applies_from_map() {
        let map = toml_lite::parse("[embed]\nbackend = \"simd\"\n").unwrap();
        let mut cfg = EmbedConfig::default();
        cfg.apply(&map, "embed").unwrap();
        assert_eq!(cfg.backend, Backend::Simd);
        cfg.validate().unwrap();
    }
}
