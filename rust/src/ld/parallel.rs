//! Sharded multi-threaded force accumulation — [`NativeBackend`]'s
//! exact semantics, fanned out over a [`WorkerPool`].
//!
//! The force decomposition makes this embarrassingly parallel: every
//! point's `attr`/`rep` rows are written by exactly one shard (contiguous
//! point ranges → disjoint output slices), negative samples are pre-drawn
//! by the engine, and both backends run the *same* per-point kernel —
//! [`crate::ld::forces::forces_range`] — so the result is
//! **bitwise-identical** to [`NativeBackend`] at any thread count, by
//! construction rather than by parallel maintenance of two code paths:
//!
//! * `attr` / `rep` — each row is produced by the shared sequential
//!   per-point accumulation;
//! * `sqdist_batch` — each output element is one independent `sqdist`;
//! * `update` — the gradient/momentum step writes disjoint `y` / `vel`
//!   row chunks through the shared
//!   [`crate::ld::forces::update_range`] kernel, and the implosion Σy²
//!   folds one f64 subtotal per point in point order (same discipline
//!   as `wsum`), so even the implosion decision is partition-free;
//! * [`NegStats::wsum`] — both backends fold one f64 subtotal per point
//!   in point order (shards write their subtotals into a disjoint slice
//!   of a shared scratch vector; the fold happens after the join), so
//!   even the f64 reduction carries no sharding-dependent rounding;
//! * [`NegStats::count`] / [`NegStats::covered`] — exact integers.
//!
//! `rust/tests/parity.rs` asserts all of this bit-for-bit across thread
//! counts. The property matters beyond testing: an embedding run is
//! reproducible from its seed regardless of `--threads`.
//!
//! Small inputs do not shard: below a minimum-work floor per extra
//! shard the scoped-thread fork/join (~tens of µs) costs more than the
//! compute it buys, so the call falls back to fewer shards — possibly
//! inline on the caller's thread. The partition never changes output
//! values, so the floors are pure wall-clock tuning.

use crate::data::matrix::{sqdist, Matrix};
use crate::engine::backend::{ComputeBackend, NegSamples, NegStats};
use crate::hd::Affinities;
use crate::knn::iterative::IterativeKnn;
use crate::ld::forces::{ensure_supported_dim, forces_range, update_range};
use crate::ld::simd::{forces_range_simd, sqdist_lanes, update_range_simd};
use crate::runtime::pool::{self, shard_ranges, WorkerPool};
use anyhow::Result;

/// Which per-point range kernel the shard tasks run. Both variants
/// share the exact same sharding, disjoint-write and point-order-fold
/// plumbing; the choice only swaps the inner math, so each variant is
/// bitwise thread-count-invariant on its own (scalar additionally
/// matches [`NativeBackend`] bit-for-bit; SIMD matches it within
/// lane-reassociation tolerance — see `crate::ld::simd`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum RangeKernel {
    /// The scalar reference kernels ([`forces_range`] /
    /// [`update_range`] / [`sqdist`]).
    Scalar,
    /// The lane-vectorized kernels from [`crate::ld::simd`].
    Simd,
}

/// Default minimum points per shard in `forces` (a point costs roughly
/// a microsecond at typical k_hd + k_ld + n_neg slot counts).
const MIN_POINTS_PER_SHARD: usize = 256;
/// Default minimum candidate pairs per shard in `sqdist_batch` (a pair
/// costs tens of nanoseconds).
const MIN_PAIRS_PER_SHARD: usize = 8192;

/// Multi-threaded [`ComputeBackend`] sharding the native hot paths.
pub struct ParallelBackend {
    pool: WorkerPool,
    min_points_per_shard: usize,
    min_pairs_per_shard: usize,
    /// Per-point negative-slot wsum subtotals, reduced in point order
    /// after the join (reused across calls; no per-call allocation once
    /// warm).
    wsub: Vec<f64>,
    /// Per-point Σ y² subtotals for the sharded `update` pass, reduced
    /// in point order after the join (same discipline as `wsub`).
    ssub: Vec<f64>,
    /// Which inner kernel the shard tasks run (scalar reference vs
    /// lane-vectorized); see [`RangeKernel`].
    kernel: RangeKernel,
}

impl ParallelBackend {
    /// A backend with `threads` workers (`0` = auto-detect from the
    /// machine's available parallelism).
    pub fn new(threads: usize) -> ParallelBackend {
        ParallelBackend {
            pool: WorkerPool::with_auto(threads),
            min_points_per_shard: MIN_POINTS_PER_SHARD,
            min_pairs_per_shard: MIN_PAIRS_PER_SHARD,
            wsub: Vec::new(),
            ssub: Vec::new(),
            kernel: RangeKernel::Scalar,
        }
    }

    /// A backend whose shard tasks run `kernel` instead of the scalar
    /// default — the constructor [`crate::ld::SimdBackend`] wraps.
    pub(crate) fn with_kernel(threads: usize, kernel: RangeKernel) -> ParallelBackend {
        let mut backend = ParallelBackend::new(threads);
        backend.kernel = kernel;
        backend
    }

    /// Override the minimum work per shard (`forces` points /
    /// `sqdist_batch` pairs). Outputs are partition-independent, so
    /// this only tunes wall-clock; the parity tests set `(1, 1)` to
    /// force full sharding on small inputs.
    pub fn with_shard_floors(mut self, min_points: usize, min_pairs: usize) -> ParallelBackend {
        self.min_points_per_shard = min_points.max(1);
        self.min_pairs_per_shard = min_pairs.max(1);
        self
    }

    /// The resolved worker count.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Shards to actually use for `len` items under a per-shard floor
    /// (delegates to the shared [`pool::effective_shards`] formula).
    fn effective_shards(&self, len: usize, min_per_shard: usize) -> usize {
        pool::effective_shards(&self.pool, len, min_per_shard)
    }
}

impl ComputeBackend for ParallelBackend {
    fn sqdist_batch(
        &mut self,
        x: &Matrix,
        owners: &[u32],
        cands: &[u32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        debug_assert_eq!(owners.len(), cands.len());
        let len = owners.len();
        out.clear();
        out.resize(len, 0.0);
        let shards = self.effective_shards(len, self.min_pairs_per_shard);
        let kernel = self.kernel;
        let mut tasks = Vec::new();
        let mut rest: &mut [f32] = out.as_mut_slice();
        for range in shard_ranges(len, shards) {
            let (chunk, tail) = rest.split_at_mut(range.len());
            rest = tail;
            tasks.push(move || {
                let start = range.start;
                for t in range {
                    let (a, b) = (x.row(owners[t] as usize), x.row(cands[t] as usize));
                    chunk[t - start] = match kernel {
                        RangeKernel::Scalar => sqdist(a, b),
                        RangeKernel::Simd => sqdist_lanes(a, b),
                    };
                }
            });
        }
        self.pool.run_tasks(tasks);
        Ok(())
    }

    fn forces(
        &mut self,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
        attr: &mut Matrix,
        rep: &mut Matrix,
    ) -> Result<NegStats> {
        let n = y.n();
        let d = y.d();
        debug_assert_eq!(attr.n(), n);
        debug_assert_eq!(rep.n(), n);
        debug_assert_eq!(attr.d(), d);
        debug_assert_eq!(rep.d(), d);
        ensure_supported_dim(d)?;
        if self.wsub.len() != n {
            // Every slot is written by forces_range below (the ranges
            // cover [0, n)), so stale subtotals never leak; skipping
            // the clear avoids a per-iteration memset.
            self.wsub.clear();
            self.wsub.resize(n, 0.0);
        }
        let shards = self.effective_shards(n, self.min_points_per_shard);
        let kernel = self.kernel;
        let mut tasks = Vec::new();
        let mut attr_rest: &mut [f32] = attr.data_mut();
        let mut rep_rest: &mut [f32] = rep.data_mut();
        let mut wsub_rest: &mut [f64] = self.wsub.as_mut_slice();
        for range in shard_ranges(n, shards) {
            let rows = range.len();
            let (attr_chunk, tail) = attr_rest.split_at_mut(rows * d);
            attr_rest = tail;
            let (rep_chunk, tail) = rep_rest.split_at_mut(rows * d);
            rep_rest = tail;
            let (wsub_chunk, tail) = wsub_rest.split_at_mut(rows);
            wsub_rest = tail;
            tasks.push(move || {
                let start = range.start;
                let on_wsub = |i: usize, wsub: f64| wsub_chunk[i - start] = wsub;
                match kernel {
                    RangeKernel::Scalar => forces_range(
                        y, knn, aff, neg, alpha, far_scale, range, attr_chunk, rep_chunk, on_wsub,
                    ),
                    RangeKernel::Simd => forces_range_simd(
                        y, knn, aff, neg, alpha, far_scale, range, attr_chunk, rep_chunk, on_wsub,
                    ),
                }
            });
        }
        let mut stats = NegStats::default();
        for (count, covered) in self.pool.run_tasks(tasks) {
            stats.count += count;
            stats.covered += covered;
        }
        // Point-order fold of the per-point subtotals: the same f64
        // summation structure as the sequential backend, so `wsum` is
        // independent of the shard partition.
        for &w in &self.wsub {
            stats.wsum += w;
        }
        Ok(stats)
    }

    fn update(
        &mut self,
        y: &mut Matrix,
        vel: &mut Matrix,
        attr: &Matrix,
        rep: &Matrix,
        a_mult: f32,
        r_mult: f32,
        lr: f32,
        mom: f32,
    ) -> Result<f64> {
        let n = y.n();
        let d = y.d();
        debug_assert_eq!(vel.n(), n);
        debug_assert_eq!(attr.n(), n);
        debug_assert_eq!(rep.n(), n);
        if self.ssub.len() != n {
            // Same skip-clear discipline as `wsub`: update_range writes
            // every slot, so only a size change needs a reset.
            self.ssub.clear();
            self.ssub.resize(n, 0.0);
        }
        let shards = self.effective_shards(n, self.min_points_per_shard);
        let kernel = self.kernel;
        let mut tasks = Vec::new();
        let mut y_rest: &mut [f32] = y.data_mut();
        let mut v_rest: &mut [f32] = vel.data_mut();
        let mut s_rest: &mut [f64] = self.ssub.as_mut_slice();
        let attr_all = attr.data();
        let rep_all = rep.data();
        for range in shard_ranges(n, shards) {
            let rows = range.len();
            let (y_chunk, tail) = y_rest.split_at_mut(rows * d);
            y_rest = tail;
            let (v_chunk, tail) = v_rest.split_at_mut(rows * d);
            v_rest = tail;
            let (s_chunk, tail) = s_rest.split_at_mut(rows);
            s_rest = tail;
            let a_chunk = &attr_all[range.start * d..range.end * d];
            let r_chunk = &rep_all[range.start * d..range.end * d];
            let start = range.start;
            tasks.push(move || {
                let on_ss = |i: usize, ss: f64| s_chunk[i - start] = ss;
                match kernel {
                    RangeKernel::Scalar => update_range(
                        range, d, y_chunk, v_chunk, a_chunk, r_chunk, a_mult, r_mult, lr, mom,
                        on_ss,
                    ),
                    RangeKernel::Simd => update_range_simd(
                        range, d, y_chunk, v_chunk, a_chunk, r_chunk, a_mult, r_mult, lr, mom,
                        on_ss,
                    ),
                }
            });
        }
        self.pool.run_tasks(tasks);
        // Point-order fold: the same f64 summation structure as the
        // sequential default, so the implosion decision is independent
        // of the shard partition.
        let mut total = 0.0f64;
        for &s in &self.ssub {
            total += s;
        }
        Ok(total)
    }

    fn name(&self) -> &'static str {
        "parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;
    use crate::ld::forces::NativeBackend;
    use crate::util::Rng;

    fn setup(n: usize, d_ld: usize, seed: u64) -> (Matrix, IterativeKnn, Affinities) {
        let ds = datasets::blobs(n, 5, 3, 0.6, 8.0, seed);
        let mut rng = Rng::new(seed ^ 1);
        let mut yv = Vec::with_capacity(n * d_ld);
        for _ in 0..n * d_ld {
            yv.push(rng.gauss_ms(0.0, 1.0) as f32);
        }
        let y = Matrix::from_vec(yv, n, d_ld).unwrap();
        let k = 8.min(n - 1);
        let exact = brute_knn(&ds.x, k);
        let mut knn = IterativeKnn::new(n, k, k);
        for i in 0..n {
            for (j, dd) in exact.entries(i) {
                knn.hd.insert(i, j, dd);
            }
        }
        let exact_ld = brute_knn(&y, k);
        for i in 0..n {
            for (j, dd) in exact_ld.entries(i) {
                knn.ld.insert(i, j, dd);
            }
        }
        let mut aff = Affinities::new(n, k);
        aff.recalibrate_all(&mut knn, 5.0);
        (y, knn, aff)
    }

    #[test]
    fn forces_bitwise_match_native_across_thread_counts() {
        // Odd n so shards are uneven; threads > n exercises clamping.
        // Floors are dropped to (1, 1) so these small inputs really do
        // fan out across shards.
        for &n in &[97usize, 130] {
            let (y, knn, aff) = setup(n, 3, 11);
            let mut rng = Rng::new(42);
            let neg = NegSamples::draw(n, 6, &mut rng);
            let mut native = NativeBackend::new();
            let (mut a0, mut r0) = (Matrix::zeros(n, 3), Matrix::zeros(n, 3));
            let s0 = native.forces(&y, &knn, &aff, &neg, 0.7, 9.5, &mut a0, &mut r0).unwrap();
            for threads in [1usize, 2, 3, 8, 200] {
                let mut par = ParallelBackend::new(threads).with_shard_floors(1, 1);
                let (mut a1, mut r1) = (Matrix::zeros(n, 3), Matrix::zeros(n, 3));
                let s1 = par.forces(&y, &knn, &aff, &neg, 0.7, 9.5, &mut a1, &mut r1).unwrap();
                for (u, v) in a0.data().iter().zip(a1.data()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "attr differs at {threads} threads");
                }
                for (u, v) in r0.data().iter().zip(r1.data()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "rep differs at {threads} threads");
                }
                assert_eq!(s0.wsum.to_bits(), s1.wsum.to_bits(), "wsum at {threads} threads");
                assert_eq!(s0.count, s1.count);
                assert_eq!(s0.covered, s1.covered);
            }
        }
    }

    #[test]
    fn default_floors_fall_back_to_fewer_shards_with_identical_results() {
        // Under the production floors a 130-point pass runs on a single
        // shard — and must still match native exactly.
        let n = 130usize;
        let (y, knn, aff) = setup(n, 2, 13);
        let mut rng = Rng::new(7);
        let neg = NegSamples::draw(n, 4, &mut rng);
        let mut native = NativeBackend::new();
        let (mut a0, mut r0) = (Matrix::zeros(n, 2), Matrix::zeros(n, 2));
        let s0 = native.forces(&y, &knn, &aff, &neg, 1.0, 3.0, &mut a0, &mut r0).unwrap();
        let mut par = ParallelBackend::new(4);
        assert_eq!(par.effective_shards(n, 256), 1, "floor must collapse tiny inputs");
        let (mut a1, mut r1) = (Matrix::zeros(n, 2), Matrix::zeros(n, 2));
        let s1 = par.forces(&y, &knn, &aff, &neg, 1.0, 3.0, &mut a1, &mut r1).unwrap();
        assert_eq!(a0.data(), a1.data());
        assert_eq!(r0.data(), r1.data());
        assert_eq!(s0.wsum.to_bits(), s1.wsum.to_bits());
    }

    #[test]
    fn update_bitwise_matches_native_across_thread_counts() {
        // The default (sequential) trait implementation vs the sharded
        // override: y, vel and the Σy² fold must agree bit-for-bit, so
        // the implosion decision can never depend on --threads.
        for &n in &[97usize, 513] {
            let d = 3usize;
            let mut rng = Rng::new(19);
            let mk = |rng: &mut Rng| -> Matrix {
                let v: Vec<f32> = (0..n * d).map(|_| rng.gauss_ms(0.0, 1.0) as f32).collect();
                Matrix::from_vec(v, n, d).unwrap()
            };
            let y0 = mk(&mut rng);
            let v0 = mk(&mut rng);
            let attr = mk(&mut rng);
            let rep = mk(&mut rng);
            let (a_mult, r_mult, lr, mom) = (2.0f32, 0.03f32, 0.1f32, 0.8f32);
            let mut native = NativeBackend::new();
            let (mut y1, mut v1) = (y0.clone(), v0.clone());
            let ss1 =
                native.update(&mut y1, &mut v1, &attr, &rep, a_mult, r_mult, lr, mom).unwrap();
            for threads in [1usize, 2, 4, 9] {
                let mut par = ParallelBackend::new(threads).with_shard_floors(1, 1);
                let (mut y2, mut v2) = (y0.clone(), v0.clone());
                let ss2 =
                    par.update(&mut y2, &mut v2, &attr, &rep, a_mult, r_mult, lr, mom).unwrap();
                assert_eq!(ss1.to_bits(), ss2.to_bits(), "Σy² differs at {threads} threads");
                for (a, b) in y1.data().iter().zip(y2.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "y differs at {threads} threads");
                }
                for (a, b) in v1.data().iter().zip(v2.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "vel differs at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn sqdist_bitwise_matches_native() {
        let ds = datasets::blobs(50, 7, 2, 1.0, 5.0, 9);
        let owners: Vec<u32> = (0..37).collect();
        let cands: Vec<u32> = (10..47).collect();
        let mut native = NativeBackend::new();
        let mut o0 = Vec::new();
        native.sqdist_batch(&ds.x, &owners, &cands, &mut o0).unwrap();
        for threads in [1usize, 2, 4] {
            let mut par = ParallelBackend::new(threads).with_shard_floors(1, 1);
            let mut o1 = Vec::new();
            par.sqdist_batch(&ds.x, &owners, &cands, &mut o1).unwrap();
            assert_eq!(o0.len(), o1.len());
            for (u, v) in o0.iter().zip(&o1) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn empty_batches_are_fine() {
        let mut par = ParallelBackend::new(4).with_shard_floors(1, 1);
        let x = Matrix::zeros(4, 3);
        let mut out = vec![1.0f32];
        par.sqdist_batch(&x, &[], &[], &mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn too_wide_ld_dim_is_a_checked_error() {
        let y = Matrix::zeros(4, 65);
        let knn = IterativeKnn::new(4, 2, 2);
        let aff = Affinities::new(4, 2);
        let neg = NegSamples { m: 0, idx: vec![] };
        let mut par = ParallelBackend::new(2);
        let (mut attr, mut rep) = (Matrix::zeros(4, 65), Matrix::zeros(4, 65));
        let err = par.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap_err();
        assert!(format!("{err:?}").contains("64"), "{err:?}");
    }

    #[test]
    fn zero_threads_auto_detects() {
        assert!(ParallelBackend::new(0).threads() >= 1);
        assert_eq!(ParallelBackend::new(3).threads(), 3);
    }
}
