//! The heavy-tailed LD similarity kernel of Kobak et al. [10], Eq. 4:
//!
//! ```text
//! w_ij = (1 + ||y_i - y_j||² / α)^(-α)
//! ```
//!
//! α = 1 recovers t-SNE's Student-t kernel; α < 1 gives heavier tails
//! (finer cluster fragmentation); α → ∞ approaches a Gaussian.
//!
//! A pleasant identity keeps the gradient cheap: the gradient factor of
//! Eq. 5 is `w^{1/α} = (1 + d²/α)^{-1}` — *independent of the exponent*,
//! so one reciprocal serves every α.

/// Gradient factor g = w^{1/α} = 1 / (1 + d²/α).
#[inline(always)]
pub fn grad_factor(sq_dist: f32, alpha: f32) -> f32 {
    1.0 / (1.0 + sq_dist / alpha)
}

/// Kernel value w = (1 + d²/α)^{-α} = g^α.
#[inline(always)]
pub fn kernel_w(sq_dist: f32, alpha: f32) -> f32 {
    let g = grad_factor(sq_dist, alpha);
    if alpha == 1.0 {
        g // t-SNE fast path (the default)
    } else {
        g.powf(alpha)
    }
}

/// Both values at once (the force loops need both).
#[inline(always)]
pub fn kernel_pair(sq_dist: f32, alpha: f32) -> (f32, f32) {
    let g = grad_factor(sq_dist, alpha);
    let w = if alpha == 1.0 { g } else { g.powf(alpha) };
    (w, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    #[test]
    fn alpha_one_matches_student_t() {
        for d2 in [0.0f32, 0.5, 1.0, 4.0, 100.0] {
            let w = kernel_w(d2, 1.0);
            assert!((w - 1.0 / (1.0 + d2)).abs() < 1e-7);
            assert!((grad_factor(d2, 1.0) - w).abs() < 1e-7);
        }
    }

    #[test]
    fn heavier_tails_for_smaller_alpha() {
        // At large distance, smaller α must give larger w (heavier tail).
        let d2 = 25.0f32;
        let w_heavy = kernel_w(d2, 0.3);
        let w_t = kernel_w(d2, 1.0);
        let w_light = kernel_w(d2, 4.0);
        assert!(w_heavy > w_t && w_t > w_light, "{w_heavy} {w_t} {w_light}");
    }

    #[test]
    fn kernel_properties() {
        pt::check("kernel-props", 64, |rng, _| {
            let alpha = (rng.f32() * 4.0 + 0.05).min(4.0);
            let d2 = rng.f32() * 50.0;
            let (w, g) = kernel_pair(d2, alpha);
            crate::prop_assert!((0.0..=1.0).contains(&w), "w out of range: {w}");
            crate::prop_assert!((0.0..=1.0).contains(&g), "g out of range: {g}");
            crate::prop_assert!(
                (kernel_w(0.0, alpha) - 1.0).abs() < 1e-6,
                "w(0) != 1"
            );
            // w = g^α identity
            crate::prop_assert!(
                (w - g.powf(alpha)).abs() < 1e-5,
                "identity broken: w={w} g^a={}",
                g.powf(alpha)
            );
            // monotone decreasing in d²
            let w2 = kernel_w(d2 + 1.0, alpha);
            crate::prop_assert!(w2 <= w + 1e-7, "not monotone");
            Ok(())
        });
    }

    #[test]
    fn gaussian_limit_for_large_alpha() {
        // (1 + d²/α)^(-α) → exp(-d²) as α → ∞.
        let d2 = 1.5f32;
        let w = kernel_w(d2, 512.0);
        assert!((w - (-d2).exp()).abs() < 5e-3, "w={w} vs {}", (-d2).exp());
    }
}
