//! Low-dimensional side: the heavy-tailed similarity kernel and the
//! native force accumulation backends (sequential reference + the
//! sharded multi-threaded variant, bitwise-identical to it).

pub mod kernel;
pub mod forces;
pub mod parallel;

pub use forces::NativeBackend;
pub use parallel::ParallelBackend;
