//! Low-dimensional side: the heavy-tailed similarity kernel and the
//! native force accumulation backends — the sequential scalar
//! reference, the sharded multi-threaded variant (bitwise-identical to
//! it), and the lane-vectorized SIMD variant (bitwise-invariant across
//! thread counts, approximate vs the scalar pair).

pub mod kernel;
pub mod forces;
pub mod parallel;
pub mod simd;

pub use forces::NativeBackend;
pub use parallel::ParallelBackend;
pub use simd::SimdBackend;
