//! Low-dimensional side: the heavy-tailed similarity kernel and the
//! native force accumulation backend.

pub mod kernel;
pub mod forces;

pub use forces::NativeBackend;
