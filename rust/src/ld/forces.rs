//! Native (pure Rust) force accumulation — the reference semantics for
//! the Eq. 5/6 gradient and the performance baseline against which the
//! PJRT tile path is parity-tested and benchmarked.
//!
//! Per point i (Eq. 6 decomposition):
//!
//! 1. **HD slots** (attraction + close repulsion): for each stored HD
//!    neighbour j with conditional p_{j|i}:
//!    `attr_i += p·g·(y_j − y_i)` and `rep_i += w·g·(y_i − y_j)`.
//! 2. **LD slots** (the paper's novel close-range repulsion): for each
//!    estimated LD neighbour j *not in the HD set*:
//!    `rep_i += w·g·(y_i − y_j)`.
//! 3. **Negative samples** (far field): same repulsion expression,
//!    accumulated separately by the engine's scaling, and contributing
//!    to the Z-estimate statistics.
//!
//! The repulsion accumulated here is *unnormalised* (no division by Z);
//! the engine multiplies by its running `1/((N−1)·E[w])` estimate,
//! reproducing q_ij = w_ij / Z up to the far-field scaling documented in
//! DESIGN.md.

use crate::data::matrix::{sqdist, Matrix};
use crate::engine::backend::{ComputeBackend, NegSamples, NegStats};
use crate::hd::Affinities;
use crate::knn::iterative::IterativeKnn;
use crate::ld::kernel::kernel_pair;
use anyhow::Result;
use std::ops::Range;

/// The pure-Rust backend (no per-call allocation).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

/// The embedding-dimension ceiling of the stack-buffer fast path below.
/// [`crate::config::EmbedConfig::validate`] enforces the same bound as
/// `ld_dim <= 64`.
pub(crate) fn ensure_supported_dim(d: usize) -> Result<()> {
    anyhow::ensure!(
        d <= 64,
        "LD dim {d} > 64 unsupported by the native force path (EmbedConfig enforces ld_dim <= 64)"
    );
    Ok(())
}

/// Accumulate the Eq. 6 force decomposition for every point in `range`:
/// row `i` is written (fully overwritten) at offset
/// `(i - range.start) * d` of `attr_out` / `rep_out`, and each point's
/// negative-slot f64 wsum subtotal is reported through
/// `on_wsub(i, subtotal)` in point order. Returns `(count, covered)`.
///
/// This is the **single source of truth** for the per-point force math:
/// [`NativeBackend`] runs it over `0..n` on the calling thread, and
/// [`crate::ld::ParallelBackend`] runs it per shard over disjoint
/// ranges — which is what makes the two backends bitwise-identical by
/// construction rather than by parallel maintenance of two copies.
///
/// §Perf: each point's attraction/repulsion accumulates in small stack
/// buffers and is written back once — repeated slicing of the output
/// inside the slot loops cost ~35% of the pass (bounds checks + lost
/// register allocation). The buffers are 64-wide; callers must check
/// [`ensure_supported_dim`] first.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forces_range(
    y: &Matrix,
    knn: &IterativeKnn,
    aff: &Affinities,
    neg: &NegSamples,
    alpha: f32,
    far_scale: f32,
    range: Range<usize>,
    attr_out: &mut [f32],
    rep_out: &mut [f32],
    mut on_wsub: impl FnMut(usize, f64),
) -> (usize, usize) {
    let d = y.d();
    debug_assert!(d <= 64, "call ensure_supported_dim first");
    let start = range.start;
    debug_assert!(attr_out.len() >= range.len() * d);
    debug_assert!(rep_out.len() >= range.len() * d);
    let mut count = 0usize;
    let mut covered = 0usize;
    let mut yi_buf = [0.0f32; 64];
    let mut acc_a = [0.0f32; 64];
    let mut acc_r = [0.0f32; 64];
    for i in range {
        let yi_start = i * d;
        yi_buf[..d].copy_from_slice(&y.data()[yi_start..yi_start + d]);
        let yi = &yi_buf[..d];
        acc_a[..d].iter_mut().for_each(|v| *v = 0.0);
        acc_r[..d].iter_mut().for_each(|v| *v = 0.0);
        // --- 1. HD slots: attraction + close repulsion ------------
        for (s, (j, _hd_dist)) in knn.hd.entries(i).enumerate() {
            let p = aff.p_slot(i, s);
            let yj = y.row(j as usize);
            let d2 = sqdist(yi, yj);
            let (w, g) = kernel_pair(d2, alpha);
            let ag = p * g;
            let rg = w * g;
            for k in 0..d {
                let delta = yj[k] - yi[k];
                acc_a[k] += ag * delta;
                acc_r[k] -= rg * delta;
            }
            covered += 1;
        }
        // --- 2. LD slots not in the HD set: close repulsion -------
        for (j, _stale) in knn.ld.entries(i) {
            if knn.hd.contains(i, j) {
                continue; // already covered by term 1 (not re-counted)
            }
            let yj = y.row(j as usize);
            let d2 = sqdist(yi, yj);
            let (w, g) = kernel_pair(d2, alpha);
            let rg = w * g;
            for k in 0..d {
                acc_r[k] += rg * (yi[k] - yj[k]);
            }
            covered += 1;
        }
        // --- 3. Negative samples: far field ------------------------
        // One f64 subtotal per point, handed to the caller in point
        // order — the summation structure that keeps wsum independent
        // of how callers shard the range.
        let mut wsub = 0.0f64;
        for &j in neg.row(i) {
            let yj = y.row(j as usize);
            let d2 = sqdist(yi, yj);
            let (w, g) = kernel_pair(d2, alpha);
            wsub += w as f64;
            count += 1;
            let rg = w * g * far_scale;
            for k in 0..d {
                acc_r[k] += rg * (yi[k] - yj[k]);
            }
        }
        on_wsub(i, wsub);
        let off = (i - start) * d;
        attr_out[off..off + d].copy_from_slice(&acc_a[..d]);
        rep_out[off..off + d].copy_from_slice(&acc_r[..d]);
    }
    (count, covered)
}

/// The gradient/momentum update for every point in `range`, with the
/// implosion-RMS reduction fused in: for each coordinate
/// `v = mom·v + lr·(a_mult·attr + r_mult·rep)`, then `y += v`. Row `i`
/// of `y_out` / `vel_out` (and of the `attr` / `rep` inputs) lives at
/// offset `(i - range.start) * d`; each point's post-update Σ y² f64
/// subtotal is reported through `on_ss(i, subtotal)` in point order.
///
/// Like [`forces_range`], this is the single source of truth shared by
/// the sequential default ([`ComputeBackend::update`]) and the sharded
/// override ([`crate::ld::ParallelBackend`]), which is what makes the
/// update — and the implosion decision derived from the fold — bitwise
/// thread-count-invariant.
#[allow(clippy::too_many_arguments)]
pub(crate) fn update_range(
    range: Range<usize>,
    d: usize,
    y_out: &mut [f32],
    vel_out: &mut [f32],
    attr: &[f32],
    rep: &[f32],
    a_mult: f32,
    r_mult: f32,
    lr: f32,
    mom: f32,
    mut on_ss: impl FnMut(usize, f64),
) {
    let start = range.start;
    debug_assert!(y_out.len() >= range.len() * d);
    debug_assert!(vel_out.len() >= range.len() * d);
    debug_assert!(attr.len() >= range.len() * d);
    debug_assert!(rep.len() >= range.len() * d);
    for i in range {
        let off = (i - start) * d;
        let mut ss = 0.0f64;
        for t in off..off + d {
            let grad = a_mult * attr[t] + r_mult * rep[t];
            vel_out[t] = mom * vel_out[t] + lr * grad;
            y_out[t] += vel_out[t];
            ss += (y_out[t] as f64) * (y_out[t] as f64);
        }
        on_ss(i, ss);
    }
}

impl ComputeBackend for NativeBackend {
    fn sqdist_batch(
        &mut self,
        x: &Matrix,
        owners: &[u32],
        cands: &[u32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        debug_assert_eq!(owners.len(), cands.len());
        out.clear();
        out.reserve(owners.len());
        for (&i, &j) in owners.iter().zip(cands) {
            out.push(sqdist(x.row(i as usize), x.row(j as usize)));
        }
        Ok(())
    }

    fn forces(
        &mut self,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
        attr: &mut Matrix,
        rep: &mut Matrix,
    ) -> Result<NegStats> {
        let n = y.n();
        debug_assert_eq!(attr.n(), n);
        debug_assert_eq!(rep.n(), n);
        ensure_supported_dim(y.d())?;
        // Every row in 0..n is fully overwritten by `forces_range`, and
        // the per-point wsum subtotals fold in point order — the exact
        // structure the sharded backend reproduces, so both are
        // bitwise-identical.
        let mut wsum = 0.0f64;
        let (count, covered) = forces_range(
            y,
            knn,
            aff,
            neg,
            alpha,
            far_scale,
            0..n,
            attr.data_mut(),
            rep.data_mut(),
            |_, wsub| wsum += wsub,
        );
        Ok(NegStats { wsum, count, covered })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;
    use crate::util::Rng;

    fn setup(n: usize, d_ld: usize, seed: u64) -> (Matrix, Matrix, IterativeKnn, Affinities) {
        let ds = datasets::blobs(n, 5, 3, 0.6, 8.0, seed);
        let mut rng = Rng::new(seed ^ 1);
        let mut yv = Vec::with_capacity(n * d_ld);
        for _ in 0..n * d_ld {
            yv.push(rng.gauss_ms(0.0, 1.0) as f32);
        }
        let y = Matrix::from_vec(yv, n, d_ld).unwrap();
        let k = 8;
        let exact = brute_knn(&ds.x, k);
        let mut knn = IterativeKnn::new(n, k, k);
        for i in 0..n {
            for (j, dd) in exact.entries(i) {
                knn.hd.insert(i, j, dd);
            }
            knn.ld.rescore(i, |_| 0.0);
        }
        // LD table: exact LD neighbours for determinism.
        let exact_ld = brute_knn(&y, k);
        for i in 0..n {
            for (j, dd) in exact_ld.entries(i) {
                knn.ld.insert(i, j, dd);
            }
        }
        let mut aff = Affinities::new(n, k);
        aff.recalibrate_all(&mut knn, 5.0);
        (ds.x, y, knn, aff)
    }

    /// Exhaustive O(N²) oracle computing the same decomposition.
    fn oracle(
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
    ) -> (Matrix, Matrix, NegStats) {
        let n = y.n();
        let d = y.d();
        let mut attr = Matrix::zeros(n, d);
        let mut rep = Matrix::zeros(n, d);
        let mut stats = NegStats::default();
        for i in 0..n {
            for (s, (j, _)) in knn.hd.entries(i).enumerate() {
                let p = aff.p_slot(i, s);
                let d2 = y.sqdist(i, j as usize);
                let (w, g) = kernel_pair(d2, alpha);
                for k in 0..d {
                    let delta = y.row(j as usize)[k] - y.row(i)[k];
                    attr.data_mut()[i * d + k] += p * g * delta;
                    rep.data_mut()[i * d + k] += w * g * (-delta);
                }
                stats.covered += 1;
            }
            for (j, _) in knn.ld.entries(i) {
                if knn.hd.contains(i, j) {
                    continue;
                }
                stats.covered += 1;
                let d2 = y.sqdist(i, j as usize);
                let (w, g) = kernel_pair(d2, alpha);
                for k in 0..d {
                    let delta = y.row(i)[k] - y.row(j as usize)[k];
                    rep.data_mut()[i * d + k] += w * g * delta;
                }
            }
            for &j in neg.row(i) {
                let d2 = y.sqdist(i, j as usize);
                let (w, g) = kernel_pair(d2, alpha);
                stats.wsum += w as f64;
                stats.count += 1;
                for k in 0..d {
                    let delta = y.row(i)[k] - y.row(j as usize)[k];
                    rep.data_mut()[i * d + k] += w * g * far_scale * delta;
                }
            }
        }
        (attr, rep, stats)
    }

    #[test]
    fn native_matches_oracle() {
        for &alpha in &[0.5f32, 1.0, 2.0] {
            let (x, y, knn, aff) = setup(120, 2, 7);
            let _ = x;
            let mut rng = Rng::new(42);
            let neg = NegSamples::draw(120, 6, &mut rng);
            let mut backend = NativeBackend::new();
            let mut attr = Matrix::zeros(120, 2);
            let mut rep = Matrix::zeros(120, 2);
            let far_scale = 13.5f32; // non-trivial to exercise the scaling
            let stats = backend
                .forces(&y, &knn, &aff, &neg, alpha, far_scale, &mut attr, &mut rep)
                .unwrap();
            let (eattr, erep, estats) = oracle(&y, &knn, &aff, &neg, alpha, far_scale);
            for (a, b) in attr.data().iter().zip(eattr.data()) {
                assert!((a - b).abs() < 1e-5, "attr mismatch {a} vs {b} (alpha={alpha})");
            }
            for (a, b) in rep.data().iter().zip(erep.data()) {
                assert!((a - b).abs() < 1e-4, "rep mismatch {a} vs {b} (alpha={alpha})");
            }
            assert!((stats.wsum - estats.wsum).abs() < 1e-6);
            assert_eq!(stats.count, estats.count);
            assert_eq!(stats.covered, estats.covered, "covered-pair count mismatch");
        }
    }

    #[test]
    fn attraction_points_toward_neighbours() {
        // Two points, neighbour of each other, far apart in LD:
        // attraction on 0 must point toward 1.
        let y = Matrix::from_vec(vec![0.0, 0.0, 10.0, 0.0], 2, 2).unwrap();
        let mut knn = IterativeKnn::new(2, 1, 1);
        knn.hd.insert(0, 1, 1.0);
        knn.hd.insert(1, 0, 1.0);
        let mut aff = Affinities::new(2, 1);
        aff.recalibrate_all(&mut knn, 2.0);
        let neg = NegSamples { m: 0, idx: vec![] };
        let mut backend = NativeBackend::new();
        let (mut attr, mut rep) = (Matrix::zeros(2, 2), Matrix::zeros(2, 2));
        backend.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap();
        assert!(attr.row(0)[0] > 0.0, "attraction should pull 0 toward +x");
        assert!(attr.row(1)[0] < 0.0);
        // Repulsion pushes apart.
        assert!(rep.row(0)[0] < 0.0);
        assert!(rep.row(1)[0] > 0.0);
    }

    #[test]
    fn sqdist_batch_matches_direct() {
        let ds = datasets::blobs(50, 7, 2, 1.0, 5.0, 9);
        let mut backend = NativeBackend::new();
        let owners: Vec<u32> = (0..30).collect();
        let cands: Vec<u32> = (10..40).collect();
        let mut out = Vec::new();
        backend.sqdist_batch(&ds.x, &owners, &cands, &mut out).unwrap();
        for t in 0..30 {
            let expect = ds.x.sqdist(owners[t] as usize, cands[t] as usize);
            assert!((out[t] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn ld_slots_excluded_when_in_hd() {
        // Single pair present in both tables: rep must count it once.
        let y = Matrix::from_vec(vec![0.0, 0.0, 1.0, 0.0], 2, 2).unwrap();
        let mut knn = IterativeKnn::new(2, 1, 1);
        knn.hd.insert(0, 1, 1.0);
        knn.ld.insert(0, 1, 1.0);
        let mut aff = Affinities::new(2, 1);
        aff.recalibrate_all(&mut knn, 2.0);
        let neg = NegSamples { m: 0, idx: vec![] };
        let mut b = NativeBackend::new();
        let (mut attr, mut rep) = (Matrix::zeros(2, 2), Matrix::zeros(2, 2));
        b.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap();
        let (w, g) = kernel_pair(1.0, 1.0);
        let expect = w * g * (0.0 - 1.0);
        assert!((rep.row(0)[0] - expect).abs() < 1e-6, "double-counted LD slot");
    }

    #[test]
    fn covered_counts_overlap_once() {
        // Point 0: one HD slot (→1) plus one LD slot that duplicates it
        // (skipped, →0); the naive k_hd + k_ld bound would say 2.
        let y = Matrix::from_vec(vec![0.0, 0.0, 1.0, 0.0], 2, 2).unwrap();
        let mut knn = IterativeKnn::new(2, 1, 1);
        knn.hd.insert(0, 1, 1.0);
        knn.ld.insert(0, 1, 1.0);
        let mut aff = Affinities::new(2, 1);
        aff.recalibrate_all(&mut knn, 2.0);
        let neg = NegSamples { m: 0, idx: vec![] };
        let mut b = NativeBackend::new();
        let (mut attr, mut rep) = (Matrix::zeros(2, 2), Matrix::zeros(2, 2));
        let stats = b.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap();
        assert_eq!(stats.covered, 1, "overlapping slot must be covered exactly once");
        // A distinct LD twin counts as term 2.
        knn.ld.clear_point(0);
        knn.ld.insert(1, 0, 1.0);
        let stats = b.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap();
        assert_eq!(stats.covered, 2, "HD slot of 0 plus non-overlapping LD slot of 1");
    }

    #[test]
    fn update_range_matches_manual_loop_and_reports_subtotals() {
        let n = 7usize;
        let d = 3usize;
        let mut rng = Rng::new(21);
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..n * d).map(|_| rng.gauss_ms(0.0, 1.0) as f32).collect()
        };
        let y0 = mk(&mut rng);
        let v0 = mk(&mut rng);
        let attr = mk(&mut rng);
        let rep = mk(&mut rng);
        let (a_mult, r_mult, lr, mom) = (1.5f32, 0.25f32, 0.1f32, 0.8f32);
        // Manual reference with the same per-point fold structure.
        let mut ye = y0.clone();
        let mut ve = v0.clone();
        let mut expect_ss = vec![0.0f64; n];
        for i in 0..n {
            for k in 0..d {
                let t = i * d + k;
                let grad = a_mult * attr[t] + r_mult * rep[t];
                ve[t] = mom * ve[t] + lr * grad;
                ye[t] += ve[t];
                expect_ss[i] += (ye[t] as f64) * (ye[t] as f64);
            }
        }
        let mut y = y0;
        let mut v = v0;
        let mut got_ss = vec![0.0f64; n];
        let mut order = Vec::new();
        update_range(0..n, d, &mut y, &mut v, &attr, &rep, a_mult, r_mult, lr, mom, |i, ss| {
            order.push(i);
            got_ss[i] = ss;
        });
        assert_eq!(order, (0..n).collect::<Vec<_>>(), "subtotals must fold in point order");
        for t in 0..n * d {
            assert_eq!(y[t].to_bits(), ye[t].to_bits(), "y[{t}]");
            assert_eq!(v[t].to_bits(), ve[t].to_bits(), "vel[{t}]");
        }
        for i in 0..n {
            assert_eq!(got_ss[i].to_bits(), expect_ss[i].to_bits(), "ss[{i}]");
        }
    }

    #[test]
    fn too_wide_ld_dim_is_a_checked_error() {
        // d = 65 exceeds the 64-wide stack buffers: must be a clean Err
        // (release builds used to hit an out-of-bounds slice).
        let y = Matrix::zeros(4, 65);
        let knn = IterativeKnn::new(4, 2, 2);
        let aff = Affinities::new(4, 2);
        let neg = NegSamples { m: 0, idx: vec![] };
        let mut b = NativeBackend::new();
        let (mut attr, mut rep) = (Matrix::zeros(4, 65), Matrix::zeros(4, 65));
        let err = b.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap_err();
        assert!(format!("{err:?}").contains("64"), "{err:?}");
    }
}
