//! Lane-vectorized (SIMD) force kernels and the [`SimdBackend`].
//!
//! Same Eq. 5/6 decomposition as [`crate::ld::forces`], restructured
//! from scalar row loops onto [`F32x8`] lane arithmetic:
//!
//! * Neighbour slots stream through a **transposed 8-row tile**
//!   (structure-of-arrays view of up to eight `y` rows, gathered by
//!   [`Matrix::gather_lanes`]) so the per-coordinate inner loop is one
//!   lane subtract/multiply instead of eight strided scalar passes.
//! * Squared distances, the heavy-tailed kernel `g = 1/(1 + d²/α)` and
//!   the weight `w = g^α` are evaluated for eight neighbours at once.
//! * Per-coordinate attraction/repulsion accumulate in **lane
//!   accumulators** (`[F32x8; 64]`) and are folded once per point with
//!   the fixed-order [`F32x8::hsum`].
//!
//! Determinism contract (docs/determinism.md):
//!
//! * Every per-point result is a pure function of that point's slot
//!   lists — groups of 8 are formed from the slot order alone, padded
//!   lanes use the point's own index (zero delta) with their
//!   coefficients zeroed, and all folds have a fixed association. The
//!   kernels are therefore **bitwise thread-count-invariant**: the
//!   shard partition can never change a lane grouping.
//! * Lane folds associate f32 additions differently from the scalar
//!   kernels, so SIMD results are **approximate, not bitwise**, vs
//!   [`crate::ld::NativeBackend`] (`rust/tests/parity.rs` pins the
//!   tolerance). The one exception is [`update_range_simd`]: the
//!   momentum update is purely elementwise and its Σy² fold is kept
//!   scalar-sequential, so the update pass — and the implosion
//!   decision — stays bitwise-identical to the scalar backends.
//!
//! [`SimdBackend`] composes with the existing [`ParallelBackend`]
//! sharding (thread-scaling × lane-scaling): it is a `ParallelBackend`
//! whose shard tasks dispatch to these kernels instead of the scalar
//! ones.

use crate::data::matrix::Matrix;
use crate::engine::backend::{ComputeBackend, NegSamples, NegStats};
use crate::hd::Affinities;
use crate::knn::iterative::IterativeKnn;
use crate::ld::parallel::{ParallelBackend, RangeKernel};
use crate::util::simd::{F32x8, LANES};
use anyhow::Result;
use std::ops::Range;

/// Pad the tail lanes of a neighbour-index group with the owning
/// point's index: the gathered row equals `y_i`, the delta is exactly
/// zero, and the flush helpers zero the padded coefficient lanes — so
/// padding never contributes to any accumulator and depends only on
/// the point itself, never on the shard partition.
#[inline(always)]
fn pad(idx: &mut [u32; LANES], fill: usize, i: usize) {
    for slot in idx.iter_mut().skip(fill) {
        *slot = i as u32;
    }
}

/// Gather the 8 neighbour rows of `idx` into `tile` as deltas
/// (`tile[k] = y_j[k] − y_i[k]` per lane) and evaluate the
/// heavy-tailed kernel for all lanes: returns `(w, g)` with
/// `g = 1/(1 + d²/α)` and `w = g^α` (α = 1 fast path, exactly like
/// [`crate::ld::kernel::kernel_pair`]).
#[inline(always)]
fn lane_deltas_kernel(
    y: &Matrix,
    d: usize,
    yi: &[f32; 64],
    idx: &[u32; LANES],
    alpha: f32,
    tile: &mut [F32x8; 64],
) -> (F32x8, F32x8) {
    y.gather_lanes(idx, &mut tile[..d]);
    let mut d2 = F32x8::ZERO;
    for (k, lane) in tile.iter_mut().enumerate().take(d) {
        let delta = lane.sub(F32x8::splat(yi[k]));
        *lane = delta;
        d2 = d2.add(delta.mul(delta));
    }
    let one = F32x8::splat(1.0);
    let g = one.div(one.add(d2.div(F32x8::splat(alpha))));
    let w = if alpha == 1.0 {
        g
    } else {
        let mut o = g.0;
        for v in o.iter_mut() {
            *v = v.powf(alpha);
        }
        F32x8(o)
    };
    (w, g)
}

/// Flush one HD group: attraction `+= p·g·Δ` and close repulsion
/// `−= w·g·Δ` into the lane accumulators (Δ = y_j − y_i).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn flush_hd(
    y: &Matrix,
    d: usize,
    yi: &[f32; 64],
    idx: &[u32; LANES],
    p: &[f32; LANES],
    fill: usize,
    alpha: f32,
    tile: &mut [F32x8; 64],
    acc_a: &mut [F32x8; 64],
    acc_r: &mut [F32x8; 64],
) {
    let (w, g) = lane_deltas_kernel(y, d, yi, idx, alpha, tile);
    let mut ag = F32x8(*p).mul(g);
    let mut rg = w.mul(g);
    for l in fill..LANES {
        ag.0[l] = 0.0;
        rg.0[l] = 0.0;
    }
    for k in 0..d {
        acc_a[k] = acc_a[k].add(ag.mul(tile[k]));
        acc_r[k] = acc_r[k].sub(rg.mul(tile[k]));
    }
}

/// Flush one repulsion-only group (LD slots with `scale = 1`, negative
/// samples with `scale = far_scale`): `rep += scale·w·g·(y_i − y_j)`,
/// i.e. `−= scale·w·g·Δ`. Returns the lane weights so the negative
/// pass can fold its wsum subtotal (padded lanes must be skipped by
/// the caller).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn flush_rep(
    y: &Matrix,
    d: usize,
    yi: &[f32; 64],
    idx: &[u32; LANES],
    fill: usize,
    alpha: f32,
    scale: f32,
    tile: &mut [F32x8; 64],
    acc_r: &mut [F32x8; 64],
) -> F32x8 {
    let (w, g) = lane_deltas_kernel(y, d, yi, idx, alpha, tile);
    let mut rg = w.mul(g).mul(F32x8::splat(scale));
    for l in fill..LANES {
        rg.0[l] = 0.0;
    }
    for k in 0..d {
        acc_r[k] = acc_r[k].sub(rg.mul(tile[k]));
    }
    w
}

/// Lane-vectorized twin of [`crate::ld::forces::forces_range`]: same
/// signature, same output layout, same `(count, covered)` /
/// `on_wsub` point-order reporting — shard-composable through the
/// same [`ParallelBackend`] plumbing. Callers must check
/// [`crate::ld::forces::ensure_supported_dim`] first (the tiles and
/// accumulators are 64-wide).
#[allow(clippy::too_many_arguments)]
pub(crate) fn forces_range_simd(
    y: &Matrix,
    knn: &IterativeKnn,
    aff: &Affinities,
    neg: &NegSamples,
    alpha: f32,
    far_scale: f32,
    range: Range<usize>,
    attr_out: &mut [f32],
    rep_out: &mut [f32],
    mut on_wsub: impl FnMut(usize, f64),
) -> (usize, usize) {
    let d = y.d();
    debug_assert!(d <= 64, "call ensure_supported_dim first");
    let start = range.start;
    debug_assert!(attr_out.len() >= range.len() * d);
    debug_assert!(rep_out.len() >= range.len() * d);
    let mut count = 0usize;
    let mut covered = 0usize;
    let mut yi_buf = [0.0f32; 64];
    let mut tile = [F32x8::ZERO; 64];
    let mut acc_a = [F32x8::ZERO; 64];
    let mut acc_r = [F32x8::ZERO; 64];
    let mut idx = [0u32; LANES];
    let mut pbuf = [0.0f32; LANES];
    for i in range {
        let yi_start = i * d;
        yi_buf[..d].copy_from_slice(&y.data()[yi_start..yi_start + d]);
        acc_a[..d].iter_mut().for_each(|v| *v = F32x8::ZERO);
        acc_r[..d].iter_mut().for_each(|v| *v = F32x8::ZERO);
        // --- 1. HD slots: attraction + close repulsion ------------
        let mut fill = 0usize;
        for (s, (j, _hd_dist)) in knn.hd.entries(i).enumerate() {
            idx[fill] = j;
            pbuf[fill] = aff.p_slot(i, s);
            fill += 1;
            covered += 1;
            if fill == LANES {
                flush_hd(
                    y, d, &yi_buf, &idx, &pbuf, LANES, alpha, &mut tile, &mut acc_a, &mut acc_r,
                );
                fill = 0;
            }
        }
        if fill > 0 {
            pad(&mut idx, fill, i);
            flush_hd(y, d, &yi_buf, &idx, &pbuf, fill, alpha, &mut tile, &mut acc_a, &mut acc_r);
        }
        // --- 2. LD slots not in the HD set: close repulsion -------
        fill = 0;
        for (j, _stale) in knn.ld.entries(i) {
            if knn.hd.contains(i, j) {
                continue; // already covered by term 1 (not re-counted)
            }
            idx[fill] = j;
            fill += 1;
            covered += 1;
            if fill == LANES {
                flush_rep(y, d, &yi_buf, &idx, LANES, alpha, 1.0, &mut tile, &mut acc_r);
                fill = 0;
            }
        }
        if fill > 0 {
            pad(&mut idx, fill, i);
            flush_rep(y, d, &yi_buf, &idx, fill, alpha, 1.0, &mut tile, &mut acc_r);
        }
        // --- 3. Negative samples: far field ------------------------
        // One f64 subtotal per point in lane (= slot) order, reported
        // in point order — the same fold discipline as the scalar
        // kernel, so wsum stays shard-partition-independent.
        let mut wsub = 0.0f64;
        fill = 0;
        for &j in neg.row(i) {
            idx[fill] = j;
            fill += 1;
            count += 1;
            if fill == LANES {
                let w =
                    flush_rep(y, d, &yi_buf, &idx, LANES, alpha, far_scale, &mut tile, &mut acc_r);
                for &wl in &w.0 {
                    wsub += wl as f64;
                }
                fill = 0;
            }
        }
        if fill > 0 {
            pad(&mut idx, fill, i);
            let w = flush_rep(y, d, &yi_buf, &idx, fill, alpha, far_scale, &mut tile, &mut acc_r);
            for &wl in w.0.iter().take(fill) {
                wsub += wl as f64;
            }
        }
        on_wsub(i, wsub);
        // One fixed-order horizontal fold per coordinate per point.
        let off = (i - start) * d;
        for k in 0..d {
            attr_out[off + k] = acc_a[k].hsum();
            rep_out[off + k] = acc_r[k].hsum();
        }
    }
    (count, covered)
}

/// Lane-vectorized twin of [`crate::ld::forces::update_range`].
///
/// The gradient/momentum update is purely elementwise (no horizontal
/// fold touches f32), and the implosion Σy² subtotal is folded
/// scalar-sequentially over each row exactly like the scalar kernel —
/// so this pass is **bitwise-identical** to `update_range`, not merely
/// within tolerance (pinned by a `to_bits` test below).
#[allow(clippy::too_many_arguments)]
pub(crate) fn update_range_simd(
    range: Range<usize>,
    d: usize,
    y_out: &mut [f32],
    vel_out: &mut [f32],
    attr: &[f32],
    rep: &[f32],
    a_mult: f32,
    r_mult: f32,
    lr: f32,
    mom: f32,
    mut on_ss: impl FnMut(usize, f64),
) {
    let start = range.start;
    debug_assert!(y_out.len() >= range.len() * d);
    debug_assert!(vel_out.len() >= range.len() * d);
    debug_assert!(attr.len() >= range.len() * d);
    debug_assert!(rep.len() >= range.len() * d);
    let am = F32x8::splat(a_mult);
    let rm = F32x8::splat(r_mult);
    let lrv = F32x8::splat(lr);
    let momv = F32x8::splat(mom);
    for i in range {
        let off = (i - start) * d;
        let chunks = d / LANES;
        for c in 0..chunks {
            let t = off + c * LANES;
            let grad = am.mul(F32x8::load(&attr[t..])).add(rm.mul(F32x8::load(&rep[t..])));
            let v = momv.mul(F32x8::load(&vel_out[t..])).add(lrv.mul(grad));
            v.store(&mut vel_out[t..]);
            let ynew = F32x8::load(&y_out[t..]).add(v);
            ynew.store(&mut y_out[t..]);
        }
        for t in off + chunks * LANES..off + d {
            let grad = a_mult * attr[t] + r_mult * rep[t];
            vel_out[t] = mom * vel_out[t] + lr * grad;
            y_out[t] += vel_out[t];
        }
        let mut ss = 0.0f64;
        for t in off..off + d {
            ss += (y_out[t] as f64) * (y_out[t] as f64);
        }
        on_ss(i, ss);
    }
}

/// Lane-vectorized squared Euclidean distance: one lane accumulator
/// over 8-wide chunks, one fixed-order [`F32x8::hsum`], then a scalar
/// sequential tail. Deterministic, but associated differently from
/// the scalar [`crate::data::matrix::sqdist`] (4-way unroll), so the
/// two agree within f32 rounding, not bitwise.
#[inline(always)]
pub(crate) fn sqdist_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = F32x8::ZERO;
    for c in 0..chunks {
        let t = c * LANES;
        let delta = F32x8::load(&a[t..]).sub(F32x8::load(&b[t..]));
        acc = acc.add(delta.mul(delta));
    }
    let mut s = acc.hsum();
    for t in chunks * LANES..n {
        let delta = a[t] - b[t];
        s += delta * delta;
    }
    s
}

/// The lane-vectorized [`ComputeBackend`]: a [`ParallelBackend`] whose
/// shard tasks run the SIMD kernels, so thread-scaling and
/// lane-scaling multiply. `threads = 1` (the default single-thread
/// config) runs the kernels inline on the calling thread.
///
/// Selected with `--backend simd`, `EmbedConfig { backend:
/// Backend::Simd, .. }`, or `FUNCSNE_BACKEND=simd`.
pub struct SimdBackend {
    inner: ParallelBackend,
}

impl SimdBackend {
    /// A SIMD backend with `threads` workers (`0` = auto-detect).
    pub fn new(threads: usize) -> SimdBackend {
        SimdBackend { inner: ParallelBackend::with_kernel(threads, RangeKernel::Simd) }
    }

    /// See [`ParallelBackend::with_shard_floors`].
    pub fn with_shard_floors(mut self, min_points: usize, min_pairs: usize) -> SimdBackend {
        self.inner = self.inner.with_shard_floors(min_points, min_pairs);
        self
    }

    /// The resolved worker count.
    pub fn threads(&self) -> usize {
        self.inner.threads()
    }
}

impl ComputeBackend for SimdBackend {
    fn sqdist_batch(
        &mut self,
        x: &Matrix,
        owners: &[u32],
        cands: &[u32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.inner.sqdist_batch(x, owners, cands, out)
    }

    fn forces(
        &mut self,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
        attr: &mut Matrix,
        rep: &mut Matrix,
    ) -> Result<NegStats> {
        self.inner.forces(y, knn, aff, neg, alpha, far_scale, attr, rep)
    }

    fn update(
        &mut self,
        y: &mut Matrix,
        vel: &mut Matrix,
        attr: &Matrix,
        rep: &Matrix,
        a_mult: f32,
        r_mult: f32,
        lr: f32,
        mom: f32,
    ) -> Result<f64> {
        self.inner.update(y, vel, attr, rep, a_mult, r_mult, lr, mom)
    }

    fn name(&self) -> &'static str {
        "simd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;
    use crate::ld::forces::NativeBackend;
    use crate::ld::kernel::kernel_pair;
    use crate::util::Rng;

    /// Remainder-lane sweep: below/at/above one lane group, the 7-of-8
    /// and full-64 edges.
    const DIMS: [usize; 7] = [1, 2, 3, 8, 16, 63, 64];

    fn setup(n: usize, d_ld: usize, seed: u64) -> (Matrix, IterativeKnn, Affinities) {
        let ds = datasets::blobs(n, 5, 3, 0.6, 8.0, seed);
        let mut rng = Rng::new(seed ^ 1);
        let mut yv = Vec::with_capacity(n * d_ld);
        for _ in 0..n * d_ld {
            yv.push(rng.gauss_ms(0.0, 1.0) as f32);
        }
        let y = Matrix::from_vec(yv, n, d_ld).unwrap();
        let k = 8.min(n - 1);
        let exact = brute_knn(&ds.x, k);
        let mut knn = IterativeKnn::new(n, k, k);
        for i in 0..n {
            for (j, dd) in exact.entries(i) {
                knn.hd.insert(i, j, dd);
            }
        }
        let exact_ld = brute_knn(&y, k);
        for i in 0..n {
            for (j, dd) in exact_ld.entries(i) {
                knn.ld.insert(i, j, dd);
            }
        }
        let mut aff = Affinities::new(n, k);
        aff.recalibrate_all(&mut knn, 5.0);
        (y, knn, aff)
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-3 * (1.0 + a.abs().max(b.abs()))
    }

    /// Exhaustive scalar oracle of the Eq. 6 decomposition — the same
    /// reference the native backend is tested against, now applied to
    /// the lane kernels (within lane-reassociation tolerance).
    fn oracle(
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
    ) -> (Matrix, Matrix, NegStats) {
        let n = y.n();
        let d = y.d();
        let mut attr = Matrix::zeros(n, d);
        let mut rep = Matrix::zeros(n, d);
        let mut stats = NegStats::default();
        for i in 0..n {
            for (s, (j, _)) in knn.hd.entries(i).enumerate() {
                let p = aff.p_slot(i, s);
                let (w, g) = kernel_pair(y.sqdist(i, j as usize), alpha);
                for k in 0..d {
                    let delta = y.row(j as usize)[k] - y.row(i)[k];
                    attr.data_mut()[i * d + k] += p * g * delta;
                    rep.data_mut()[i * d + k] -= w * g * delta;
                }
                stats.covered += 1;
            }
            for (j, _) in knn.ld.entries(i) {
                if knn.hd.contains(i, j) {
                    continue;
                }
                stats.covered += 1;
                let (w, g) = kernel_pair(y.sqdist(i, j as usize), alpha);
                for k in 0..d {
                    rep.data_mut()[i * d + k] +=
                        w * g * (y.row(i)[k] - y.row(j as usize)[k]);
                }
            }
            for &j in neg.row(i) {
                let (w, g) = kernel_pair(y.sqdist(i, j as usize), alpha);
                stats.wsum += w as f64;
                stats.count += 1;
                for k in 0..d {
                    rep.data_mut()[i * d + k] +=
                        w * g * far_scale * (y.row(i)[k] - y.row(j as usize)[k]);
                }
            }
        }
        (attr, rep, stats)
    }

    #[test]
    fn simd_forces_match_oracle_across_dims_and_alphas() {
        let n = 130usize;
        for &d in &DIMS {
            for &alpha in &[0.5f32, 1.0, 2.0] {
                let (y, knn, aff) = setup(n, d, 11 + d as u64);
                let mut rng = Rng::new(42);
                let neg = NegSamples::draw(n, 6, &mut rng);
                let (eattr, erep, estats) = oracle(&y, &knn, &aff, &neg, alpha, 9.5);
                let mut simd = SimdBackend::new(1);
                let (mut a, mut r) = (Matrix::zeros(n, d), Matrix::zeros(n, d));
                let s = simd.forces(&y, &knn, &aff, &neg, alpha, 9.5, &mut a, &mut r).unwrap();
                for (got, want) in a.data().iter().zip(eattr.data()) {
                    assert!(close(*got, *want), "attr {got} vs {want} (d={d} alpha={alpha})");
                }
                for (got, want) in r.data().iter().zip(erep.data()) {
                    assert!(close(*got, *want), "rep {got} vs {want} (d={d} alpha={alpha})");
                }
                assert!(
                    (s.wsum - estats.wsum).abs() <= 1e-4 * (1.0 + estats.wsum.abs()),
                    "wsum {} vs {} (d={d} alpha={alpha})",
                    s.wsum,
                    estats.wsum
                );
                assert_eq!(s.count, estats.count, "count (d={d})");
                assert_eq!(s.covered, estats.covered, "covered (d={d})");
            }
        }
    }

    #[test]
    fn simd_forces_bitwise_thread_invariant() {
        // d = 3 and 63 keep partially-filled lane groups and the
        // remainder coordinates in play; floors (1, 1) force real
        // fan-out at n = 130.
        for &d in &[3usize, 63] {
            let n = 130usize;
            let (y, knn, aff) = setup(n, d, 23);
            let mut rng = Rng::new(5);
            let neg = NegSamples::draw(n, 6, &mut rng);
            let mut base: Option<(Matrix, Matrix, NegStats)> = None;
            for &threads in &[1usize, 2, 4] {
                let mut simd = SimdBackend::new(threads).with_shard_floors(1, 1);
                let (mut a, mut r) = (Matrix::zeros(n, d), Matrix::zeros(n, d));
                let s = simd.forces(&y, &knn, &aff, &neg, 0.7, 9.5, &mut a, &mut r).unwrap();
                match &base {
                    None => base = Some((a, r, s)),
                    Some((a0, r0, s0)) => {
                        for (u, v) in a0.data().iter().zip(a.data()) {
                            assert_eq!(u.to_bits(), v.to_bits(), "attr at {threads} threads");
                        }
                        for (u, v) in r0.data().iter().zip(r.data()) {
                            assert_eq!(u.to_bits(), v.to_bits(), "rep at {threads} threads");
                        }
                        assert_eq!(s0.wsum.to_bits(), s.wsum.to_bits(), "wsum");
                        assert_eq!(s0.count, s.count);
                        assert_eq!(s0.covered, s.covered);
                    }
                }
            }
        }
    }

    #[test]
    fn simd_update_is_bitwise_identical_to_native() {
        // The update pass has no f32 reassociation, so SIMD vs scalar
        // must agree exactly — across the remainder-dim sweep.
        for &d in &DIMS {
            let n = 97usize;
            let mut rng = Rng::new(19 + d as u64);
            let mk = |rng: &mut Rng| -> Matrix {
                let v: Vec<f32> = (0..n * d).map(|_| rng.gauss_ms(0.0, 1.0) as f32).collect();
                Matrix::from_vec(v, n, d).unwrap()
            };
            let y0 = mk(&mut rng);
            let v0 = mk(&mut rng);
            let attr = mk(&mut rng);
            let rep = mk(&mut rng);
            let (a_mult, r_mult, lr, mom) = (2.0f32, 0.03f32, 0.1f32, 0.8f32);
            let mut native = NativeBackend::new();
            let (mut y1, mut v1) = (y0.clone(), v0.clone());
            let ss1 =
                native.update(&mut y1, &mut v1, &attr, &rep, a_mult, r_mult, lr, mom).unwrap();
            for &threads in &[1usize, 2, 4] {
                let mut simd = SimdBackend::new(threads).with_shard_floors(1, 1);
                let (mut y2, mut v2) = (y0.clone(), v0.clone());
                let ss2 =
                    simd.update(&mut y2, &mut v2, &attr, &rep, a_mult, r_mult, lr, mom).unwrap();
                assert_eq!(ss1.to_bits(), ss2.to_bits(), "Σy² (d={d}, {threads} threads)");
                for (a, b) in y1.data().iter().zip(y2.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "y (d={d}, {threads} threads)");
                }
                for (a, b) in v1.data().iter().zip(v2.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "vel (d={d}, {threads} threads)");
                }
            }
        }
    }

    #[test]
    fn simd_sqdist_close_to_native_and_thread_invariant() {
        for &dim in &[3usize, 8, 31, 32, 63, 64, 127] {
            let ds = datasets::blobs(60, dim, 2, 1.0, 5.0, 9);
            let owners: Vec<u32> = (0..47).collect();
            let cands: Vec<u32> = (10..57).collect();
            let mut native = NativeBackend::new();
            let mut o0 = Vec::new();
            native.sqdist_batch(&ds.x, &owners, &cands, &mut o0).unwrap();
            let mut base: Option<Vec<f32>> = None;
            for &threads in &[1usize, 2, 4] {
                let mut simd = SimdBackend::new(threads).with_shard_floors(1, 1);
                let mut o1 = Vec::new();
                simd.sqdist_batch(&ds.x, &owners, &cands, &mut o1).unwrap();
                for (u, v) in o0.iter().zip(&o1) {
                    assert!(close(*u, *v), "sqdist {u} vs {v} (dim={dim})");
                }
                match &base {
                    None => base = Some(o1),
                    Some(b) => {
                        for (u, v) in b.iter().zip(&o1) {
                            assert_eq!(u.to_bits(), v.to_bits(), "sqdist at {threads} threads");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sqdist_lanes_matches_naive() {
        let mut rng = Rng::new(3);
        for &n in &[1usize, 7, 8, 9, 16, 40, 129] {
            let a: Vec<f32> = (0..n).map(|_| rng.gauss_ms(0.0, 2.0) as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.gauss_ms(0.0, 2.0) as f32).collect();
            let naive: f64 =
                a.iter().zip(&b).map(|(x, y)| ((x - y) as f64) * ((x - y) as f64)).sum();
            let fast = sqdist_lanes(&a, &b) as f64;
            assert!((naive - fast).abs() <= 1e-4 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn too_wide_ld_dim_is_a_checked_error() {
        let y = Matrix::zeros(4, 65);
        let knn = IterativeKnn::new(4, 2, 2);
        let aff = Affinities::new(4, 2);
        let neg = NegSamples { m: 0, idx: vec![] };
        let mut simd = SimdBackend::new(2);
        let (mut attr, mut rep) = (Matrix::zeros(4, 65), Matrix::zeros(4, 65));
        let err = simd.forces(&y, &knn, &aff, &neg, 1.0, 1.0, &mut attr, &mut rep).unwrap_err();
        assert!(format!("{err:?}").contains("64"), "{err:?}");
    }

    #[test]
    fn backend_name_and_threads() {
        let simd = SimdBackend::new(3);
        assert_eq!(simd.threads(), 3);
        assert_eq!(SimdBackend::new(1).name(), "simd");
        assert!(SimdBackend::new(0).threads() >= 1);
    }
}
