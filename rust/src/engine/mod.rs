//! The FUnc-SNE engine: single-phase, interleaved KNN refinement and
//! gradient descent, with dynamic-dataset support and on-the-fly
//! hyperparameter changes.

pub mod backend;
pub mod funcsne;

pub use backend::{ComputeBackend, NegSamples, NegStats};
pub use funcsne::{EngineState, EngineStats, FuncSne, PhaseMicros};
