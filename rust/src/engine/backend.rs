//! The compute-backend boundary between the L3 coordinator logic and the
//! numeric hot spots.
//!
//! Two implementations exist:
//!
//! * [`crate::ld::NativeBackend`] — pure Rust, the reference semantics
//!   and the PJRT ablation baseline;
//! * [`crate::coordinator::PjrtBackend`] — dispatches fixed-shape tiles
//!   to AOT-compiled XLA executables (the Pallas kernels lowered by
//!   `python/compile/aot.py`), the paper's "GPU kernel" analogue.
//!
//! Both receive *identical* inputs (the engine draws negative samples
//! itself, so backends are deterministic given their arguments), which
//! is what the parity integration test exploits.

use crate::data::Matrix;
use crate::hd::Affinities;
use crate::knn::iterative::IterativeKnn;
use crate::runtime::pool::{effective_shards, shard_ranges, split_by_ranges, WorkerPool};
use crate::util::{lane, RandomSource, StreamRng};
use anyhow::Result;

/// Minimum points per shard when refilling negative samples from
/// counter streams (a point costs only `m` stream draws, so small
/// inputs are cheaper inline than forked).
pub const MIN_NEG_POINTS_PER_SHARD: usize = 2048;

/// Statistics from the force pass, used by the engine to maintain its
/// running estimate of the global normaliser
/// Z = Σ_{k≠l} w_kl ≈ N(N−1)·E[w], and to size the far-field scaling of
/// the *next* iteration from what the near field actually covered.
#[derive(Clone, Copy, Debug, Default)]
pub struct NegStats {
    /// Σ w over all (point, negative-sample) pairs this iteration.
    /// Accumulated as one f64 subtotal per point, then reduced over
    /// points in index order — a summation structure both backends share
    /// so the result is bitwise-identical regardless of sharding.
    pub wsum: f64,
    /// Number of such pairs.
    pub count: usize,
    /// Near-field pairs actually processed this pass: HD slots (term 1)
    /// plus LD slots whose twin is *not* in the HD set (term 2). LD
    /// slots skipped for overlapping the HD set are **not** counted —
    /// this is the real covered count the engine's `far_scale` needs,
    /// not the `k_hd + k_ld` upper bound.
    pub covered: usize,
}

/// Pre-drawn negative samples: `m` uniform non-self indices per point,
/// flattened row-major (n × m).
#[derive(Clone, Debug)]
pub struct NegSamples {
    pub m: usize,
    pub idx: Vec<u32>,
}

impl NegSamples {
    /// Draw fresh samples for `n` points.
    pub fn draw(n: usize, m: usize, rng: &mut crate::util::Rng) -> NegSamples {
        let mut s = NegSamples { m, idx: Vec::new() };
        s.redraw(n, rng);
        s
    }

    /// Refill in place (§Perf: the engine reuses one buffer per run
    /// instead of allocating n·m ids every iteration).
    ///
    /// With `n < 2` there is no valid non-self sample, so the buffer is
    /// left empty (`m` draws per point would previously index out of
    /// range at `n == 1`: `n.max(2) - 1` put 1 in a 1-row table).
    pub fn redraw(&mut self, n: usize, rng: &mut crate::util::Rng) {
        let m = self.m;
        self.idx.clear();
        if n < 2 || m == 0 {
            return;
        }
        self.idx.reserve(n * m);
        for i in 0..n {
            for _ in 0..m {
                // Uniform over the n-1 others: draw in [0, n-1) and skip i.
                let mut j = rng.below(n - 1);
                if j >= i {
                    j += 1;
                }
                self.idx.push(j as u32);
            }
        }
    }

    /// Refill from per-point counter streams (`lane::NEG`), sharded
    /// over `pool`: row `i` depends only on `(seed, iter, i)`, so the
    /// result is bitwise-identical at any thread count and any shard
    /// partition — unlike [`NegSamples::redraw`], whose sequential
    /// stream forces a single consumption order. Same `n < 2` contract
    /// as `redraw`.
    pub fn redraw_streams(
        &mut self,
        n: usize,
        seed: u64,
        iter: u64,
        pool: &WorkerPool,
        min_points_per_shard: usize,
    ) {
        let m = self.m;
        if n < 2 || m == 0 {
            self.idx.clear();
            return;
        }
        if self.idx.len() != n * m {
            // Every slot is overwritten by the shard tasks below, so
            // stale ids never leak; skipping the clear avoids a
            // per-iteration memset of the whole buffer.
            self.idx.clear();
            self.idx.resize(n * m, 0);
        }
        let ranges = shard_ranges(n, effective_shards(pool, n, min_points_per_shard));
        let chunks = split_by_ranges(self.idx.as_mut_slice(), &ranges, m);
        let tasks: Vec<_> = chunks
            .into_iter()
            .zip(ranges)
            .map(|(chunk, range)| {
                move || {
                    let start = range.start;
                    for i in range {
                        let mut rng = StreamRng::at(seed, iter, i as u64, lane::NEG);
                        let row = &mut chunk[(i - start) * m..(i - start + 1) * m];
                        for slot in row.iter_mut() {
                            // Uniform over the n-1 others: draw then skip i.
                            let mut j = rng.below(n - 1);
                            if j >= i {
                                j += 1;
                            }
                            *slot = j as u32;
                        }
                    }
                }
            })
            .collect();
        pool.run_tasks(tasks);
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.idx[i * self.m..(i + 1) * self.m]
    }
}

/// The numeric services the engine needs per iteration: candidate
/// scoring, the force pass, and the gradient/momentum update.
///
/// This seam is where the SIMD layout restructuring lives: the engine
/// hands over whole batches/ranges, so a backend is free to regroup
/// the work into 8-wide lane tiles ([`crate::ld::SimdBackend`]), shard
/// it over threads ([`crate::ld::ParallelBackend`]), or ship it to an
/// AOT accelerator — without the engine's slot semantics or RNG
/// streams noticing.
pub trait ComputeBackend {
    /// Squared HD distances for candidate pairs: `out[t] = ||x[owners[t]]
    /// - x[cands[t]]||²`. Batches may be any length; implementations tile
    /// and pad as needed.
    fn sqdist_batch(
        &mut self,
        x: &Matrix,
        owners: &[u32],
        cands: &[u32],
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Full force pass. Writes the attraction movement direction
    /// Σ p·g·(y_j − y_i) into `attr` and the *unnormalised* repulsion
    /// Σ w·g·(y_i − y_j) into `rep` (the engine applies the Z
    /// normalisation). Returns the negative-slot kernel statistics and
    /// the near-field covered-pair count ([`NegStats::covered`]).
    ///
    /// Slot semantics (identical in both backends; see DESIGN.md §2):
    /// * HD slots — attraction with p_{j|i}, plus repulsion (Eq. 6 term 1);
    /// * LD slots with the twin not in the HD set — repulsion (term 2);
    /// * negative samples — repulsion multiplied by `far_scale` (the
    ///   uncovered-pair count over the sample count, supplied by the
    ///   engine — term 3), and counted *unscaled* into [`NegStats`].
    #[allow(clippy::too_many_arguments)]
    fn forces(
        &mut self,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
        attr: &mut Matrix,
        rep: &mut Matrix,
    ) -> Result<NegStats>;

    /// Step 5 of an iteration: the gradient/momentum update with the
    /// implosion-RMS reduction fused in. For every coordinate `t`:
    /// `v[t] = mom·v[t] + lr·(a_mult·attr[t] + r_mult·rep[t])`, then
    /// `y[t] += v[t]`. Returns Σ y² (post-update) for the engine's
    /// implosion guard.
    ///
    /// Summation contract (the same discipline as [`NegStats::wsum`]):
    /// one f64 subtotal per *point*, folded in point order — the
    /// default implementation and the sharded override share
    /// [`crate::ld::forces::update_range`], so the fold (and therefore
    /// the implosion decision) is bitwise-identical at any thread
    /// count. The default runs sequentially on the calling thread;
    /// [`crate::ld::ParallelBackend`] shards it by point ranges, and
    /// the SIMD lane kernel keeps this exact scalar-sequential Σy² fold
    /// so even its update stays bitwise-equal to the reference.
    #[allow(clippy::too_many_arguments)]
    fn update(
        &mut self,
        y: &mut Matrix,
        vel: &mut Matrix,
        attr: &Matrix,
        rep: &Matrix,
        a_mult: f32,
        r_mult: f32,
        lr: f32,
        mom: f32,
    ) -> Result<f64> {
        let n = y.n();
        let d = y.d();
        debug_assert_eq!(vel.n(), n);
        debug_assert_eq!(attr.n(), n);
        debug_assert_eq!(rep.n(), n);
        let mut total = 0.0f64;
        crate::ld::forces::update_range(
            0..n,
            d,
            y.data_mut(),
            vel.data_mut(),
            attr.data(),
            rep.data(),
            a_mult,
            r_mult,
            lr,
            mom,
            |_, ss| total += ss,
        );
        Ok(total)
    }

    /// Human-readable name for logs / EXPERIMENTS.md.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn neg_samples_never_self_and_in_range() {
        let mut rng = Rng::new(3);
        for &(n, m) in &[(2usize, 4usize), (10, 8), (100, 3)] {
            let neg = NegSamples::draw(n, m, &mut rng);
            assert_eq!(neg.idx.len(), n * m);
            for i in 0..n {
                for &j in neg.row(i) {
                    assert_ne!(j as usize, i, "self-sample at {i}");
                    assert!((j as usize) < n);
                }
            }
        }
    }

    #[test]
    fn neg_samples_roughly_uniform() {
        let mut rng = Rng::new(4);
        let n = 20;
        let neg = NegSamples::draw(n, 500, &mut rng);
        let mut counts = vec![0usize; n];
        for &j in &neg.idx {
            counts[j as usize] += 1;
        }
        let expect = (n * 500) as f64 / n as f64;
        for (j, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.8 && (c as f64) < expect * 1.2,
                "count[{j}] = {c}, expect ~{expect}"
            );
        }
    }

    /// Regression: `n == 1` used to draw `below(1) = 0`, bump it past
    /// the skipped self index and emit 1 — out of range for a 1-row
    /// matrix. There is no valid non-self sample, so the buffer must
    /// come back empty instead.
    #[test]
    fn neg_samples_single_point_yields_empty() {
        let mut rng = Rng::new(5);
        let neg = NegSamples::draw(1, 8, &mut rng);
        assert!(neg.idx.is_empty(), "no non-self sample exists at n = 1");
        let mut s = NegSamples { m: 3, idx: vec![9, 9, 9] };
        s.redraw(0, &mut rng);
        assert!(s.idx.is_empty());
        let pool = crate::runtime::pool::WorkerPool::new(4);
        s.redraw_streams(1, 7, 3, &pool, 1);
        assert!(s.idx.is_empty());
    }

    /// The stream refill is bitwise-identical at any pool width and
    /// shard partition, never self-samples, and stays in range.
    #[test]
    fn neg_samples_streams_thread_count_invariant() {
        let n = 137usize; // odd: every multi-shard partition is uneven
        let m = 6usize;
        let fill = |threads: usize, floor: usize| -> Vec<u32> {
            let pool = crate::runtime::pool::WorkerPool::new(threads);
            let mut s = NegSamples { m, idx: Vec::new() };
            s.redraw_streams(n, 42, 9, &pool, floor);
            s.idx
        };
        let base = fill(1, 1);
        assert_eq!(base.len(), n * m);
        for i in 0..n {
            for &j in &base[i * m..(i + 1) * m] {
                assert_ne!(j as usize, i, "self-sample at {i}");
                assert!((j as usize) < n);
            }
        }
        for threads in [2usize, 4, 16] {
            assert_eq!(fill(threads, 1), base, "idx differs at {threads} threads");
        }
        // Production floor collapses to one shard — still identical.
        assert_eq!(fill(8, MIN_NEG_POINTS_PER_SHARD), base);
    }

    /// Streams differ across iterations and seeds (no accidental
    /// constant-lane reuse).
    #[test]
    fn neg_samples_streams_vary_by_iter_and_seed() {
        let pool = crate::runtime::pool::WorkerPool::new(1);
        let fill = |seed: u64, iter: u64| -> Vec<u32> {
            let mut s = NegSamples { m: 8, idx: Vec::new() };
            s.redraw_streams(64, seed, iter, &pool, 1);
            s.idx
        };
        let a = fill(1, 1);
        assert_ne!(a, fill(1, 2), "same stream across iterations");
        assert_ne!(a, fill(2, 1), "same stream across seeds");
        assert_eq!(a, fill(1, 1), "not reproducible");
    }
}
