//! The compute-backend boundary between the L3 coordinator logic and the
//! numeric hot spots.
//!
//! Two implementations exist:
//!
//! * [`crate::ld::NativeBackend`] — pure Rust, the reference semantics
//!   and the PJRT ablation baseline;
//! * [`crate::coordinator::PjrtBackend`] — dispatches fixed-shape tiles
//!   to AOT-compiled XLA executables (the Pallas kernels lowered by
//!   `python/compile/aot.py`), the paper's "GPU kernel" analogue.
//!
//! Both receive *identical* inputs (the engine draws negative samples
//! itself, so backends are deterministic given their arguments), which
//! is what the parity integration test exploits.

use crate::data::Matrix;
use crate::hd::Affinities;
use crate::knn::iterative::IterativeKnn;
use anyhow::Result;

/// Statistics from the force pass, used by the engine to maintain its
/// running estimate of the global normaliser
/// Z = Σ_{k≠l} w_kl ≈ N(N−1)·E[w], and to size the far-field scaling of
/// the *next* iteration from what the near field actually covered.
#[derive(Clone, Copy, Debug, Default)]
pub struct NegStats {
    /// Σ w over all (point, negative-sample) pairs this iteration.
    /// Accumulated as one f64 subtotal per point, then reduced over
    /// points in index order — a summation structure both backends share
    /// so the result is bitwise-identical regardless of sharding.
    pub wsum: f64,
    /// Number of such pairs.
    pub count: usize,
    /// Near-field pairs actually processed this pass: HD slots (term 1)
    /// plus LD slots whose twin is *not* in the HD set (term 2). LD
    /// slots skipped for overlapping the HD set are **not** counted —
    /// this is the real covered count the engine's `far_scale` needs,
    /// not the `k_hd + k_ld` upper bound.
    pub covered: usize,
}

/// Pre-drawn negative samples: `m` uniform non-self indices per point,
/// flattened row-major (n × m).
#[derive(Clone, Debug)]
pub struct NegSamples {
    pub m: usize,
    pub idx: Vec<u32>,
}

impl NegSamples {
    /// Draw fresh samples for `n` points.
    pub fn draw(n: usize, m: usize, rng: &mut crate::util::Rng) -> NegSamples {
        let mut s = NegSamples { m, idx: Vec::new() };
        s.redraw(n, rng);
        s
    }

    /// Refill in place (§Perf: the engine reuses one buffer per run
    /// instead of allocating n·m ids every iteration).
    pub fn redraw(&mut self, n: usize, rng: &mut crate::util::Rng) {
        let m = self.m;
        self.idx.clear();
        self.idx.reserve(n * m);
        for i in 0..n {
            for _ in 0..m {
                // Uniform over the n-1 others: draw in [0, n-1) and skip i.
                let mut j = rng.below(n.max(2) - 1);
                if j >= i {
                    j += 1;
                }
                self.idx.push(j as u32);
            }
        }
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[u32] {
        &self.idx[i * self.m..(i + 1) * self.m]
    }
}

/// The two numeric services the engine needs per iteration.
pub trait ComputeBackend {
    /// Squared HD distances for candidate pairs: `out[t] = ||x[owners[t]]
    /// - x[cands[t]]||²`. Batches may be any length; implementations tile
    /// and pad as needed.
    fn sqdist_batch(
        &mut self,
        x: &Matrix,
        owners: &[u32],
        cands: &[u32],
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Full force pass. Writes the attraction movement direction
    /// Σ p·g·(y_j − y_i) into `attr` and the *unnormalised* repulsion
    /// Σ w·g·(y_i − y_j) into `rep` (the engine applies the Z
    /// normalisation). Returns the negative-slot kernel statistics and
    /// the near-field covered-pair count ([`NegStats::covered`]).
    ///
    /// Slot semantics (identical in both backends; see DESIGN.md §2):
    /// * HD slots — attraction with p_{j|i}, plus repulsion (Eq. 6 term 1);
    /// * LD slots with the twin not in the HD set — repulsion (term 2);
    /// * negative samples — repulsion multiplied by `far_scale` (the
    ///   uncovered-pair count over the sample count, supplied by the
    ///   engine — term 3), and counted *unscaled* into [`NegStats`].
    #[allow(clippy::too_many_arguments)]
    fn forces(
        &mut self,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
        attr: &mut Matrix,
        rep: &mut Matrix,
    ) -> Result<NegStats>;

    /// Human-readable name for logs / EXPERIMENTS.md.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn neg_samples_never_self_and_in_range() {
        let mut rng = Rng::new(3);
        for &(n, m) in &[(2usize, 4usize), (10, 8), (100, 3)] {
            let neg = NegSamples::draw(n, m, &mut rng);
            assert_eq!(neg.idx.len(), n * m);
            for i in 0..n {
                for &j in neg.row(i) {
                    assert_ne!(j as usize, i, "self-sample at {i}");
                    assert!((j as usize) < n);
                }
            }
        }
    }

    #[test]
    fn neg_samples_roughly_uniform() {
        let mut rng = Rng::new(4);
        let n = 20;
        let neg = NegSamples::draw(n, 500, &mut rng);
        let mut counts = vec![0usize; n];
        for &j in &neg.idx {
            counts[j as usize] += 1;
        }
        let expect = (n * 500) as f64 / n as f64;
        for (j, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.8 && (c as f64) < expect * 1.2,
                "count[{j}] = {c}, expect ~{expect}"
            );
        }
    }
}
