//! Synthetic dataset generators — structural twins of the paper's data.
//!
//! Each generator documents which paper dataset it substitutes and which
//! structural property the corresponding experiment depends on. All
//! generators return a [`Dataset`] with ground-truth labels (and, where
//! applicable, a ground-truth hierarchy), which the metrics and figure
//! drivers consume.

use super::matrix::Matrix;
use crate::util::Rng;

/// A labelled point cloud plus optional hierarchy ground truth.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Matrix,
    /// Primary (leaf-level) integer label per point.
    pub labels: Vec<usize>,
    /// Optional coarser label per point (e.g. root cell type / digit
    /// class when `labels` is the sub-cluster id).
    pub coarse_labels: Option<Vec<usize>>,
    /// Optional ground-truth parent map over leaf label ids
    /// (`hierarchy[leaf] = parent group id`) for the Fig. 10 comparison.
    pub hierarchy: Option<Vec<usize>>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.n()
    }

    pub fn d(&self) -> usize {
        self.x.d()
    }

    pub fn n_classes(&self) -> usize {
        self.labels.iter().copied().max().map_or(0, |m| m + 1)
    }
}

/// Apply a random (Haar-ish via Gram-Schmidt on Gaussian) rotation lifting
/// a (n, d_in) cloud into `d_out >= d_in` ambient dimensions, then add
/// isotropic Gaussian noise. This is how all generators "hide" their
/// low-dimensional structure inside a higher-dimensional ambient space.
fn lift(x: &Matrix, d_out: usize, noise: f64, rng: &mut Rng) -> Matrix {
    let d_in = x.d();
    assert!(d_out >= d_in);
    // Random orthonormal basis: d_in rows of length d_out.
    let mut basis: Vec<Vec<f32>> = Vec::with_capacity(d_in);
    for _ in 0..d_in {
        let mut v: Vec<f32> = (0..d_out).map(|_| rng.gauss() as f32).collect();
        for b in &basis {
            let proj = crate::data::matrix::dot(&v, b);
            for k in 0..d_out {
                v[k] -= proj * b[k];
            }
        }
        let norm = crate::data::matrix::dot(&v, &v).sqrt().max(1e-12);
        for vk in v.iter_mut() {
            *vk /= norm;
        }
        basis.push(v);
    }
    let mut out = Matrix::zeros(x.n(), d_out);
    for i in 0..x.n() {
        let src = x.row(i);
        let dst = out.row_mut(i);
        for (j, b) in basis.iter().enumerate() {
            let c = src[j];
            for k in 0..d_out {
                dst[k] += c * b[k];
            }
        }
        if noise > 0.0 {
            for dk in dst.iter_mut() {
                *dk += rng.gauss_ms(0.0, noise) as f32;
            }
        }
    }
    out
}

/// The classic S-curve: a 2-D sheet bent into an 'S' in 3-D (Fig. 1).
///
/// `unbalanced`: if set, the bottom half of the sheet is sampled 10×
/// less frequently, reproducing the bottom panel of Fig. 1.
pub fn scurve(n: usize, noise: f64, unbalanced: bool, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Matrix::zeros(n, 3);
    let mut labels = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        // t in [-3π/2, 3π/2]; label = top/bottom half.
        let t = rng.range_f64(-1.5 * std::f64::consts::PI, 1.5 * std::f64::consts::PI);
        let bottom = t < 0.0;
        if unbalanced && bottom && !rng.chance(0.1) {
            continue;
        }
        let u = rng.range_f64(0.0, 2.0);
        let row = x.row_mut(i);
        row[0] = (t.sin() + rng.gauss_ms(0.0, noise)) as f32;
        row[1] = (u + rng.gauss_ms(0.0, noise)) as f32;
        row[2] = ((t.cos().abs() * t.signum() - t.signum()) + rng.gauss_ms(0.0, noise)) as f32;
        labels.push(if bottom { 1 } else { 0 });
        i += 1;
    }
    Dataset {
        name: format!("scurve_n{n}{}", if unbalanced { "_unbalanced" } else { "" }),
        x,
        labels,
        coarse_labels: None,
        hierarchy: None,
    }
}

/// Isotropic Gaussian blobs (Figs 4, 6 middle, 7, 8, Table 1).
///
/// `centers` cluster centres drawn uniformly in a cube of side
/// `box_side`, each blob with std `std`. `d` ambient dimensions.
pub fn blobs(n: usize, d: usize, centers: usize, std: f64, box_side: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let c: Vec<Vec<f32>> = (0..centers)
        .map(|_| (0..d).map(|_| rng.range_f64(-box_side / 2.0, box_side / 2.0) as f32).collect())
        .collect();
    let mut x = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let k = i % centers; // balanced assignment
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = c[k][j] + rng.gauss_ms(0.0, std) as f32;
        }
        labels.push(k);
    }
    Dataset {
        name: format!("blobs_n{n}_d{d}_k{centers}"),
        x,
        labels,
        coarse_labels: None,
        hierarchy: None,
    }
}

/// Fig. 7 "Overlapping" preset: 5 wide Gaussians with heavy overlap.
pub fn blobs_overlapping(n: usize, d: usize, seed: u64) -> Dataset {
    let mut ds = blobs(n, d, 5, 2.0, 4.0, seed);
    ds.name = format!("blobs_overlap_n{n}_d{d}");
    ds
}

/// Fig. 7 "Disjointed" preset: 1000 tight, well-separated centres of 30
/// points each (the local-minimum trap for NN-descent).
pub fn blobs_disjointed(centers: usize, per_center: usize, d: usize, seed: u64) -> Dataset {
    let n = centers * per_center;
    let mut ds = blobs(n, d, centers, 0.05, 40.0, seed);
    ds.name = format!("blobs_disjoint_c{centers}_p{per_center}_d{d}");
    ds
}

/// COIL-20 twin (Fig. 6 bottom): `objects` closed 1-D ring manifolds
/// (image sequences of rotating objects) lifted into `d_out` dims.
///
/// Each object is a circle with object-specific radius/phase in its own
/// random 2-D plane of the ambient space, plus small noise — preserving
/// what the experiment needs: per-object ring topology, inter-object
/// separation, local neighbourhoods that follow the rotation angle.
pub fn coil_like(objects: usize, per_object: usize, d_out: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let n = objects * per_object;
    let mut intrinsic = Matrix::zeros(n, 3);
    let mut labels = Vec::with_capacity(n);
    for o in 0..objects {
        let radius = rng.range_f64(2.0, 4.0);
        let phase = rng.range_f64(0.0, std::f64::consts::TAU);
        let zc = rng.range_f64(-20.0, 20.0); // object separation axis
        for p in 0..per_object {
            let i = o * per_object + p;
            let a = phase + std::f64::consts::TAU * p as f64 / per_object as f64;
            let row = intrinsic.row_mut(i);
            row[0] = (radius * a.cos()) as f32;
            row[1] = (radius * a.sin()) as f32;
            row[2] = zc as f32;
            labels.push(o);
        }
    }
    // Rotate each object's ring into its own plane by lifting the whole
    // cloud and adding per-object offsets in the ambient space.
    let mut x = lift(&intrinsic, d_out, 0.05, &mut rng);
    for o in 0..objects {
        let offset: Vec<f32> = (0..d_out).map(|_| rng.gauss_ms(0.0, 3.0) as f32).collect();
        for p in 0..per_object {
            let row = x.row_mut(o * per_object + p);
            for k in 0..d_out {
                row[k] += offset[k];
            }
        }
    }
    Dataset {
        name: format!("coil_like_o{objects}_p{per_object}"),
        x,
        labels,
        coarse_labels: None,
        hierarchy: None,
    }
}

/// MNIST twin (Figs 3, 9): 10 digit classes with *planted sub-structure*.
///
/// What Fig. 3 requires of the data:
/// * class "1" lies on a 1-D manifold (tilt angle) with two density dips
///   → fragments into 3 sub-clusters at heavy tails;
/// * class "4" has 4 sub-modes separated by density dips → fragments into
///   4 clusters between α=0.5 and α=0.4;
/// * classes {3,5,8} and {4,9,7} are each mutually close (the Fig. 9
///   late-speciation groups), "1" is far from everything except "2".
///
/// The generator plants exactly these: class centres on a fixed layout
/// whose pairwise distances encode the affinity groups, per-class
/// sub-mode mixtures with controlled dip depth, lifted to `d_out` dims.
pub fn mnist_like(n: usize, d_out: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let intrinsic_d = 8;
    // Class centres: groups {3,5,8}, {4,9,7}, {1,2} are near each other.
    let group_of = [0usize, 1, 1, 2, 3, 2, 4, 3, 2, 3]; // digit -> group
    let mut group_centres: Vec<Vec<f32>> = Vec::new();
    for _ in 0..5 {
        group_centres.push((0..intrinsic_d).map(|_| rng.gauss_ms(0.0, 9.0) as f32).collect());
    }
    let mut class_centres: Vec<Vec<f32>> = Vec::new();
    for digit in 0..10 {
        let g = &group_centres[group_of[digit]];
        class_centres
            .push(g.iter().map(|&v| v + rng.gauss_ms(0.0, 2.4) as f32).collect());
    }
    // Sub-mode plan per class: (n_modes, dip_separation)
    let sub_modes: [usize; 10] = [2, 3, 2, 2, 4, 2, 2, 2, 3, 2];
    let mut intrinsic = Matrix::zeros(n, intrinsic_d);
    let mut labels = Vec::with_capacity(n);
    let mut sub_labels = Vec::with_capacity(n);
    let mut sub_id_base = 0usize;
    let mut class_sub_base = [0usize; 10];
    for digit in 0..10 {
        class_sub_base[digit] = sub_id_base;
        sub_id_base += sub_modes[digit];
    }
    for i in 0..n {
        let digit = i % 10;
        let c = &class_centres[digit];
        let m = sub_modes[digit];
        let row = intrinsic.row_mut(i);
        if digit == 1 {
            // 1-D tilt-angle manifold with density dips at mode borders:
            // sample t from a trimodal distribution on [-1, 1].
            let mode = rng.below(m);
            let centre = -0.8 + 1.6 * mode as f64 / (m - 1).max(1) as f64;
            let t = centre + rng.gauss_ms(0.0, 0.14);
            for (k, rk) in row.iter_mut().enumerate() {
                *rk = c[k]
                    + if k == 0 { (t * 3.0) as f32 } else { rng.gauss_ms(0.0, 0.25) as f32 };
            }
            sub_labels.push(class_sub_base[digit] + mode);
        } else {
            let mode = rng.below(m);
            // Sub-mode displacement along a class-specific direction with
            // a real density dip between modes (separation 2.8 σ).
            let dir = (digit * 3 + 1) % intrinsic_d;
            let sep = 1.15f32;
            for (k, rk) in row.iter_mut().enumerate() {
                let base = c[k] + rng.gauss_ms(0.0, 0.4) as f32;
                *rk = if k == dir {
                    base + sep * (mode as f32 - (m as f32 - 1.0) / 2.0)
                } else {
                    base
                };
            }
            sub_labels.push(class_sub_base[digit] + mode);
        }
        labels.push(digit);
    }
    let x = lift(&intrinsic, d_out, 0.08, &mut rng);
    Dataset {
        name: format!("mnist_like_n{n}"),
        x,
        labels: sub_labels,
        coarse_labels: Some(labels),
        hierarchy: None,
    }
}

/// Rat-brain scRNA-seq twin (Figs 2, 5, 6 top, 10).
///
/// Three root cell types (non-neuron / inhibitory / excitatory) splitting
/// into subtypes and then leaf clusters — a 3-level taxonomy with
/// log-normal-ish spread, mimicking Tasic et al. [2]. The ground-truth
/// tree is returned in `hierarchy` (leaf → subtype id) and
/// `coarse_labels` (point → root type) so Fig. 10 can compare the
/// recovered cluster graph against the planted dendrogram.
pub fn rat_brain_like(n: usize, d_out: usize, seed: u64) -> Dataset {
    hierarchical_cells("rat_brain_like", n, d_out, &[5, 12, 16], seed)
}

/// Tabula-Muris twin (Fig. 5 right): more tissues, flatter hierarchy.
pub fn tabula_like(n: usize, d_out: usize, seed: u64) -> Dataset {
    hierarchical_cells("tabula_like", n, d_out, &[8, 20, 26], seed)
}

/// Shared 3-level hierarchical cell-population generator.
///
/// `shape = [roots, subtypes, leaves]` — total counts at each level;
/// subtypes are assigned to roots, leaves to subtypes, both randomly but
/// deterministically.
fn hierarchical_cells(name: &str, n: usize, d_out: usize, shape: &[usize; 3], seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let intrinsic_d = 10;
    let (n_root, n_sub, n_leaf) = (shape[0], shape[1], shape[2]);
    let root_c: Vec<Vec<f32>> = (0..n_root)
        .map(|_| (0..intrinsic_d).map(|_| rng.gauss_ms(0.0, 10.0) as f32).collect())
        .collect();
    let sub_parent: Vec<usize> = (0..n_sub)
        .map(|s| if s < n_root { s } else { rng.below(n_root) })
        .collect();
    let sub_c: Vec<Vec<f32>> = (0..n_sub)
        .map(|s| {
            root_c[sub_parent[s]]
                .iter()
                .map(|&v| v + rng.gauss_ms(0.0, 3.0) as f32)
                .collect()
        })
        .collect();
    let leaf_parent: Vec<usize> = (0..n_leaf)
        .map(|l| if l < n_sub { l } else { rng.below(n_sub) })
        .collect();
    let leaf_c: Vec<Vec<f32>> = (0..n_leaf)
        .map(|l| {
            sub_c[leaf_parent[l]]
                .iter()
                .map(|&v| v + rng.gauss_ms(0.0, 1.1) as f32)
                .collect()
        })
        .collect();
    // Leaf sizes: power-law-ish (single-cell cluster sizes are skewed).
    let mut weights: Vec<f64> = (0..n_leaf).map(|_| rng.f64().powf(1.5) + 0.05).collect();
    let wsum: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= wsum;
    }
    let mut intrinsic = Matrix::zeros(n, intrinsic_d);
    let mut labels = Vec::with_capacity(n);
    let mut coarse = Vec::with_capacity(n);
    for i in 0..n {
        // Sample a leaf proportional to weight.
        let mut u = rng.f64();
        let mut leaf = n_leaf - 1;
        for (l, &w) in weights.iter().enumerate() {
            if u < w {
                leaf = l;
                break;
            }
            u -= w;
        }
        let c = &leaf_c[leaf];
        let row = intrinsic.row_mut(i);
        for (k, rk) in row.iter_mut().enumerate() {
            *rk = c[k] + rng.gauss_ms(0.0, 0.55) as f32;
        }
        labels.push(leaf);
        coarse.push(sub_parent[leaf_parent[leaf]]);
    }
    let x = lift(&intrinsic, d_out, 0.12, &mut rng);
    Dataset {
        name: format!("{name}_n{n}"),
        x,
        labels,
        coarse_labels: Some(coarse),
        hierarchy: Some(leaf_parent),
    }
}

/// Deep-feature twin of EVA(ImageNet) (Table 2, Fig. 11).
///
/// What Table 2 requires of the data: raw ambient features where 1-NN
/// one-shot classification is *mediocre* (class manifolds are elongated /
/// heteroscedastic so a single labelled sample is often closer to another
/// class's fringe), while the classes are nonetheless separable given the
/// full neighbourhood structure — so that concentrating each class with a
/// 32-d NE dramatically improves one-shot accuracy.
///
/// Construction: each class is an anisotropic Gaussian whose top few
/// principal directions are *shared across classes* (a "style" subspace,
/// large variance, class-uninformative) plus a small class-specific
/// offset in a "content" subspace (small variance, class-informative).
/// 1-NN with one shot is dominated by the style variance; neighbourhood
/// graphs (many samples per class) still connect within-class points.
pub fn deep_features(
    n: usize,
    classes: usize,
    d_out: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let style_d = 12; // shared high-variance nuisance subspace
    let content_d = 16; // class-identity subspace
    let intrinsic_d = style_d + content_d;
    let class_c: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..content_d).map(|_| rng.gauss_ms(0.0, 1.0) as f32).collect())
        .collect();
    let mut intrinsic = Matrix::zeros(n, intrinsic_d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let k = i % classes;
        let row = intrinsic.row_mut(i);
        for (s, rs) in row.iter_mut().take(style_d).enumerate() {
            // Heavy shared style variance, heteroscedastic per dimension.
            let sd = 2.5 / (1.0 + s as f64 * 0.35);
            *rs = rng.gauss_ms(0.0, sd) as f32;
        }
        for c in 0..content_d {
            row[style_d + c] = class_c[k][c] + rng.gauss_ms(0.0, 0.42) as f32;
        }
        labels.push(k);
    }
    let x = lift(&intrinsic, d_out, 0.25, &mut rng);
    Dataset {
        name: format!("deep_features_n{n}_c{classes}"),
        x,
        labels,
        coarse_labels: None,
        hierarchy: None,
    }
}

/// Nested blobs with a known 2-level tree, used by the hierarchy
/// integration tests: `super_k` super-clusters each containing `sub_k`
/// sub-clusters.
pub fn nested_blobs(
    n: usize,
    d: usize,
    super_k: usize,
    sub_k: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let supers: Vec<Vec<f32>> = (0..super_k)
        .map(|_| (0..d).map(|_| rng.gauss_ms(0.0, 25.0) as f32).collect())
        .collect();
    let mut leaf_c = Vec::new();
    let mut leaf_parent = Vec::new();
    for (s, sc) in supers.iter().enumerate() {
        for _ in 0..sub_k {
            leaf_c.push(sc.iter().map(|&v| v + rng.gauss_ms(0.0, 3.0) as f32).collect::<Vec<f32>>());
            leaf_parent.push(s);
        }
    }
    let leaves = leaf_c.len();
    let mut x = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    let mut coarse = Vec::with_capacity(n);
    for i in 0..n {
        let l = i % leaves;
        let row = x.row_mut(i);
        for k in 0..d {
            row[k] = leaf_c[l][k] + rng.gauss_ms(0.0, 0.4) as f32;
        }
        labels.push(l);
        coarse.push(leaf_parent[l]);
    }
    Dataset {
        name: format!("nested_blobs_{super_k}x{sub_k}"),
        x,
        labels,
        coarse_labels: Some(coarse),
        hierarchy: Some(leaf_parent),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::dist;

    #[test]
    fn scurve_shapes_and_labels() {
        let ds = scurve(500, 0.01, false, 1);
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.labels.len(), 500);
        let top = ds.labels.iter().filter(|&&l| l == 0).count();
        assert!(top > 150 && top < 350, "balanced halves, got top={top}");
    }

    #[test]
    fn scurve_unbalanced_has_sparse_bottom() {
        let ds = scurve(2000, 0.01, true, 2);
        let bottom = ds.labels.iter().filter(|&&l| l == 1).count();
        // bottom sampled 10x less: expect ~ 1/11 of points
        assert!(
            bottom < 2000 / 5,
            "unbalanced bottom fraction too large: {bottom}/2000"
        );
    }

    #[test]
    fn blobs_separated_when_std_small() {
        let ds = blobs(300, 8, 3, 0.01, 20.0, 3);
        // Points sharing a label should be much closer than across labels.
        let mut same = 0.0f64;
        let mut diff = 0.0f64;
        let (mut ns, mut nd) = (0, 0);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let dd = dist(ds.x.row(i), ds.x.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    same += dd;
                    ns += 1;
                } else {
                    diff += dd;
                    nd += 1;
                }
            }
        }
        assert!(same / ns as f64 * 5.0 < diff / nd as f64);
    }

    #[test]
    fn disjointed_preset_is_tight() {
        let ds = blobs_disjointed(50, 10, 16, 4);
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.n_classes(), 50);
    }

    #[test]
    fn coil_rings_are_closed() {
        let per = 36;
        let ds = coil_like(4, per, 24, 5);
        // Consecutive frames of an object are close; frame 0 and frame
        // per-1 are also close (ring closure).
        for o in 0..4 {
            let base = o * per;
            let step = dist(ds.x.row(base), ds.x.row(base + 1));
            let closure = dist(ds.x.row(base), ds.x.row(base + per - 1));
            let opposite = dist(ds.x.row(base), ds.x.row(base + per / 2));
            assert!(closure < opposite, "ring not closed for object {o}");
            assert!(step < opposite, "ring not locally continuous for {o}");
        }
    }

    #[test]
    fn mnist_like_has_subclusters_and_classes() {
        let ds = mnist_like(1000, 32, 6);
        assert_eq!(ds.n(), 1000);
        assert_eq!(ds.d(), 32);
        let coarse = ds.coarse_labels.as_ref().unwrap();
        assert_eq!(coarse.iter().copied().max().unwrap(), 9);
        // sub-cluster labels outnumber classes
        assert!(ds.n_classes() > 10);
    }

    #[test]
    fn rat_brain_hierarchy_is_consistent() {
        let ds = rat_brain_like(800, 50, 7);
        let h = ds.hierarchy.as_ref().unwrap();
        assert_eq!(h.len(), 16); // leaves
        assert!(h.iter().all(|&p| p < 12)); // parents are subtype ids
        let coarse = ds.coarse_labels.as_ref().unwrap();
        assert!(coarse.iter().all(|&c| c < 5));
    }

    #[test]
    fn deep_features_style_dominates_pairwise_distance() {
        let ds = deep_features(400, 20, 64, 8);
        // With one sample per class, nearest neighbour should often be a
        // different class (the Table-2 premise): check that within-class
        // distances are NOT much smaller than between-class distances.
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = dist(ds.x.row(i), ds.x.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    same.push(dd);
                } else {
                    diff.push(dd);
                }
            }
        }
        let ms = crate::util::stats::mean(&same);
        let md = crate::util::stats::mean(&diff);
        assert!(ms / md > 0.6, "style noise should blur 1-NN margins: {ms} vs {md}");
        assert!(ms < md, "classes must still be statistically separable");
    }

    #[test]
    fn nested_blobs_tree_shape() {
        let ds = nested_blobs(600, 10, 3, 4, 9);
        assert_eq!(ds.hierarchy.as_ref().unwrap().len(), 12);
        assert_eq!(ds.n_classes(), 12);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = mnist_like(200, 16, 42);
        let b = mnist_like(200, 16, 42);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.labels, b.labels);
    }
}
