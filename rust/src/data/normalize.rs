//! Column-wise normalisation / standardisation.
//!
//! NE pipelines conventionally standardise (or at least centre) the HD
//! data before computing distances; the figure drivers use these helpers
//! so every method baseline sees the same preprocessing.

use super::matrix::Matrix;

/// Centre columns and scale each to unit variance (σ floor 1e-6).
pub fn standardize(x: &mut Matrix) {
    let n = x.n();
    let d = x.d();
    if n == 0 {
        return;
    }
    let means = x.center();
    let _ = means;
    let mut var = vec![0.0f64; d];
    for i in 0..n {
        for (k, &v) in x.row(i).iter().enumerate() {
            var[k] += (v as f64) * (v as f64);
        }
    }
    let inv_std: Vec<f32> =
        var.iter().map(|&v| (1.0 / (v / n as f64).sqrt().max(1e-6)) as f32).collect();
    for i in 0..n {
        for (k, v) in x.row_mut(i).iter_mut().enumerate() {
            *v *= inv_std[k];
        }
    }
}

/// Rescale the whole cloud so its mean pairwise scale is O(1):
/// divide by the RMS of coordinates. Keeps relative geometry intact.
pub fn rms_scale(x: &mut Matrix) {
    let n = x.n() * x.d();
    if n == 0 {
        return;
    }
    let rms =
        (x.data().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64).sqrt();
    if rms > 1e-12 {
        let inv = (1.0 / rms) as f32;
        for v in x.data_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::Rng;

    #[test]
    fn standardize_gives_unit_columns() {
        let mut rng = Rng::new(3);
        let mut x = Matrix::from_vec(pt::gauss_mat(&mut rng, 200, 5, 7.0), 200, 5).unwrap();
        standardize(&mut x);
        for k in 0..5 {
            let mut m = 0.0f64;
            let mut v = 0.0f64;
            for i in 0..200 {
                m += x.row(i)[k] as f64;
            }
            m /= 200.0;
            for i in 0..200 {
                let c = x.row(i)[k] as f64 - m;
                v += c * c;
            }
            v /= 200.0;
            assert!(m.abs() < 1e-4);
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn rms_scale_sets_rms_to_one() {
        let mut rng = Rng::new(4);
        let mut x = Matrix::from_vec(pt::gauss_mat(&mut rng, 64, 3, 12.0), 64, 3).unwrap();
        rms_scale(&mut x);
        let rms = (x.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / x.data().len() as f64)
            .sqrt();
        assert!((rms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let mut empty = Matrix::zeros(0, 3);
        standardize(&mut empty);
        rms_scale(&mut empty);
        let mut constant = Matrix::from_vec(vec![5.0; 12], 4, 3).unwrap();
        standardize(&mut constant);
        assert!(constant.data().iter().all(|v| v.is_finite()));
    }
}
