//! Data substrate: matrices, synthetic dataset generators, normalisation.
//!
//! The paper evaluates on MNIST, rat-brain / Tabula-Muris scRNA-seq,
//! COIL-20, Gaussian blobs, an S-curve, and EVA features of ImageNet.
//! None of those are downloadable in this offline environment, so each is
//! replaced by a structural twin generated here (see DESIGN.md §3 for the
//! substitution rationale). Every generator takes an explicit seed and is
//! fully deterministic.

pub mod matrix;
pub mod normalize;
pub mod datasets;

pub use datasets::Dataset;
pub use matrix::Matrix;
