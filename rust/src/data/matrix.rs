//! A minimal row-major f32 matrix.
//!
//! All point clouds (HD data, LD embeddings) are stored as `Matrix`:
//! contiguous row-major storage so that a point's coordinates are one
//! cache line run, which the KNN and force hot loops rely on.

use crate::util::simd::{F32x8, LANES};
use anyhow::{bail, Result};

/// Row-major (n, d) matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    n: usize,
    d: usize,
}

impl Matrix {
    /// Zero-filled (n, d).
    pub fn zeros(n: usize, d: usize) -> Self {
        Matrix { data: vec![0.0; n * d], n, d }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(data: Vec<f32>, n: usize, d: usize) -> Result<Self> {
        if data.len() != n * d {
            bail!("matrix buffer length {} != {}x{}", data.len(), n, d);
        }
        Ok(Matrix { data, n, d })
    }

    #[inline(always)]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline(always)]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Row `i` as a slice of length `d`.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline(always)]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline(always)]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Squared Euclidean distance between rows i and j.
    #[inline(always)]
    pub fn sqdist(&self, i: usize, j: usize) -> f32 {
        sqdist(self.row(i), self.row(j))
    }

    /// Column means.
    pub fn col_means(&self) -> Vec<f32> {
        let mut m = vec![0.0f64; self.d];
        for i in 0..self.n {
            for (k, &v) in self.row(i).iter().enumerate() {
                m[k] += v as f64;
            }
        }
        m.iter().map(|&v| (v / self.n.max(1) as f64) as f32).collect()
    }

    /// Subtract column means in place; returns the means.
    pub fn center(&mut self) -> Vec<f32> {
        let means = self.col_means();
        for i in 0..self.n {
            let row = self.row_mut(i);
            for (k, v) in row.iter_mut().enumerate() {
                *v -= means[k];
            }
        }
        means
    }

    /// Append a row, growing n by one.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.d);
        self.data.extend_from_slice(row);
        self.n += 1;
    }

    /// Remove row `i` by swapping in the last row (O(d)); returns the
    /// index that moved into `i` (the old last row), if any.
    pub fn swap_remove_row(&mut self, i: usize) -> Option<usize> {
        assert!(i < self.n);
        let last = self.n - 1;
        if i != last {
            // swap rows i and last
            for k in 0..self.d {
                self.data.swap(i * self.d + k, last * self.d + k);
            }
        }
        self.data.truncate(last * self.d);
        self.n = last;
        if i != last {
            Some(last)
        } else {
            None
        }
    }

    /// Transpose-gather eight rows into a structure-of-arrays lane
    /// tile: after the call, `tile[k].0[l] == self.row(idx[l])[k]` for
    /// every coordinate `k < d` and lane `l`.
    ///
    /// This is the SoA view the SIMD force kernels run on: the
    /// row-major `Matrix` stays the storage of record (the scalar
    /// backends and the rest of the system are untouched), and a
    /// ~`d * 32`-byte register-friendly tile is materialized per
    /// 8-neighbour group right before the lane math. Callers with
    /// fewer than 8 live neighbours pad `idx` with a self-index so the
    /// padded lanes compute a zero delta; `tile` must have at least
    /// `d` slots.
    #[inline(always)]
    pub fn gather_lanes(&self, idx: &[u32; LANES], tile: &mut [F32x8]) {
        for (l, &i) in idx.iter().enumerate() {
            for (k, &v) in self.row(i as usize).iter().enumerate() {
                tile[k].0[l] = v;
            }
        }
    }

    /// Gather a subset of rows into a new matrix.
    pub fn take_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.d);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }
}

/// Squared Euclidean distance of two equal-length slices.
///
/// This is the single hottest scalar routine in the whole system (KNN
/// candidate scoring); it is written as a 4-way unrolled accumulator so
/// LLVM auto-vectorises it.
#[inline(always)]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
    }
    let mut acc = (acc0 + acc1) + (acc2 + acc3);
    for i in chunks * 4..n {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Euclidean distance.
#[inline(always)]
pub fn dist(a: &[f32], b: &[f32]) -> f32 {
    sqdist(a, b).sqrt()
}

/// Dot product (used by PCA power iteration).
#[inline(always)]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::Rng;

    #[test]
    fn zeros_shape() {
        let m = Matrix::zeros(3, 4);
        assert_eq!(m.n(), 3);
        assert_eq!(m.d(), 4);
        assert!(m.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_vec_checks_len() {
        assert!(Matrix::from_vec(vec![0.0; 5], 2, 3).is_err());
        assert!(Matrix::from_vec(vec![0.0; 6], 2, 3).is_ok());
    }

    #[test]
    fn sqdist_matches_naive() {
        pt::check("sqdist-naive", 64, |rng, _| {
            let d = rng.range_usize(1, 40);
            let a = pt::vec_f32(rng, d, 3.0);
            let b = pt::vec_f32(rng, d, 3.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let fast = sqdist(&a, &b);
            crate::prop_assert!(
                (naive - fast).abs() <= 1e-4 * (1.0 + naive.abs()),
                "naive={naive} fast={fast} d={d}"
            );
            Ok(())
        });
    }

    #[test]
    fn center_zeroes_means() {
        let mut rng = Rng::new(2);
        let mut m = Matrix::from_vec(pt::gauss_mat(&mut rng, 50, 7, 2.0), 50, 7).unwrap();
        m.center();
        for mean in m.col_means() {
            assert!(mean.abs() < 1e-4);
        }
    }

    #[test]
    fn push_and_swap_remove() {
        let mut m = Matrix::zeros(0, 2);
        m.push_row(&[1.0, 2.0]);
        m.push_row(&[3.0, 4.0]);
        m.push_row(&[5.0, 6.0]);
        assert_eq!(m.n(), 3);
        // Remove middle: last row moves into slot 1.
        let moved = m.swap_remove_row(1);
        assert_eq!(moved, Some(2));
        assert_eq!(m.n(), 2);
        assert_eq!(m.row(1), &[5.0, 6.0]);
        // Remove last: nothing moves.
        assert_eq!(m.swap_remove_row(1), None);
        assert_eq!(m.n(), 1);
    }

    #[test]
    fn gather_lanes_transposes_rows() {
        let mut rng = Rng::new(7);
        let d = 5;
        let m = Matrix::from_vec(pt::gauss_mat(&mut rng, 12, d, 2.0), 12, d).unwrap();
        let idx: [u32; 8] = [3, 0, 11, 7, 7, 2, 9, 1];
        let mut tile = [F32x8::ZERO; 8];
        m.gather_lanes(&idx, &mut tile[..d]);
        for (l, &i) in idx.iter().enumerate() {
            for k in 0..d {
                assert_eq!(tile[k].0[l].to_bits(), m.row(i as usize)[k].to_bits());
            }
        }
        // Slots past d are untouched.
        assert_eq!(tile[d].0, [0.0; 8]);
    }

    #[test]
    fn take_rows_gathers() {
        let m = Matrix::from_vec(vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0], 3, 2).unwrap();
        let s = m.take_rows(&[2, 0]);
        assert_eq!(s.row(0), &[2.0, 2.0]);
        assert_eq!(s.row(1), &[0.0, 0.0]);
    }
}
