//! Baseline NE methods the paper compares against (see DESIGN.md §3 for
//! the FIt-SNE→BH substitution note).

pub mod exact_tsne;
pub mod bhtsne;
pub mod umap_like;
