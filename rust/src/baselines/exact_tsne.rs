//! Exact O(N²) heavy-tailed t-SNE — the reference optimiser.
//!
//! Serves three roles: (i) the "t-SNE" panels of Figs 1/2, (ii) the
//! exact-gradient oracle for Table 1's repulsive-field error analysis,
//! (iii) a correctness anchor for the accelerated engine (both optimise
//! the same Eq. 4 objective; at small N their quality must agree).
//!
//! α = 1 reproduces classic t-SNE; other α give the Kobak et al. [10]
//! heavy-tailed variant.

use crate::data::Matrix;
use crate::hd::perplexity::{calibrate, conditionals};
use crate::ld::kernel::kernel_pair;
use crate::util::Rng;

/// Configuration (subset of the engine's, for apples-to-apples panels).
#[derive(Clone, Debug)]
pub struct TsneConfig {
    pub ld_dim: usize,
    pub alpha: f64,
    pub perplexity: f64,
    pub n_iters: usize,
    pub lr: f64,
    pub momentum: f64,
    pub early_exag: f64,
    pub early_exag_iters: usize,
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig {
            ld_dim: 2,
            alpha: 1.0,
            perplexity: 30.0,
            n_iters: 500,
            lr: 100.0,
            momentum: 0.7,
            early_exag: 4.0,
            early_exag_iters: 100,
            seed: 42,
        }
    }
}

/// Symmetrised dense P matrix (row-major n×n, Σ = 1).
pub fn dense_p(x: &Matrix, perplexity: f64) -> Vec<f32> {
    let n = x.n();
    let mut p = vec![0.0f32; n * n];
    let mut sq = vec![0.0f32; n - 1];
    let mut cond = vec![0.0f32; n - 1];
    for i in 0..n {
        let mut t = 0;
        for j in 0..n {
            if j != i {
                sq[t] = x.sqdist(i, j);
                t += 1;
            }
        }
        let cal = calibrate(&sq, perplexity, None);
        conditionals(&sq, cal.beta, &mut cond);
        let mut t = 0;
        for j in 0..n {
            if j != i {
                p[i * n + j] = cond[t];
                t += 1;
            }
        }
    }
    // Symmetrise: p_ij = (p_{j|i} + p_{i|j}) / (2n)  (Σ over ordered pairs = 1)
    for i in 0..n {
        for j in (i + 1)..n {
            let v = (p[i * n + j] + p[j * n + i]) / (2.0 * n as f32);
            p[i * n + j] = v;
            p[j * n + i] = v;
        }
    }
    for i in 0..n {
        p[i * n + i] = 0.0;
    }
    p
}

/// Exact per-point *movement* directions (negative gradient / 4) at the
/// current embedding, split into attraction and repulsion components
/// (Table 1 needs the split).
pub fn exact_gradient_split(y: &Matrix, p: &[f32], alpha: f32) -> (Matrix, Matrix) {
    let n = y.n();
    let d = y.d();
    // Z = Σ_{k≠l} w_kl
    let mut z = 0.0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let (w, _) = kernel_pair(y.sqdist(i, j), alpha);
            z += 2.0 * w as f64;
        }
    }
    let zinv = (1.0 / z.max(1e-300)) as f32;
    let mut attr = Matrix::zeros(n, d);
    let mut rep = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..n {
            if j == i {
                continue;
            }
            let (w, g) = kernel_pair(y.sqdist(i, j), alpha);
            let pij = p[i * n + j];
            let q = w * zinv;
            for c in 0..d {
                let delta = y.row(i)[c] - y.row(j)[c];
                attr.data_mut()[i * d + c] += pij * g * (-delta);
                rep.data_mut()[i * d + c] += q * g * delta;
            }
        }
    }
    (attr, rep)
}

/// Run exact heavy-tailed t-SNE; returns the embedding.
pub fn exact_tsne(x: &Matrix, cfg: &TsneConfig) -> Matrix {
    let n = x.n();
    let d = cfg.ld_dim;
    let p = dense_p(x, cfg.perplexity);
    let mut rng = Rng::new(cfg.seed);
    let mut y = Matrix::zeros(n, d);
    for v in y.data_mut() {
        *v = rng.gauss_ms(0.0, 1e-2) as f32;
    }
    let mut vel = Matrix::zeros(n, d);
    let alpha = cfg.alpha as f32;
    let mut p_work = p.clone();
    for iter in 0..cfg.n_iters {
        let exag = if iter < cfg.early_exag_iters { cfg.early_exag as f32 } else { 1.0 };
        if exag != 1.0 || iter == cfg.early_exag_iters {
            for (w, orig) in p_work.iter_mut().zip(&p) {
                *w = orig * exag;
            }
        }
        let (attr, rep) = exact_gradient_split(&y, &p_work, alpha);
        let lr = cfg.lr as f32;
        let mom = cfg.momentum as f32;
        for t in 0..y.data().len() {
            let grad = attr.data()[t] + rep.data()[t];
            vel.data_mut()[t] = mom * vel.data_mut()[t] + lr * grad;
            y.data_mut()[t] += vel.data()[t];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::metrics::rnx_auc;

    #[test]
    fn dense_p_is_symmetric_normalised() {
        let ds = datasets::blobs(60, 5, 3, 0.5, 6.0, 1);
        let p = dense_p(&ds.x, 10.0);
        let n = 60;
        let total: f64 = p.iter().map(|&v| v as f64).sum();
        assert!((total - 1.0).abs() < 1e-4, "ΣP = {total}");
        for i in 0..n {
            assert_eq!(p[i * n + i], 0.0);
            for j in 0..n {
                assert!((p[i * n + j] - p[j * n + i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn exact_tsne_separates_blobs() {
        let ds = datasets::blobs(150, 8, 3, 0.4, 12.0, 2);
        let cfg = TsneConfig { n_iters: 250, perplexity: 15.0, ..TsneConfig::default() };
        let y = exact_tsne(&ds.x, &cfg);
        assert!(y.data().iter().all(|v| v.is_finite()));
        let auc = rnx_auc(&ds.x, &y, 40);
        assert!(auc > 0.35, "exact t-SNE quality too low: AUC {auc}");
    }

    #[test]
    fn gradient_split_signs() {
        // Two neighbouring points in HD placed far apart in LD:
        // attraction points toward the HD neighbour.
        let x = Matrix::from_vec(vec![0.0, 0.0, 0.1, 0.0, 5.0, 5.0, 5.1, 5.0], 4, 2).unwrap();
        let p = dense_p(&x, 2.0);
        let y = Matrix::from_vec(vec![0.0, 0.0, 3.0, 0.0, 0.0, 3.0, 3.0, 3.0], 4, 2).unwrap();
        let (attr, rep) = exact_gradient_split(&y, &p, 1.0);
        assert!(attr.row(0)[0] > 0.0, "attraction should pull toward HD neighbour");
        assert!(rep.row(0)[0] < 0.0 || rep.row(0)[1] < 0.0);
    }

    #[test]
    fn heavy_tails_compact_clusters() {
        // The qualitative Fig. 3 effect, measured crudely: with heavier
        // tails the same-cluster/all-pairs distance ratio shrinks.
        let ds = datasets::blobs(120, 8, 4, 0.5, 10.0, 3);
        let run = |alpha: f64| {
            let cfg =
                TsneConfig { alpha, n_iters: 200, perplexity: 10.0, ..TsneConfig::default() };
            let y = exact_tsne(&ds.x, &cfg);
            let (mut same, mut all) = (Vec::new(), Vec::new());
            for i in 0..120 {
                for j in (i + 1)..120 {
                    let d = (y.sqdist(i, j) as f64).sqrt();
                    all.push(d);
                    if ds.labels[i] == ds.labels[j] {
                        same.push(d);
                    }
                }
            }
            crate::util::stats::mean(&same) / crate::util::stats::mean(&all).max(1e-12)
        };
        let t_ratio = run(1.0);
        let heavy_ratio = run(0.4);
        assert!(
            heavy_ratio < t_ratio + 0.05,
            "heavy tails should compact clusters: α=0.4 ratio {heavy_ratio} vs α=1 {t_ratio}"
        );
    }
}
