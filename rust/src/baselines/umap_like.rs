//! UMAP-like negative-sampling neighbour embedding (McInnes et al. [8]).
//!
//! The "fast but coarse" baseline of Figs 6/8 and Table 1: repulsion is
//! estimated *only* from a handful of uniform negative samples per edge,
//! using UMAP's cross-entropy force expressions with the standard
//! (a, b) curve for min_dist ≈ 0.1. Per-epoch edge sampling follows
//! UMAP's epochs_per_sample scheme in simplified form (edges sampled
//! proportionally to their fuzzy weight).

use crate::config::KnnConfig;
use crate::data::Matrix;
use crate::knn::brute::brute_knn;
use crate::knn::nn_descent::nn_descent;
use crate::util::Rng;

/// UMAP-like configuration.
#[derive(Clone, Debug)]
pub struct UmapConfig {
    pub ld_dim: usize,
    pub k: usize,
    pub n_epochs: usize,
    pub neg_per_edge: usize,
    pub lr: f64,
    /// Curve parameters (defaults fit min_dist=0.1, spread=1.0).
    pub a: f64,
    pub b: f64,
    pub seed: u64,
    pub exact_knn_below: usize,
}

impl Default for UmapConfig {
    fn default() -> Self {
        UmapConfig {
            ld_dim: 2,
            k: 15,
            n_epochs: 300,
            neg_per_edge: 5,
            lr: 1.0,
            a: 1.577,
            b: 0.895,
            seed: 42,
            exact_knn_below: 2500,
        }
    }
}

/// Fuzzy simplicial edge list: (i, j, weight) with UMAP's smooth-knn
/// calibration and probabilistic t-conorm symmetrisation.
pub fn fuzzy_graph(x: &Matrix, k: usize, seed: u64, exact_below: usize) -> Vec<(u32, u32, f32)> {
    let n = x.n();
    let k = k.min(n - 1);
    let table = if n <= exact_below {
        brute_knn(x, k)
    } else {
        nn_descent(x, &KnnConfig { k, seed, ..KnnConfig::default() }).table
    };
    // Per point: rho_i = nearest distance; sigma_i by binary search s.t.
    // sum_j exp(-(d_ij - rho)/sigma) = log2(k).
    let target = (k as f64).log2();
    let mut directed = vec![0.0f32; n * k];
    let mut ids = vec![u32::MAX; n * k];
    for i in 0..n {
        let mut dists: Vec<(u32, f32)> = table.entries(i).map(|(j, d)| (j, d.sqrt())).collect();
        dists.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        if dists.is_empty() {
            continue;
        }
        let rho = dists[0].1;
        let (mut lo, mut hi) = (1e-4f64, 1e4f64);
        let mut sigma = 1.0f64;
        for _ in 0..48 {
            sigma = (lo + hi) / 2.0;
            let s: f64 = dists
                .iter()
                .map(|&(_, d)| (-(((d - rho).max(0.0)) as f64) / sigma).exp())
                .sum();
            if s > target {
                hi = sigma;
            } else {
                lo = sigma;
            }
        }
        for (s, &(j, d)) in dists.iter().enumerate() {
            ids[i * k + s] = j;
            directed[i * k + s] = (-(((d - rho).max(0.0)) as f64) / sigma).exp() as f32;
        }
    }
    // Symmetrise with the probabilistic t-conorm: w = a + b − a·b.
    // BTreeMap so the edge list comes out in (i, j) order — edge order
    // decides SGD update order, so hash order would make the baseline
    // nondeterministic across runs.
    let mut map = std::collections::BTreeMap::<(u32, u32), (f32, f32)>::new();
    for i in 0..n {
        for s in 0..k {
            let j = ids[i * k + s];
            if j == u32::MAX {
                continue;
            }
            let (lo, hi) = if (i as u32) < j { (i as u32, j) } else { (j, i as u32) };
            let e = map.entry((lo, hi)).or_insert((0.0, 0.0));
            if (i as u32) < j {
                e.0 = directed[i * k + s];
            } else {
                e.1 = directed[i * k + s];
            }
        }
    }
    map.into_iter()
        .map(|((i, j), (wa, wb))| (i, j, wa + wb - wa * wb))
        .filter(|&(_, _, w)| w > 0.0)
        .collect()
}

/// Run the UMAP-like optimiser.
pub fn umap_like(x: &Matrix, cfg: &UmapConfig) -> Matrix {
    let n = x.n();
    let edges = fuzzy_graph(x, cfg.k, cfg.seed, cfg.exact_knn_below);
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let mut y = Matrix::zeros(n, cfg.ld_dim);
    for v in y.data_mut() {
        *v = rng.gauss_ms(0.0, 1.0) as f32 * 10.0;
    }
    let a = cfg.a as f32;
    let b = cfg.b as f32;
    let d = cfg.ld_dim;
    let wmax = edges.iter().map(|e| e.2).fold(0.0f32, f32::max).max(1e-9);
    for epoch in 0..cfg.n_epochs {
        let lr = (cfg.lr * (1.0 - epoch as f64 / cfg.n_epochs as f64)) as f32;
        for &(i, j, w) in &edges {
            // Sample the edge proportionally to its weight.
            if !rng.chance((w / wmax) as f64) {
                continue;
            }
            let (i, j) = (i as usize, j as usize);
            let d2 = y.sqdist(i, j);
            // Attractive grad coefficient (UMAP): -2ab d^{2(b-1)} / (1 + a d^{2b})
            let grad_a = if d2 > 0.0 {
                (-2.0 * a * b * d2.powf(b - 1.0)) / (1.0 + a * d2.powf(b))
            } else {
                0.0
            };
            for c in 0..d {
                let delta = y.row(i)[c] - y.row(j)[c];
                let gc = (grad_a * delta).clamp(-4.0, 4.0) * lr;
                y.row_mut(i)[c] += gc;
                y.row_mut(j)[c] -= gc;
            }
            // Negative samples: repulsive CE term on i.
            for _ in 0..cfg.neg_per_edge {
                let t = rng.below(n);
                if t == i {
                    continue;
                }
                let d2 = y.sqdist(i, t);
                let grad_r = (2.0 * b) / ((0.001 + d2) * (1.0 + a * d2.powf(b)));
                for c in 0..d {
                    let delta = y.row(i)[c] - y.row(t)[c];
                    let gc = (grad_r * delta).clamp(-4.0, 4.0) * lr;
                    y.row_mut(i)[c] += gc;
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::metrics::rnx_auc;

    #[test]
    fn fuzzy_graph_weights_in_unit_interval() {
        let ds = datasets::blobs(120, 6, 3, 0.5, 8.0, 1);
        let edges = fuzzy_graph(&ds.x, 10, 1, 10_000);
        assert!(!edges.is_empty());
        for &(i, j, w) in &edges {
            assert!(i < j, "edges must be canonical (i < j)");
            assert!((0.0..=1.0 + 1e-6).contains(&w), "weight {w}");
        }
        // Each point appears in at least one edge.
        let mut seen = vec![false; 120];
        for &(i, j, _) in &edges {
            seen[i as usize] = true;
            seen[j as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn umap_like_separates_blobs() {
        let ds = datasets::blobs(200, 8, 3, 0.4, 12.0, 2);
        let cfg = UmapConfig { n_epochs: 150, ..UmapConfig::default() };
        let y = umap_like(&ds.x, &cfg);
        assert!(y.data().iter().all(|v| v.is_finite()));
        let auc = rnx_auc(&ds.x, &y, 40);
        assert!(auc > 0.25, "UMAP-like quality too low: AUC {auc}");
    }

    #[test]
    fn supports_higher_ld_dims() {
        let ds = datasets::blobs(120, 6, 2, 0.5, 8.0, 3);
        let cfg = UmapConfig { ld_dim: 8, n_epochs: 50, ..UmapConfig::default() };
        let y = umap_like(&ds.x, &cfg);
        assert_eq!(y.d(), 8);
        assert!(y.data().iter().all(|v| v.is_finite()));
    }
}
