//! Barnes-Hut t-SNE (van der Maaten [17]) — the "model the whole LD
//! space occupancy" baseline family.
//!
//! Substitution note (DESIGN.md §3): the paper benchmarks FIt-SNE; its
//! interpolation grid is a different O(N) realisation of the *same*
//! modelling strategy (precise repulsion at all ranges, target dim
//! restricted to 2–3). Barnes-Hut at θ=0.5 reproduces the behavioural
//! properties Table 1 / Fig. 6 rely on, with O(N log N) iterations and a
//! hard 2-D restriction — which is exactly the restriction the paper's
//! "unconstrained" contribution removes.

use crate::data::Matrix;
use crate::hd::perplexity::{calibrate, conditionals};
use crate::knn::brute::brute_knn;
use crate::knn::nn_descent::nn_descent;
use crate::config::KnnConfig;
use crate::ld::kernel::kernel_pair;
use crate::util::Rng;

/// A quadtree over 2-D points, storing centres of mass.
pub struct QuadTree {
    nodes: Vec<Node>,
}

struct Node {
    // Bounding square.
    cx: f32,
    cy: f32,
    half: f32,
    // Aggregates.
    mass: f32,
    com_x: f32,
    com_y: f32,
    // Children (0 = none); leaf point index + count.
    children: [u32; 4],
    point: u32,
    count: u32,
}

const NO_CHILD: u32 = 0;
const NO_POINT: u32 = u32::MAX;

impl QuadTree {
    /// Build over a (n, 2) embedding.
    pub fn build(y: &Matrix) -> QuadTree {
        assert_eq!(y.d(), 2, "Barnes-Hut is 2-D only (the paper's point)");
        let n = y.n();
        let (mut xmin, mut xmax, mut ymin, mut ymax) =
            (f32::INFINITY, f32::NEG_INFINITY, f32::INFINITY, f32::NEG_INFINITY);
        for i in 0..n {
            xmin = xmin.min(y.row(i)[0]);
            xmax = xmax.max(y.row(i)[0]);
            ymin = ymin.min(y.row(i)[1]);
            ymax = ymax.max(y.row(i)[1]);
        }
        let half = ((xmax - xmin).max(ymax - ymin) / 2.0).max(1e-6) * 1.001;
        let root = Node {
            cx: (xmin + xmax) / 2.0,
            cy: (ymin + ymax) / 2.0,
            half,
            mass: 0.0,
            com_x: 0.0,
            com_y: 0.0,
            children: [NO_CHILD; 4],
            point: NO_POINT,
            count: 0,
        };
        let mut tree = QuadTree { nodes: vec![root] };
        for i in 0..n {
            tree.insert(0, y.row(i)[0], y.row(i)[1], i as u32, 0);
        }
        tree
    }

    fn quadrant(node: &Node, x: f32, y: f32) -> usize {
        (usize::from(x >= node.cx)) | (usize::from(y >= node.cy) << 1)
    }

    fn insert(&mut self, idx: usize, x: f32, y: f32, point: u32, depth: usize) {
        // Update aggregates on the way down.
        {
            let node = &mut self.nodes[idx];
            node.com_x = (node.com_x * node.mass + x) / (node.mass + 1.0);
            node.com_y = (node.com_y * node.mass + y) / (node.mass + 1.0);
            node.mass += 1.0;
            node.count += 1;
        }
        // Depth cap: coincident points pile up in one leaf.
        if depth > 48 {
            return;
        }
        let (is_leaf, existing, cx, cy, half) = {
            let node = &self.nodes[idx];
            (node.children == [NO_CHILD; 4], node.point, node.cx, node.cy, node.half)
        };
        if is_leaf && existing == NO_POINT && self.nodes[idx].count == 1 {
            self.nodes[idx].point = point;
            return;
        }
        if is_leaf && existing != NO_POINT {
            // Split: push the existing point down.
            let (ex, ey) = {
                // We don't store coordinates in the node; re-derive from
                // the aggregates: before this insert the leaf held exactly
                // one point, so its old COM was that point's position.
                let node = &self.nodes[idx];
                let m = node.mass; // includes the new point already
                (
                    node.com_x * m - x, // (com·m − new) = old point coords
                    node.com_y * m - y,
                )
            };
            self.nodes[idx].point = NO_POINT;
            let q = {
                let node = &self.nodes[idx];
                Self::quadrant(node, ex, ey)
            };
            let child = self.child_for(idx, q, cx, cy, half);
            self.insert_leafward(child, ex, ey, existing, depth + 1);
        }
        let q = Self::quadrant(&self.nodes[idx], x, y);
        let child = self.child_for(idx, q, cx, cy, half);
        self.insert(child, x, y, point, depth + 1);
    }

    /// Insert without re-adding mass along this node (already counted).
    fn insert_leafward(&mut self, idx: usize, x: f32, y: f32, point: u32, depth: usize) {
        self.insert(idx, x, y, point, depth);
    }

    fn child_for(&mut self, idx: usize, q: usize, cx: f32, cy: f32, half: f32) -> usize {
        if self.nodes[idx].children[q] != NO_CHILD {
            return self.nodes[idx].children[q] as usize;
        }
        let h = half / 2.0;
        let ncx = cx + if q & 1 != 0 { h } else { -h };
        let ncy = cy + if q & 2 != 0 { h } else { -h };
        let new_idx = self.nodes.len();
        self.nodes.push(Node {
            cx: ncx,
            cy: ncy,
            half: h,
            mass: 0.0,
            com_x: 0.0,
            com_y: 0.0,
            children: [NO_CHILD; 4],
            point: NO_POINT,
            count: 0,
        });
        self.nodes[idx].children[q] = new_idx as u32;
        new_idx
    }

    /// Barnes-Hut repulsion estimate at (x, y): Σ over cells of
    /// mass·w·g·(p − com), plus the Z contribution Σ mass·w.
    /// Returns (fx, fy, z_part).
    pub fn repulsion(&self, x: f32, y: f32, theta: f32, alpha: f32) -> (f32, f32, f32) {
        let mut fx = 0.0f32;
        let mut fy = 0.0f32;
        let mut z = 0.0f32;
        let mut stack = vec![0usize];
        while let Some(idx) = stack.pop() {
            let node = &self.nodes[idx];
            if node.count == 0 {
                continue;
            }
            let dx = x - node.com_x;
            let dy = y - node.com_y;
            let d2 = dx * dx + dy * dy;
            let cell_size = node.half * 2.0;
            let is_far = cell_size * cell_size < theta * theta * d2;
            let is_leaf = node.children == [NO_CHILD; 4];
            if is_far || is_leaf {
                if d2 < 1e-12 && node.count <= 1 {
                    continue; // the query point itself
                }
                let (w, g) = kernel_pair(d2, alpha);
                let m = node.mass;
                // The query point may be inside this aggregate; its own
                // self-term has d2≈0 only in its own leaf, skipped above.
                z += m * w;
                let f = m * w * g;
                fx += f * dx;
                fy += f * dy;
            } else {
                for &c in &node.children {
                    if c != NO_CHILD {
                        stack.push(c as usize);
                    }
                }
            }
        }
        (fx, fy, z)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

/// BH t-SNE configuration.
#[derive(Clone, Debug)]
pub struct BhConfig {
    pub alpha: f64,
    pub perplexity: f64,
    pub k: usize,
    pub theta: f64,
    pub n_iters: usize,
    pub lr: f64,
    pub momentum: f64,
    pub early_exag: f64,
    pub early_exag_iters: usize,
    pub seed: u64,
    /// Use exact KNN below this N, NN-descent above.
    pub exact_knn_below: usize,
}

impl Default for BhConfig {
    fn default() -> Self {
        BhConfig {
            alpha: 1.0,
            perplexity: 30.0,
            k: 90,
            theta: 0.5,
            n_iters: 500,
            lr: 60.0,
            momentum: 0.7,
            early_exag: 4.0,
            early_exag_iters: 100,
            seed: 42,
            exact_knn_below: 2500,
        }
    }
}

/// Sparse symmetrised affinities on a KNN graph: (row offsets aligned to
/// k·i, neighbour ids, p values). Directed edges carry p_{j|i}/(2N) and
/// forces are applied to the owner — consistent with the engine.
fn sparse_p(x: &Matrix, k: usize, perplexity: f64, seed: u64, exact_below: usize) -> (Vec<u32>, Vec<f32>) {
    let n = x.n();
    let k = k.min(n - 1);
    let table = if n <= exact_below {
        brute_knn(x, k)
    } else {
        nn_descent(x, &KnnConfig { k, seed, ..KnnConfig::default() }).table
    };
    let mut ids = vec![0u32; n * k];
    let mut p = vec![0.0f32; n * k];
    let mut sq = vec![0.0f32; k];
    let mut cond = vec![0.0f32; k];
    for i in 0..n {
        let len = table.len(i);
        for (s, (j, d)) in table.entries(i).enumerate() {
            ids[i * k + s] = j;
            sq[s] = d;
        }
        let cal = calibrate(&sq[..len], perplexity, None);
        conditionals(&sq[..len], cal.beta, &mut cond[..len]);
        let scale = 1.0 / (2.0 * n as f32);
        for s in 0..len {
            p[i * k + s] = cond[s] * scale;
        }
        for s in len..k {
            ids[i * k + s] = u32::MAX;
        }
    }
    (ids, p)
}

/// Run Barnes-Hut heavy-tailed t-SNE (2-D only).
pub fn bh_tsne(x: &Matrix, cfg: &BhConfig) -> Matrix {
    let n = x.n();
    let (ids, p) = sparse_p(x, cfg.k, cfg.perplexity, cfg.seed, cfg.exact_knn_below);
    let k = ids.len() / n;
    let mut rng = Rng::new(cfg.seed);
    let mut y = Matrix::zeros(n, 2);
    for v in y.data_mut() {
        *v = rng.gauss_ms(0.0, 1e-2) as f32;
    }
    let mut vel = Matrix::zeros(n, 2);
    let alpha = cfg.alpha as f32;
    let theta = cfg.theta as f32;
    for iter in 0..cfg.n_iters {
        let exag = if iter < cfg.early_exag_iters { cfg.early_exag as f32 } else { 1.0 };
        let tree = QuadTree::build(&y);
        // Pass 1: per-point BH repulsion numerators + Z.
        let mut rep = vec![0.0f32; n * 2];
        let mut z_total = 0.0f64;
        for i in 0..n {
            let (fx, fy, z) = tree.repulsion(y.row(i)[0], y.row(i)[1], theta, alpha);
            rep[i * 2] = fx;
            rep[i * 2 + 1] = fy;
            z_total += z as f64;
        }
        let zinv = (1.0 / z_total.max(1e-300)) as f32;
        // Pass 2: attraction over the sparse graph + update.
        let lr = cfg.lr as f32;
        let mom = cfg.momentum as f32;
        for i in 0..n {
            let (mut ax, mut ay) = (0.0f32, 0.0f32);
            for s in 0..k {
                let j = ids[i * k + s];
                if j == u32::MAX {
                    continue;
                }
                let d2 = y.sqdist(i, j as usize);
                let (_w, g) = kernel_pair(d2, alpha);
                let pij = p[i * k + s] * exag * 2.0; // both edge directions act on owner
                ax += pij * g * (y.row(j as usize)[0] - y.row(i)[0]);
                ay += pij * g * (y.row(j as usize)[1] - y.row(i)[1]);
            }
            let gx = ax * (n as f32) + rep[i * 2] * zinv * n as f32;
            let gy = ay * (n as f32) + rep[i * 2 + 1] * zinv * n as f32;
            let vx = mom * vel.row(i)[0] + lr * gx / n as f32;
            let vy = mom * vel.row(i)[1] + lr * gy / n as f32;
            vel.row_mut(i)[0] = vx;
            vel.row_mut(i)[1] = vy;
            y.row_mut(i)[0] += vx;
            y.row_mut(i)[1] += vy;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::metrics::rnx_auc;
    use crate::util::proptest as pt;

    #[test]
    fn quadtree_mass_equals_point_count() {
        let mut rng = crate::util::Rng::new(1);
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, 200, 2, 3.0), 200, 2).unwrap();
        let tree = QuadTree::build(&y);
        assert_eq!(tree.nodes[0].count, 200);
        assert!((tree.nodes[0].mass - 200.0).abs() < 1e-3);
    }

    #[test]
    fn bh_repulsion_matches_exact_at_theta_zero() {
        // θ=0 forces full traversal to leaves → exact within fp error.
        let mut rng = crate::util::Rng::new(2);
        let n = 120;
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, n, 2, 2.0), n, 2).unwrap();
        let tree = QuadTree::build(&y);
        for &alpha in &[0.5f32, 1.0] {
            for i in (0..n).step_by(17) {
                let (fx, fy, z) = tree.repulsion(y.row(i)[0], y.row(i)[1], 0.0, alpha);
                let (mut ex, mut ey, mut ez) = (0.0f32, 0.0f32, 0.0f32);
                for j in 0..n {
                    if j == i {
                        continue;
                    }
                    let d2 = y.sqdist(i, j);
                    let (w, g) = kernel_pair(d2, alpha);
                    ez += w;
                    ex += w * g * (y.row(i)[0] - y.row(j)[0]);
                    ey += w * g * (y.row(i)[1] - y.row(j)[1]);
                }
                assert!((fx - ex).abs() < 2e-3 * (1.0 + ex.abs()), "fx {fx} vs {ex}");
                assert!((fy - ey).abs() < 2e-3 * (1.0 + ey.abs()), "fy {fy} vs {ey}");
                assert!((z - ez).abs() < 2e-2 * (1.0 + ez.abs()), "z {z} vs {ez}");
            }
        }
    }

    #[test]
    fn bh_repulsion_approximates_at_theta_half() {
        let mut rng = crate::util::Rng::new(3);
        let n = 300;
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, n, 2, 5.0), n, 2).unwrap();
        let tree = QuadTree::build(&y);
        let mut rel_err = 0.0f64;
        let mut count = 0;
        for i in (0..n).step_by(13) {
            let (fx, fy, _) = tree.repulsion(y.row(i)[0], y.row(i)[1], 0.5, 1.0);
            let (ex, ey, _) = tree.repulsion(y.row(i)[0], y.row(i)[1], 0.0, 1.0);
            let num = ((fx - ex).powi(2) + (fy - ey).powi(2)).sqrt() as f64;
            let den = (ex.powi(2) + ey.powi(2)).sqrt().max(1e-6) as f64;
            rel_err += num / den;
            count += 1;
        }
        rel_err /= count as f64;
        assert!(rel_err < 0.15, "BH θ=0.5 relative error too large: {rel_err}");
    }

    #[test]
    fn bh_tsne_separates_blobs() {
        let ds = datasets::blobs(200, 8, 3, 0.4, 12.0, 4);
        let cfg = BhConfig { n_iters: 200, perplexity: 12.0, k: 36, ..BhConfig::default() };
        let y = bh_tsne(&ds.x, &cfg);
        assert!(y.data().iter().all(|v| v.is_finite()));
        let auc = rnx_auc(&ds.x, &y, 40);
        assert!(auc > 0.3, "BH t-SNE quality too low: AUC {auc}");
    }
}
