//! Typed mid-run commands — the single public mutation path into a
//! running embedding.
//!
//! Frontends (CLI, GUI, network handlers) enqueue [`Command`]s from
//! outside the step loop; [`crate::session::Session`] drains the queue
//! FIFO between two iterations, so every mutation lands at a
//! well-defined point of the optimisation with no locking inside the
//! hot loop.

use crate::data::Matrix;
use crate::knn::iterative::CandidateRoutes;

/// A mutation applied between two engine iterations.
#[derive(Clone, Debug)]
pub enum Command {
    /// Change the LD kernel tail heaviness α (1.0 ≡ t-SNE; < 1 heavier).
    SetAlpha(f64),
    /// Change the HD perplexity; σ recalibration happens incrementally
    /// with warm restarts (no stop-the-world phase).
    SetPerplexity(f64),
    /// Change the attraction multiplier.
    SetAttraction(f64),
    /// Change the repulsion multiplier.
    SetRepulsion(f64),
    /// Restrict / restore the KNN candidate-generation routes.
    SetRoutes(CandidateRoutes),
    /// Append a batch of HD points (rows must match the data dim).
    InsertPoints(Matrix),
    /// Remove point `i` (swap-remove: the last point takes index `i`).
    RemovePoint(usize),
    /// Move point `i` to new HD coordinates (drifting data).
    MovePoint(usize, Vec<f32>),
    /// The "implosion button": rescale the embedding so gradients
    /// become significant again.
    Implode,
    /// Stop stepping the engine; commands still drain while paused.
    Pause,
    /// Resume stepping after [`Command::Pause`].
    Resume,
}

impl Command {
    /// Short human-readable description (used in event telemetry).
    pub fn describe(&self) -> String {
        match self {
            Command::SetAlpha(a) => format!("set_alpha({a})"),
            Command::SetPerplexity(p) => format!("set_perplexity({p})"),
            Command::SetAttraction(a) => format!("set_attraction({a})"),
            Command::SetRepulsion(r) => format!("set_repulsion({r})"),
            Command::SetRoutes(r) => format!(
                "set_routes(same={}, cross={}, random={})",
                r.same_space, r.cross_space, r.random
            ),
            Command::InsertPoints(m) => format!("insert_points({}×{})", m.n(), m.d()),
            Command::RemovePoint(i) => format!("remove_point({i})"),
            Command::MovePoint(i, _) => format!("move_point({i})"),
            Command::Implode => "implode".to_string(),
            Command::Pause => "pause".to_string(),
            Command::Resume => "resume".to_string(),
        }
    }
}
