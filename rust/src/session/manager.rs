//! Multi-session ownership: many independent embeddings stepped
//! round-robin — the first concrete move toward serving concurrent
//! embedding sessions from one process.

use super::{Command, Session, SessionBuilder};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Stable handle for a session owned by a [`SessionManager`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(pub u64);

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session-{}", self.0)
    }
}

/// What one [`SessionManager::step_all_detailed`] sweep did: how many
/// sessions advanced, and which ones failed (with the step error).
/// Failed sessions are force-paused in place with their command queues
/// intact — a server surfaces `failed` per session (e.g. in a stats
/// endpoint) and clients resume with [`crate::session::Command::Resume`]
/// once the cause is fixed.
#[derive(Clone, Debug, Default)]
pub struct StepOutcome {
    /// Sessions that ran one iteration this sweep.
    pub stepped: usize,
    /// Sessions whose step errored, with the error message. Each was
    /// paused via [`Session::force_pause`] (queued commands survive).
    pub failed: Vec<(SessionId, String)>,
}

/// Owns multiple independent [`Session`]s keyed by [`SessionId`] and
/// steps them fairly ([`SessionManager::step_all`] runs one iteration
/// per session per call, in id order).
#[derive(Default)]
pub struct SessionManager {
    next_id: u64,
    sessions: BTreeMap<u64, Session>,
}

impl SessionManager {
    pub fn new() -> SessionManager {
        SessionManager::default()
    }

    /// Take ownership of a session; returns its id.
    pub fn add(&mut self, session: Session) -> SessionId {
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.insert(id, session);
        SessionId(id)
    }

    /// Build and register in one go.
    pub fn create(&mut self, builder: SessionBuilder) -> Result<SessionId> {
        Ok(self.add(builder.build()?))
    }

    /// Re-register a restored session under its original id (boot-time
    /// crash recovery: clients hold URLs naming the old ids). Fails if
    /// the id is already occupied; fresh ids allocated afterwards never
    /// collide with any restored id.
    pub fn add_with_id(&mut self, id: SessionId, session: Session) -> Result<()> {
        if self.sessions.contains_key(&id.0) {
            bail!("session id {id} already occupied");
        }
        self.sessions.insert(id.0, session);
        self.next_id = self.next_id.max(id.0 + 1);
        Ok(())
    }

    pub fn get(&self, id: SessionId) -> Option<&Session> {
        self.sessions.get(&id.0)
    }

    pub fn get_mut(&mut self, id: SessionId) -> Option<&mut Session> {
        self.sessions.get_mut(&id.0)
    }

    /// Remove and return a session (e.g. when a client disconnects).
    pub fn remove(&mut self, id: SessionId) -> Option<Session> {
        self.sessions.remove(&id.0)
    }

    /// Ids of all live sessions, in step order.
    pub fn ids(&self) -> Vec<SessionId> {
        self.sessions.keys().copied().map(SessionId).collect()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Queue a command on one session.
    pub fn enqueue(&mut self, id: SessionId, command: Command) -> Result<()> {
        match self.sessions.get_mut(&id.0) {
            Some(s) => {
                s.enqueue(command);
                Ok(())
            }
            None => bail!("unknown session {id}"),
        }
    }

    /// One round-robin sweep: each session drains its queue and runs
    /// one iteration (paused sessions only drain).
    ///
    /// Fault isolation: a session whose step errors is paused *in
    /// place* via [`Session::force_pause`] — it stops erroring every
    /// sweep, its command queue is untouched (anything clients queued
    /// keeps draining on later sweeps, so a `Resume` after fixing the
    /// cause behaves normally), and the sweep continues: one broken
    /// session never starves the others. The failed ids come back
    /// structurally in [`StepOutcome::failed`] so a server can surface
    /// the error per session instead of losing it in a formatted blob.
    pub fn step_all_detailed(&mut self) -> StepOutcome {
        let mut out = StepOutcome::default();
        for (id, session) in self.sessions.iter_mut() {
            match session.step() {
                Ok(true) => out.stepped += 1,
                Ok(false) => {}
                Err(e) => {
                    session.force_pause();
                    out.failed.push((SessionId(*id), e.to_string()));
                }
            }
        }
        out
    }

    /// [`SessionManager::step_all_detailed`] with failures folded into
    /// one error naming every failed session (convenient for callers
    /// that treat any failure as fatal; servers want the detailed form).
    /// Returns how many sessions actually stepped.
    pub fn step_all(&mut self) -> Result<usize> {
        let out = self.step_all_detailed();
        if out.failed.is_empty() {
            return Ok(out.stepped);
        }
        let list: Vec<String> = out.failed.iter().map(|(id, e)| format!("{id}: {e}")).collect();
        bail!(
            "{} session(s) failed and were paused — {}",
            out.failed.len(),
            list.join("; ")
        )
    }

    /// `rounds` interleaved sweeps of [`SessionManager::step_all`] —
    /// sessions advance together, not one after the other.
    pub fn run_all(&mut self, rounds: usize) -> Result<()> {
        for _ in 0..rounds {
            self.step_all()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EmbedConfig;
    use crate::data::datasets;
    use crate::data::Matrix;
    use crate::engine::{ComputeBackend, FuncSne, NegSamples, NegStats};
    use crate::hd::Affinities;
    use crate::knn::iterative::IterativeKnn;
    use crate::session::Session;

    fn builder(seed: u64) -> SessionBuilder {
        let ds = datasets::blobs(90, 5, 3, 0.5, 8.0, seed);
        Session::builder()
            .dataset(ds.x)
            .k_hd(10)
            .k_ld(6)
            .perplexity(6.0)
            .jumpstart_iters(3)
            .seed(seed)
    }

    #[test]
    fn ids_are_stable_and_removal_works() {
        let mut mgr = SessionManager::new();
        let a = mgr.create(builder(1)).unwrap();
        let b = mgr.create(builder(2)).unwrap();
        assert_ne!(a, b);
        assert_eq!(mgr.len(), 2);
        assert!(mgr.remove(a).is_some());
        assert!(mgr.get(a).is_none());
        assert!(mgr.get(b).is_some());
        let c = mgr.create(builder(3)).unwrap();
        assert_ne!(c, b, "ids must not be recycled");
        assert_eq!(mgr.ids(), vec![b, c]);
    }

    #[test]
    fn step_all_advances_every_session_once() {
        let mut mgr = SessionManager::new();
        let a = mgr.create(builder(4)).unwrap();
        let b = mgr.create(builder(5)).unwrap();
        let stepped = mgr.step_all().unwrap();
        assert_eq!(stepped, 2);
        assert_eq!(mgr.get(a).unwrap().iterations(), 1);
        assert_eq!(mgr.get(b).unwrap().iterations(), 1);
        // Pause one: it stops counting as stepped.
        mgr.enqueue(a, Command::Pause).unwrap();
        let stepped = mgr.step_all().unwrap();
        assert_eq!(stepped, 1);
        assert_eq!(mgr.get(a).unwrap().iterations(), 1);
        assert_eq!(mgr.get(b).unwrap().iterations(), 2);
    }

    #[test]
    fn enqueue_unknown_session_errors() {
        let mut mgr = SessionManager::new();
        assert!(mgr.enqueue(SessionId(99), Command::Implode).is_err());
    }

    /// A backend whose every numeric call errors — stands in for a
    /// dying PJRT client / poisoned artifact to exercise fault
    /// isolation deterministically.
    struct FailingBackend;

    impl ComputeBackend for FailingBackend {
        fn sqdist_batch(
            &mut self,
            _x: &Matrix,
            _owners: &[u32],
            _cands: &[u32],
            _out: &mut Vec<f32>,
        ) -> anyhow::Result<()> {
            anyhow::bail!("injected backend failure (sqdist)")
        }

        #[allow(clippy::too_many_arguments)]
        fn forces(
            &mut self,
            _y: &Matrix,
            _knn: &IterativeKnn,
            _aff: &Affinities,
            _neg: &NegSamples,
            _alpha: f32,
            _far_scale: f32,
            _attr: &mut Matrix,
            _rep: &mut Matrix,
        ) -> anyhow::Result<NegStats> {
            anyhow::bail!("injected backend failure (forces)")
        }

        fn name(&self) -> &'static str {
            "failing"
        }
    }

    /// A session whose first step is guaranteed to error (no jumpstart
    /// phase, so the failing backend is hit immediately).
    fn failing_session(seed: u64) -> Session {
        let ds = datasets::blobs(60, 5, 3, 0.5, 8.0, seed);
        let cfg = EmbedConfig {
            k_hd: 10,
            k_ld: 6,
            perplexity: 6.0,
            jumpstart_iters: 0,
            seed,
            ..EmbedConfig::default()
        };
        let engine = FuncSne::new(ds.x, cfg).unwrap();
        Session::from_parts(engine, Box::new(FailingBackend), None, 0, 8)
    }

    #[test]
    fn failed_session_is_paused_and_siblings_keep_stepping() {
        let mut mgr = SessionManager::new();
        let a = mgr.create(builder(6)).unwrap();
        let b = mgr.add(failing_session(7));
        let c = mgr.create(builder(8)).unwrap();
        // A command queued on a healthy sibling before the sweep in
        // which `b` dies must be applied, not lost.
        mgr.enqueue(c, Command::SetAlpha(0.5)).unwrap();
        let out = mgr.step_all_detailed();
        assert_eq!(out.stepped, 2, "healthy sessions still step");
        assert_eq!(out.failed.len(), 1);
        assert_eq!(out.failed[0].0, b);
        assert!(out.failed[0].1.contains("injected backend failure"), "{}", out.failed[0].1);
        assert!(mgr.get(b).unwrap().is_paused(), "failed session auto-pauses");
        assert_eq!(mgr.get(c).unwrap().config().alpha, 0.5);
        assert_eq!(mgr.get(a).unwrap().iterations(), 1);
        // The next sweep is clean: the paused session no longer errors.
        let out = mgr.step_all_detailed();
        assert_eq!(out.stepped, 2);
        assert!(out.failed.is_empty());
    }

    #[test]
    fn queued_commands_survive_failure_and_drain_while_paused() {
        let mut mgr = SessionManager::new();
        let b = mgr.add(failing_session(9));
        let out = mgr.step_all_detailed();
        assert_eq!(out.failed.len(), 1);
        // Commands queued on the *failed* session are not discarded by
        // the auto-pause: they stay queued and drain on the next sweep
        // (paused sessions drain without stepping).
        mgr.enqueue(b, Command::SetAttraction(2.0)).unwrap();
        assert_eq!(mgr.get(b).unwrap().queued(), 1);
        let out = mgr.step_all_detailed();
        assert!(out.failed.is_empty());
        let s = mgr.get(b).unwrap();
        assert!(s.is_paused());
        assert_eq!(s.queued(), 0, "command drained while paused");
        assert_eq!(s.config().attraction, 2.0, "command applied, not dropped");
        let (applied, rejected) = s.command_counts();
        assert_eq!((applied, rejected), (1, 0));
    }

    #[test]
    fn step_all_folds_failures_into_one_error() {
        let mut mgr = SessionManager::new();
        let good = mgr.create(builder(10)).unwrap();
        let bad = mgr.add(failing_session(11));
        let err = mgr.step_all().unwrap_err().to_string();
        assert!(err.contains(&bad.to_string()), "{err}");
        assert!(err.contains("injected backend failure"), "{err}");
        // The healthy session advanced despite the reported failure.
        assert_eq!(mgr.get(good).unwrap().iterations(), 1);
        assert!(mgr.step_all().is_ok(), "paused failure stops erroring");
    }
}
