//! Multi-session ownership: many independent embeddings stepped
//! round-robin — the first concrete move toward serving concurrent
//! embedding sessions from one process.

use super::{Command, Session, SessionBuilder};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Stable handle for a session owned by a [`SessionManager`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SessionId(pub u64);

impl std::fmt::Display for SessionId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "session-{}", self.0)
    }
}

/// Owns multiple independent [`Session`]s keyed by [`SessionId`] and
/// steps them fairly ([`SessionManager::step_all`] runs one iteration
/// per session per call, in id order).
#[derive(Default)]
pub struct SessionManager {
    next_id: u64,
    sessions: BTreeMap<u64, Session>,
}

impl SessionManager {
    pub fn new() -> SessionManager {
        SessionManager::default()
    }

    /// Take ownership of a session; returns its id.
    pub fn add(&mut self, session: Session) -> SessionId {
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.insert(id, session);
        SessionId(id)
    }

    /// Build and register in one go.
    pub fn create(&mut self, builder: SessionBuilder) -> Result<SessionId> {
        Ok(self.add(builder.build()?))
    }

    pub fn get(&self, id: SessionId) -> Option<&Session> {
        self.sessions.get(&id.0)
    }

    pub fn get_mut(&mut self, id: SessionId) -> Option<&mut Session> {
        self.sessions.get_mut(&id.0)
    }

    /// Remove and return a session (e.g. when a client disconnects).
    pub fn remove(&mut self, id: SessionId) -> Option<Session> {
        self.sessions.remove(&id.0)
    }

    /// Ids of all live sessions, in step order.
    pub fn ids(&self) -> Vec<SessionId> {
        self.sessions.keys().copied().map(SessionId).collect()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Queue a command on one session.
    pub fn enqueue(&mut self, id: SessionId, command: Command) -> Result<()> {
        match self.sessions.get_mut(&id.0) {
            Some(s) => {
                s.enqueue(command);
                Ok(())
            }
            None => bail!("unknown session {id}"),
        }
    }

    /// One round-robin sweep: each session drains its queue and runs
    /// one iteration (paused sessions only drain). Returns how many
    /// sessions actually stepped.
    ///
    /// Fault isolation: a session whose step errors is auto-paused (so
    /// it stops erroring every sweep; resume it with
    /// [`Command::Resume`] after fixing the cause) and the sweep
    /// continues — one broken session never starves the others. The
    /// error returned afterwards names every failed session.
    pub fn step_all(&mut self) -> Result<usize> {
        let mut stepped = 0usize;
        let mut failures: Vec<String> = Vec::new();
        for (id, session) in self.sessions.iter_mut() {
            match session.step() {
                Ok(true) => stepped += 1,
                Ok(false) => {}
                Err(e) => {
                    session.enqueue(Command::Pause);
                    session.drain_commands();
                    failures.push(format!("{}: {e}", SessionId(*id)));
                }
            }
        }
        if !failures.is_empty() {
            bail!(
                "{} session(s) failed and were paused — {}",
                failures.len(),
                failures.join("; ")
            );
        }
        Ok(stepped)
    }

    /// `rounds` interleaved sweeps of [`SessionManager::step_all`] —
    /// sessions advance together, not one after the other.
    pub fn run_all(&mut self, rounds: usize) -> Result<()> {
        for _ in 0..rounds {
            self.step_all()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::session::Session;

    fn builder(seed: u64) -> SessionBuilder {
        let ds = datasets::blobs(90, 5, 3, 0.5, 8.0, seed);
        Session::builder()
            .dataset(ds.x)
            .k_hd(10)
            .k_ld(6)
            .perplexity(6.0)
            .jumpstart_iters(3)
            .seed(seed)
    }

    #[test]
    fn ids_are_stable_and_removal_works() {
        let mut mgr = SessionManager::new();
        let a = mgr.create(builder(1)).unwrap();
        let b = mgr.create(builder(2)).unwrap();
        assert_ne!(a, b);
        assert_eq!(mgr.len(), 2);
        assert!(mgr.remove(a).is_some());
        assert!(mgr.get(a).is_none());
        assert!(mgr.get(b).is_some());
        let c = mgr.create(builder(3)).unwrap();
        assert_ne!(c, b, "ids must not be recycled");
        assert_eq!(mgr.ids(), vec![b, c]);
    }

    #[test]
    fn step_all_advances_every_session_once() {
        let mut mgr = SessionManager::new();
        let a = mgr.create(builder(4)).unwrap();
        let b = mgr.create(builder(5)).unwrap();
        let stepped = mgr.step_all().unwrap();
        assert_eq!(stepped, 2);
        assert_eq!(mgr.get(a).unwrap().iterations(), 1);
        assert_eq!(mgr.get(b).unwrap().iterations(), 1);
        // Pause one: it stops counting as stepped.
        mgr.enqueue(a, Command::Pause).unwrap();
        let stepped = mgr.step_all().unwrap();
        assert_eq!(stepped, 1);
        assert_eq!(mgr.get(a).unwrap().iterations(), 1);
        assert_eq!(mgr.get(b).unwrap().iterations(), 2);
    }

    #[test]
    fn enqueue_unknown_session_errors() {
        let mut mgr = SessionManager::new();
        assert!(mgr.enqueue(SessionId(99), Command::Implode).is_err());
    }
}
