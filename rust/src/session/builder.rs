//! Fluent session construction.
//!
//! The builder owns the whole setup dance that callers previously wired
//! by hand: config assembly + validation, optional PCA pre-reduction of
//! wide data (the paper's recommended preprocessing), backend selection
//! (native vs PJRT artifacts), and engine construction.
//!
//! ```no_run
//! use funcsne::session::Session;
//! # let x = funcsne::data::Matrix::zeros(100, 8);
//! let mut session = Session::builder()
//!     .dataset(x)
//!     .ld_dim(2)
//!     .perplexity(30.0)
//!     .backend_name("native")
//!     .build()
//!     .unwrap();
//! session.run(500).unwrap();
//! ```

use super::Session;
use crate::config::{Backend, EmbedConfig, Init};
use crate::coordinator::driver::{default_artifact_dir, make_backend};
use crate::data::Matrix;
use crate::engine::FuncSne;
use crate::linalg::Pca;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Builds a [`Session`]; obtain one via [`Session::builder`].
pub struct SessionBuilder {
    x: Option<Matrix>,
    cfg: EmbedConfig,
    backend_name: Option<String>,
    pca_max_dim: Option<usize>,
    artifact_dir: Option<PathBuf>,
    snapshot_stride: usize,
    snapshot_capacity: usize,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionBuilder {
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            x: None,
            cfg: EmbedConfig::default(),
            backend_name: None,
            pca_max_dim: None,
            artifact_dir: None,
            snapshot_stride: 0,
            snapshot_capacity: 8,
        }
    }

    /// The HD data to embed (required).
    pub fn dataset(mut self, x: Matrix) -> Self {
        self.x = Some(x);
        self
    }

    /// Replace the whole configuration (field setters still apply on
    /// top when called afterwards).
    pub fn config(mut self, cfg: EmbedConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Target dimensionality (unconstrained — the paper's headline).
    pub fn ld_dim(mut self, d: usize) -> Self {
        self.cfg.ld_dim = d;
        self
    }

    /// LD kernel tail heaviness α.
    pub fn alpha(mut self, a: f64) -> Self {
        self.cfg.alpha = a;
        self
    }

    /// HD Gaussian perplexity.
    pub fn perplexity(mut self, p: f64) -> Self {
        self.cfg.perplexity = p;
        self
    }

    pub fn k_hd(mut self, k: usize) -> Self {
        self.cfg.k_hd = k;
        self
    }

    pub fn k_ld(mut self, k: usize) -> Self {
        self.cfg.k_ld = k;
        self
    }

    pub fn n_neg(mut self, m: usize) -> Self {
        self.cfg.n_neg = m;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.cfg.lr = lr;
        self
    }

    pub fn attraction(mut self, a: f64) -> Self {
        self.cfg.attraction = a;
        self
    }

    pub fn repulsion(mut self, r: f64) -> Self {
        self.cfg.repulsion = r;
        self
    }

    /// Default iteration budget used by [`Session::run_configured`].
    pub fn n_iters(mut self, iters: usize) -> Self {
        self.cfg.n_iters = iters;
        self
    }

    pub fn jumpstart_iters(mut self, iters: usize) -> Self {
        self.cfg.jumpstart_iters = iters;
        self
    }

    pub fn early_exag_iters(mut self, iters: usize) -> Self {
        self.cfg.early_exag_iters = iters;
        self
    }

    pub fn init(mut self, init: Init) -> Self {
        self.cfg.init = init;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Force backend (typed).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.cfg.backend = backend;
        self.backend_name = None;
        self
    }

    /// Force backend by name (`"native"` / `"simd"` / `"pjrt"`);
    /// unknown names fail at [`SessionBuilder::build`].
    pub fn backend_name(mut self, name: &str) -> Self {
        self.backend_name = Some(name.to_string());
        self
    }

    /// Linearly pre-reduce data wider than `max_dim` with PCA (the
    /// paper's §3 preprocessing). Off by default. The fitted basis is
    /// retained by the [`Session`], which keeps accepting
    /// *original-dimension* rows for `InsertPoints` / `MovePoint` and
    /// projects them through the same basis.
    pub fn pca_max_dim(mut self, max_dim: usize) -> Self {
        self.pca_max_dim = Some(max_dim);
        self
    }

    /// Worker threads for the native compute path (`> 1` shards the
    /// backend passes *and* the engine's refinement / negative-sampling
    /// passes — bitwise-identical results at any width; `0` =
    /// auto-detect).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Where PJRT AOT artifacts live (defaults to the crate's
    /// `artifacts/` directory).
    pub fn artifact_dir(mut self, dir: &Path) -> Self {
        self.artifact_dir = Some(dir.to_path_buf());
        self
    }

    /// Measure online quality every `every` iterations (0 = off, the
    /// default): sampled KNN recall / trustworthiness / continuity and
    /// iterative-KNN recall stream out as [`crate::session::Event::Quality`].
    pub fn probe_every(mut self, every: usize) -> Self {
        self.cfg.probe_every = every;
        self
    }

    /// Anchor-subset size for the quality probe (default 256).
    pub fn probe_anchors(mut self, anchors: usize) -> Self {
        self.cfg.probe_anchors = anchors;
        self
    }

    /// Record an embedding snapshot every `stride` iterations (0 = off).
    pub fn snapshot_stride(mut self, stride: usize) -> Self {
        self.snapshot_stride = stride;
        self
    }

    /// Ring-buffer capacity for snapshots (default 8, min 1).
    pub fn snapshot_capacity(mut self, capacity: usize) -> Self {
        self.snapshot_capacity = capacity;
        self
    }

    /// Validate, pre-reduce, select the backend, build the engine.
    pub fn build(self) -> Result<Session> {
        let mut cfg = self.cfg;
        let mut x = self
            .x
            .context("SessionBuilder: no dataset provided (call .dataset(matrix))")?;
        if let Some(name) = &self.backend_name {
            cfg.backend = name.parse().context("SessionBuilder: bad backend name")?;
        }
        cfg.validate().context("SessionBuilder: invalid configuration")?;
        // PCA pre-reduction keeps the fitted basis: the session must be
        // able to project incoming dynamic rows (insert/move arrive in
        // the ORIGINAL space) through the same projection, otherwise
        // dynamic data silently lands in the wrong basis.
        let mut pca = None;
        if let Some(max_dim) = self.pca_max_dim {
            if x.d() > max_dim {
                let fitted = Pca::fit(&x, max_dim, cfg.seed);
                x = fitted.transform(&x);
                pca = Some(fitted);
            }
        }
        let artifact_dir = self.artifact_dir.unwrap_or_else(default_artifact_dir);
        let backend = make_backend(&cfg, x.d(), &artifact_dir)
            .context("SessionBuilder: backend construction failed")?;
        let engine = FuncSne::new(x, cfg)?;
        Ok(Session::from_parts(
            engine,
            backend,
            pca,
            self.snapshot_stride,
            self.snapshot_capacity,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn builder_validates_ld_dim() {
        let ds = datasets::blobs(100, 6, 2, 0.5, 8.0, 1);
        let err = Session::builder().dataset(ds.x).ld_dim(0).build().unwrap_err();
        assert!(format!("{err:?}").contains("ld_dim"), "{err:?}");
    }

    #[test]
    fn builder_validates_perplexity() {
        let ds = datasets::blobs(100, 6, 2, 0.5, 8.0, 1);
        let err = Session::builder().dataset(ds.x).perplexity(1.0).build().unwrap_err();
        assert!(format!("{err:?}").contains("perplexity"), "{err:?}");
    }

    #[test]
    fn builder_rejects_unknown_backend() {
        let ds = datasets::blobs(100, 6, 2, 0.5, 8.0, 1);
        let err = Session::builder()
            .dataset(ds.x)
            .backend_name("cuda")
            .build()
            .unwrap_err();
        assert!(format!("{err:?}").contains("backend"), "{err:?}");
    }

    #[test]
    fn builder_selects_simd_backend() {
        let ds = datasets::blobs(100, 6, 2, 0.5, 8.0, 1);
        let s = Session::builder()
            .dataset(ds.x)
            .backend_name("simd")
            .k_hd(12)
            .perplexity(8.0)
            .build()
            .unwrap();
        assert_eq!(s.backend_name(), "simd");
    }

    #[test]
    fn builder_requires_dataset() {
        assert!(Session::builder().build().is_err());
    }

    #[test]
    fn pca_pre_reduction_applies_when_asked() {
        let ds = datasets::mnist_like(150, 64, 2);
        let s = Session::builder()
            .dataset(ds.x)
            .pca_max_dim(16)
            .k_hd(12)
            .perplexity(8.0)
            .build()
            .unwrap();
        assert_eq!(s.engine().x.d(), 16);
    }
}
