//! The session facade — the public API for running and *steering* an
//! embedding.
//!
//! The paper's headline contribution is interactivity: any
//! hyperparameter, including HD-side ones, can change between two
//! iterations with instantaneous feedback. This module packages that
//! capability behind one object:
//!
//! * [`SessionBuilder`] — fluent construction that owns backend
//!   selection, optional PCA pre-reduction and config validation:
//!   `Session::builder().dataset(x).ld_dim(2).perplexity(30.0).build()?`
//! * [`Command`] — typed mid-run mutations, applied through a FIFO
//!   queue drained **between** iterations ([`Session::enqueue`]), so
//!   GUI/network frontends never reach into the step loop;
//! * [`Event`] / [`EventSink`] / [`SnapshotBuffer`] — the outbound
//!   stream: per-iteration telemetry from [`EngineStats`], command
//!   outcomes, and ring-buffered embedding snapshots at a configurable
//!   stride;
//! * [`SessionManager`] — owns many independent sessions keyed by
//!   [`SessionId`] and steps them round-robin ([`SessionManager::step_all`]),
//!   the building block for serving concurrent embedding sessions.
//!
//! Threading model: [`Session`] is intentionally **not** `Send` —
//! sinks and backends are plain trait objects (GUI callbacks hold
//! `Rc`s; the PJRT client pins to a thread). A server shards sessions
//! across one [`SessionManager`] per worker thread rather than
//! migrating sessions between threads; cross-thread command routing
//! belongs in a layer above this module. *Within* a session,
//! [`SessionBuilder::threads`] widens both the sharded
//! [`crate::ld::ParallelBackend`] (forces / candidate scoring / the
//! gradient update) and the engine's own pool (KNN refinement and
//! negative sampling, randomised by counter-based
//! [`crate::util::StreamRng`] streams). All of it forks and joins
//! inside one `step` and produces bitwise-identical results at any
//! thread count — the session itself never observes the concurrency.

pub mod builder;
pub mod command;
pub mod event;
pub mod manager;

pub use builder::SessionBuilder;
pub use command::Command;
pub use event::{Event, EventSink, Snapshot, SnapshotBuffer};
pub use manager::{SessionId, SessionManager, StepOutcome};

use crate::config::EmbedConfig;
use crate::data::Matrix;
use crate::engine::{ComputeBackend, EngineStats, FuncSne};
use crate::linalg::Pca;
use crate::metrics::probe::QualityReport;
use crate::persist::snapshot::SessionState;
use crate::persist::wal::WalWriter;
use anyhow::Result;
use std::collections::VecDeque;

/// A running embedding: engine + backend + command queue + event stream.
pub struct Session {
    engine: FuncSne,
    backend: Box<dyn ComputeBackend>,
    /// The PCA basis fitted by the builder's pre-reduction, if any.
    /// Dynamic rows (`InsertPoints` / `MovePoint`) arrive in the
    /// ORIGINAL space and are projected through this basis; without it
    /// they would be rejected with a misleading dimension error — or
    /// worse, silently accepted in the wrong basis when the dims happen
    /// to coincide.
    pca: Option<Pca>,
    queue: VecDeque<Command>,
    sinks: Vec<Box<dyn EventSink>>,
    snapshots: SnapshotBuffer,
    /// Record a snapshot every `snapshot_stride` iterations (0 = off).
    snapshot_stride: usize,
    paused: bool,
    commands_applied: u64,
    commands_rejected: u64,
    /// Durable command log, attached by the server/CLI when a state
    /// dir is configured. Every drained command is appended (and
    /// fsynced) *before* it is applied — see `docs/persistence.md`.
    wal: Option<WalWriter>,
    /// Set when a WAL append fails. While set, every command is
    /// rejected: applying a command the log will never replay would
    /// fork the durable trajectory from the live one. A successful
    /// checkpoint reattaches a fresh log and clears this.
    wal_error: Option<String>,
    /// Sequence number of the last durably logged command (0 = none
    /// since creation; restores seed it from the snapshot).
    last_wal_seq: u64,
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Session")
            .field("n", &self.engine.n())
            .field("iter", &self.engine.iter)
            .field("backend", &self.backend.name())
            .field("queued", &self.queue.len())
            .field("paused", &self.paused)
            .finish_non_exhaustive()
    }
}

impl Session {
    /// Start building a session: `Session::builder().dataset(x)...`.
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub(crate) fn from_parts(
        engine: FuncSne,
        backend: Box<dyn ComputeBackend>,
        pca: Option<Pca>,
        snapshot_stride: usize,
        snapshot_capacity: usize,
    ) -> Session {
        Session {
            engine,
            backend,
            pca,
            queue: VecDeque::new(),
            sinks: Vec::new(),
            snapshots: SnapshotBuffer::new(snapshot_capacity),
            snapshot_stride,
            paused: false,
            commands_applied: 0,
            commands_rejected: 0,
            wal: None,
            wal_error: None,
            last_wal_seq: 0,
        }
    }

    // --- steering ------------------------------------------------------

    /// Queue a command; it is applied (FIFO) before the next iteration.
    pub fn enqueue(&mut self, command: Command) {
        self.queue.push_back(command);
    }

    /// Commands waiting to be applied.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Whether the session is paused (commands still drain).
    pub fn is_paused(&self) -> bool {
        self.paused
    }

    /// Pause immediately, **without** draining the command queue.
    ///
    /// This is the fault-isolation path: when a step fails, the owner
    /// ([`SessionManager`], a server) must stop the session from
    /// erroring every sweep but must not flush commands clients have
    /// already queued — they stay queued and drain normally on the next
    /// sweep (paused sessions still drain). Emits [`Event::Paused`] on
    /// the transition; a no-op if already paused.
    pub fn force_pause(&mut self) {
        if !self.paused {
            self.paused = true;
            let iter = self.engine.iter;
            self.emit(Event::Paused { iter });
        }
    }

    /// Subscribe a sink to the event stream. Closures work directly:
    /// `session.add_sink(Box::new(|e: &Event| println!("{e:?}")))`.
    pub fn add_sink(&mut self, sink: Box<dyn EventSink>) {
        self.sinks.push(sink);
    }

    // --- stepping ------------------------------------------------------

    /// Drain the command queue, then run one engine iteration (unless
    /// paused). Returns `true` if the engine actually stepped.
    pub fn step(&mut self) -> Result<bool> {
        self.drain_commands();
        if self.paused {
            return Ok(false);
        }
        self.engine.step(self.backend.as_mut())?;
        let iter = self.engine.iter;
        let stats = self.engine.stats.clone();
        self.emit(Event::Iteration { iter, stats });
        // A probe report stamped with this iteration is fresh — stream
        // it; older reports were already streamed when they happened.
        if let Some(q) = self.engine.stats.quality {
            if q.iter == iter {
                self.emit(Event::Quality {
                    iter,
                    recall: q.knn_recall,
                    trust: q.trustworthiness,
                    cont: q.continuity,
                    knn_recall_hd: q.knn_recall_hd,
                });
            }
        }
        if self.snapshot_stride > 0 && iter % self.snapshot_stride == 0 {
            self.snapshots.push(iter, &self.engine.y);
            self.emit(Event::Snapshot { iter });
        }
        Ok(true)
    }

    /// Run `iters` steps (paused steps drain commands but don't iterate).
    pub fn run(&mut self, iters: usize) -> Result<()> {
        for _ in 0..iters {
            self.step()?;
        }
        Ok(())
    }

    /// Run the `n_iters` configured at build time.
    pub fn run_configured(&mut self) -> Result<()> {
        let iters = self.engine.cfg.n_iters;
        self.run(iters)
    }

    /// Apply every queued command now, FIFO. Invalid commands are
    /// dropped with a [`Event::CommandRejected`]; returns the number
    /// applied.
    pub fn drain_commands(&mut self) -> usize {
        let mut applied = 0usize;
        while let Some(cmd) = self.queue.pop_front() {
            let description = cmd.describe();
            let iter = self.engine.iter;
            // Write-ahead: the command must be durable before it can
            // take effect. A command we cannot log is refused outright
            // — the restore path replays only what the log holds, and
            // live state must never get ahead of it.
            if let Err(reason) = self.wal_append(iter, &cmd) {
                self.commands_rejected += 1;
                self.emit(Event::CommandRejected { iter, description, reason });
                continue;
            }
            match self.apply(cmd) {
                Ok(Some(event)) => {
                    applied += 1;
                    self.commands_applied += 1;
                    self.emit(event);
                }
                Ok(None) => {
                    applied += 1;
                    self.commands_applied += 1;
                    self.emit(Event::CommandApplied { iter, description });
                }
                Err(reason) => {
                    self.commands_rejected += 1;
                    self.emit(Event::CommandRejected { iter, description, reason });
                }
            }
        }
        applied
    }

    /// Apply one command. `Ok(Some(event))` overrides the default
    /// [`Event::CommandApplied`] emission.
    fn apply(&mut self, cmd: Command) -> std::result::Result<Option<Event>, String> {
        let iter = self.engine.iter;
        match cmd {
            Command::SetAlpha(a) => {
                if !a.is_finite() || a <= 0.0 {
                    return Err(format!("alpha must be finite and > 0 (got {a})"));
                }
                self.engine.set_alpha(a);
            }
            Command::SetPerplexity(p) => {
                if !p.is_finite() || p < 2.0 {
                    return Err(format!("perplexity must be >= 2 (got {p})"));
                }
                self.engine.set_perplexity(p);
            }
            Command::SetAttraction(a) => {
                if !a.is_finite() || a < 0.0 {
                    return Err(format!("attraction must be >= 0 (got {a})"));
                }
                self.engine.set_attraction(a);
            }
            Command::SetRepulsion(r) => {
                if !r.is_finite() || r < 0.0 {
                    return Err(format!("repulsion must be >= 0 (got {r})"));
                }
                self.engine.set_repulsion(r);
            }
            Command::SetRoutes(routes) => {
                if !routes.same_space && !routes.cross_space && !routes.random {
                    return Err("at least one candidate route must stay enabled".to_string());
                }
                self.engine.set_candidate_routes(routes);
            }
            Command::InsertPoints(m) => {
                let m = self.project_incoming(m)?;
                if m.d() != self.engine.x.d() {
                    return Err(format!(
                        "insert dim {} != data dim {}",
                        m.d(),
                        self.engine.x.d()
                    ));
                }
                for r in 0..m.n() {
                    self.engine.insert_point(m.row(r));
                }
            }
            Command::RemovePoint(i) => {
                let n = self.engine.n();
                if i >= n {
                    return Err(format!("remove index {i} out of range (n = {n})"));
                }
                if n <= 4 {
                    return Err(format!("cannot remove below 4 points (n = {n})"));
                }
                self.engine.remove_point(i);
            }
            Command::MovePoint(i, row) => {
                if i >= self.engine.n() {
                    return Err(format!(
                        "move index {i} out of range (n = {})",
                        self.engine.n()
                    ));
                }
                let row = self.project_incoming_row(row)?;
                if row.len() != self.engine.x.d() {
                    return Err(format!(
                        "move row dim {} != data dim {}",
                        row.len(),
                        self.engine.x.d()
                    ));
                }
                self.engine.move_point(i, &row);
            }
            Command::Implode => self.engine.implode(),
            Command::Pause => {
                self.paused = true;
                return Ok(Some(Event::Paused { iter }));
            }
            Command::Resume => {
                self.paused = false;
                return Ok(Some(Event::Resumed { iter }));
            }
        }
        Ok(None)
    }

    /// Log a command ahead of applying it. With no WAL attached this
    /// is free; with a broken WAL every command is refused until a
    /// checkpoint reattaches a fresh log.
    fn wal_append(&mut self, iter: usize, cmd: &Command) -> std::result::Result<(), String> {
        if let Some(e) = &self.wal_error {
            return Err(format!("write-ahead log unavailable: {e}"));
        }
        let Some(wal) = self.wal.as_mut() else { return Ok(()) };
        match wal.append(iter as u64, cmd) {
            Ok(seq) => {
                self.last_wal_seq = seq;
                Ok(())
            }
            Err(e) => {
                let msg = format!("write-ahead log append failed: {e}");
                self.wal = None;
                self.wal_error = Some(msg.clone());
                Err(msg)
            }
        }
    }

    // --- durability ----------------------------------------------------

    /// Attach (or detach) the durable command log, clearing any prior
    /// WAL failure. Attached by the server/CLI at session creation, on
    /// boot restore (after replay), and after every checkpoint.
    pub fn set_wal(&mut self, wal: Option<WalWriter>) {
        self.wal = wal;
        self.wal_error = None;
    }

    /// Whether a write-ahead log is currently attached and healthy.
    pub fn wal_attached(&self) -> bool {
        self.wal.is_some() && self.wal_error.is_none()
    }

    /// The WAL failure that detached the log, if any.
    pub fn wal_error(&self) -> Option<&str> {
        self.wal_error.as_deref()
    }

    /// Sequence number of the last durably logged command.
    pub fn wal_seq(&self) -> u64 {
        self.last_wal_seq
    }

    /// The sequence number the next fresh log should start at.
    pub fn wal_next_seq(&self) -> u64 {
        self.last_wal_seq + 1
    }

    /// Seed the WAL sequence floor after replaying a log tail (the
    /// replayed commands bypass [`Session::wal_append`]).
    pub(crate) fn set_wal_seq(&mut self, seq: u64) {
        self.last_wal_seq = seq;
    }

    /// Mark the log unavailable without clearing the failure (used
    /// when a checkpoint cannot recreate the log file): commands are
    /// rejected until a later checkpoint succeeds.
    pub(crate) fn mark_wal_broken(&mut self, reason: String) {
        self.wal = None;
        self.wal_error = Some(reason);
    }

    /// Export the complete durable image of this session: engine state,
    /// PCA basis, bookkeeping, and the WAL sequence number the image is
    /// consistent with. Sinks, the ring snapshot buffer's *contents*
    /// and the WAL attachment are deliberately not part of the image —
    /// they are transient observers, not trajectory state.
    pub fn export_state(&self) -> SessionState {
        SessionState {
            engine: self.engine.export_state(),
            pca: self.pca.clone(),
            paused: self.paused,
            snapshot_stride: self.snapshot_stride as u64,
            snapshot_capacity: self.snapshots.capacity() as u64,
            commands_applied: self.commands_applied,
            commands_rejected: self.commands_rejected,
            wal_seq: self.last_wal_seq,
        }
    }

    /// Rebuild a session from a decoded snapshot image. The compute
    /// backend is reconstructed from the stored config (AOT artifacts
    /// under `artifact_dir`); stepping the result is bitwise-identical
    /// to stepping the session the image was exported from.
    pub fn from_state(st: SessionState, artifact_dir: &std::path::Path) -> Result<Session> {
        let wal_seq = st.wal_seq;
        let engine = FuncSne::from_state(st.engine)?;
        let backend =
            crate::coordinator::driver::make_backend(&engine.cfg, engine.x.d(), artifact_dir)?;
        let mut s = Session::from_parts(
            engine,
            backend,
            st.pca,
            st.snapshot_stride as usize,
            (st.snapshot_capacity as usize).max(1),
        );
        s.paused = st.paused;
        s.commands_applied = st.commands_applied;
        s.commands_rejected = st.commands_rejected;
        s.last_wal_seq = wal_seq;
        Ok(s)
    }

    /// Project an incoming row batch through the retained PCA basis (if
    /// the session was built with PCA pre-reduction). Rows must be in
    /// the *original* data space; passing already-reduced rows is an
    /// error — accepting them would bypass the projection and mix bases.
    fn project_incoming(&self, m: Matrix) -> std::result::Result<Matrix, String> {
        match &self.pca {
            None => Ok(m),
            Some(pca) => {
                if m.d() != pca.input_dim() {
                    return Err(format!(
                        "row dim {} != original data dim {} (this session PCA-reduces \
                         {} → {}; dynamic rows must arrive in the original space)",
                        m.d(),
                        pca.input_dim(),
                        pca.input_dim(),
                        pca.out_dim()
                    ));
                }
                Ok(pca.transform(&m))
            }
        }
    }

    /// Single-row variant of [`Session::project_incoming`].
    fn project_incoming_row(&self, row: Vec<f32>) -> std::result::Result<Vec<f32>, String> {
        if self.pca.is_none() {
            return Ok(row);
        }
        let d = row.len();
        let m = Matrix::from_vec(row, 1, d).map_err(|e| e.to_string())?;
        Ok(self.project_incoming(m)?.row(0).to_vec())
    }

    fn emit(&mut self, event: Event) {
        for sink in &mut self.sinks {
            sink.on_event(&event);
        }
    }

    // --- read access ---------------------------------------------------

    /// The current embedding (N × ld_dim).
    pub fn embedding(&self) -> &Matrix {
        self.engine.embedding()
    }

    /// Engine telemetry counters.
    pub fn stats(&self) -> &EngineStats {
        &self.engine.stats
    }

    /// The most recent online quality-probe report, if probing is
    /// enabled (`probe_every > 0`) and at least one probe has run.
    pub fn quality(&self) -> Option<&QualityReport> {
        self.engine.stats.quality.as_ref()
    }

    /// Iterations completed.
    pub fn iterations(&self) -> usize {
        self.engine.iter
    }

    /// Current number of points.
    pub fn n(&self) -> usize {
        self.engine.n()
    }

    /// The active configuration (reflects applied commands).
    pub fn config(&self) -> &EmbedConfig {
        &self.engine.cfg
    }

    /// Read-only engine access (metrics, KNN tables, figures).
    pub fn engine(&self) -> &FuncSne {
        &self.engine
    }

    /// The force backend's name (`"native"` / `"parallel"` / `"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Everything a frame producer needs in one borrow: the current
    /// iteration, the engine's structural epoch (bumped by insert /
    /// remove / implode — see [`FuncSne::structure_version`]) and the
    /// live embedding. Used by the server's streaming-frame subsystem
    /// ([`crate::server::frames`]) to encode keyframe/delta frames
    /// without copying the coordinates first.
    pub fn frame_source(&self) -> (usize, u64, &Matrix) {
        (self.engine.iter, self.engine.structure_version(), &self.engine.y)
    }

    /// The PCA basis fitted by the builder's pre-reduction, if any
    /// (incoming dynamic rows are projected through it).
    pub fn pca(&self) -> Option<&Pca> {
        self.pca.as_ref()
    }

    /// Recorded embedding snapshots.
    pub fn snapshots(&self) -> &SnapshotBuffer {
        &self.snapshots
    }

    /// Commands applied / rejected so far.
    pub fn command_counts(&self) -> (u64, u64) {
        (self.commands_applied, self.commands_rejected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    fn small_session(seed: u64) -> Session {
        let ds = datasets::blobs(120, 6, 3, 0.5, 8.0, seed);
        Session::builder()
            .dataset(ds.x)
            .k_hd(12)
            .k_ld(8)
            .perplexity(8.0)
            .n_neg(6)
            .jumpstart_iters(5)
            .early_exag_iters(10)
            .seed(seed)
            .build()
            .unwrap()
    }

    #[test]
    fn commands_change_config_between_iterations() {
        let mut s = small_session(1);
        s.run(10).unwrap();
        s.enqueue(Command::SetAlpha(0.5));
        s.enqueue(Command::SetAttraction(2.0));
        assert_eq!(s.config().alpha, 1.0, "commands must not apply before a step");
        s.run(1).unwrap();
        assert_eq!(s.config().alpha, 0.5);
        assert_eq!(s.config().attraction, 2.0);
        let (applied, rejected) = s.command_counts();
        assert_eq!((applied, rejected), (2, 0));
    }

    #[test]
    fn pause_and_resume_gate_stepping() {
        let mut s = small_session(2);
        s.run(5).unwrap();
        s.enqueue(Command::Pause);
        s.run(5).unwrap();
        assert_eq!(s.iterations(), 5, "paused session must not iterate");
        assert!(s.is_paused());
        s.enqueue(Command::Resume);
        s.run(3).unwrap();
        assert_eq!(s.iterations(), 8);
    }

    #[test]
    fn invalid_commands_are_rejected_not_fatal() {
        let mut s = small_session(3);
        s.run(2).unwrap();
        s.enqueue(Command::SetPerplexity(0.5)); // < 2 → rejected
        s.enqueue(Command::RemovePoint(10_000)); // out of range
        s.enqueue(Command::SetAlpha(0.7)); // fine
        s.run(1).unwrap();
        let (applied, rejected) = s.command_counts();
        assert_eq!((applied, rejected), (1, 2));
        assert_eq!(s.config().alpha, 0.7);
        assert_eq!(s.n(), 120);
    }

    #[test]
    fn snapshots_record_at_stride() {
        let ds = datasets::blobs(80, 5, 2, 0.5, 8.0, 4);
        let mut s = Session::builder()
            .dataset(ds.x)
            .k_hd(10)
            .k_ld(6)
            .perplexity(6.0)
            .jumpstart_iters(0)
            .snapshot_stride(5)
            .snapshot_capacity(3)
            .build()
            .unwrap();
        s.run(22).unwrap();
        assert_eq!(s.snapshots().total_recorded(), 4); // iters 5,10,15,20
        assert_eq!(s.snapshots().len(), 3); // ring evicted iter-5
        assert_eq!(s.snapshots().latest().unwrap().iter, 20);
        assert_eq!(s.snapshots().latest().unwrap().y.n(), 80);
    }

    #[test]
    fn quality_events_emitted_at_probe_cadence() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let ds = datasets::blobs(100, 5, 2, 0.5, 8.0, 6);
        let mut s = Session::builder()
            .dataset(ds.x)
            .k_hd(10)
            .k_ld(6)
            .perplexity(6.0)
            .jumpstart_iters(0)
            .probe_every(4)
            .probe_anchors(16)
            .seed(6)
            .build()
            .unwrap();
        let iters: Rc<RefCell<Vec<usize>>> = Rc::new(RefCell::new(Vec::new()));
        let tap = Rc::clone(&iters);
        s.add_sink(Box::new(move |e: &Event| {
            if let Event::Quality { iter, recall, trust, cont, knn_recall_hd } = e {
                for v in [recall, trust, cont, knn_recall_hd] {
                    assert!((0.0..=1.0).contains(v), "quality metric out of range: {v}");
                }
                tap.borrow_mut().push(*iter);
            }
        }));
        s.run(10).unwrap();
        assert_eq!(*iters.borrow(), vec![4, 8], "probe cadence");
        let q = s.quality().expect("latest report retained");
        assert_eq!(q.iter, 8);
        assert_eq!(q.anchors, 16);
    }

    #[test]
    fn sinks_observe_iterations_and_commands() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let events: Rc<RefCell<Vec<Event>>> = Rc::new(RefCell::new(Vec::new()));
        let tap = Rc::clone(&events);
        let mut s = small_session(5);
        s.add_sink(Box::new(move |e: &Event| tap.borrow_mut().push(e.clone())));
        s.enqueue(Command::SetRepulsion(1.5));
        s.run(3).unwrap();
        let ev = events.borrow();
        let iters = ev.iter().filter(|e| matches!(e, Event::Iteration { .. })).count();
        let applied = ev.iter().filter(|e| matches!(e, Event::CommandApplied { .. })).count();
        assert_eq!(iters, 3);
        assert_eq!(applied, 1);
        // The command event precedes the iteration it lands before.
        assert!(matches!(ev[0], Event::CommandApplied { .. }));
    }
}
