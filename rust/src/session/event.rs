//! The session's outbound event stream and snapshot ring buffer.
//!
//! This replaces the old closure-based `FuncSne::run_with` observer:
//! any number of [`EventSink`]s receive per-iteration telemetry and
//! command outcomes, while embedding coordinates are captured into a
//! bounded [`SnapshotBuffer`] at a configurable stride (so a slow
//! consumer — a GUI, a websocket — can always fetch the latest frames
//! without back-pressuring the optimisation).

use crate::data::Matrix;
use crate::engine::EngineStats;
use std::collections::VecDeque;

/// Something that happened inside a [`crate::session::Session`].
#[derive(Clone, Debug)]
pub enum Event {
    /// One engine iteration completed.
    Iteration { iter: usize, stats: EngineStats },
    /// An embedding snapshot was recorded into the [`SnapshotBuffer`].
    Snapshot { iter: usize },
    /// The online quality probe measured this iteration
    /// ([`crate::metrics::probe`]): sampled embedding KNN recall@k,
    /// trustworthiness, continuity, and the iterative-KNN recall vs the
    /// anchors' exact HD ground truth.
    Quality { iter: usize, recall: f64, trust: f64, cont: f64, knn_recall_hd: f64 },
    /// A queued command was applied between iterations.
    CommandApplied { iter: usize, description: String },
    /// A queued command failed validation and was dropped (the session
    /// keeps running — frontends surface the reason to the user).
    CommandRejected { iter: usize, description: String, reason: String },
    /// The session entered the paused state.
    Paused { iter: usize },
    /// The session left the paused state.
    Resumed { iter: usize },
}

impl Event {
    /// The iteration count at which the event was emitted.
    pub fn at_iter(&self) -> usize {
        match self {
            Event::Iteration { iter, .. }
            | Event::Snapshot { iter }
            | Event::Quality { iter, .. }
            | Event::CommandApplied { iter, .. }
            | Event::CommandRejected { iter, .. }
            | Event::Paused { iter }
            | Event::Resumed { iter } => *iter,
        }
    }
}

/// Receives every [`Event`] a session emits. Implemented for closures,
/// so `session.add_sink(Box::new(|e: &Event| ...))` works directly.
pub trait EventSink {
    fn on_event(&mut self, event: &Event);
}

impl<F: FnMut(&Event)> EventSink for F {
    fn on_event(&mut self, event: &Event) {
        self(event)
    }
}

/// One recorded embedding frame.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Iteration at which the frame was taken.
    pub iter: usize,
    /// A copy of the embedding (N × ld_dim) at that iteration.
    pub y: Matrix,
}

/// Bounded ring buffer of embedding snapshots: pushing beyond capacity
/// drops the oldest frame.
#[derive(Debug)]
pub struct SnapshotBuffer {
    cap: usize,
    buf: VecDeque<Snapshot>,
    recorded: u64,
}

impl SnapshotBuffer {
    /// A buffer holding at most `capacity` frames (min 1).
    pub fn new(capacity: usize) -> SnapshotBuffer {
        let cap = capacity.max(1);
        SnapshotBuffer { cap, buf: VecDeque::with_capacity(cap), recorded: 0 }
    }

    /// Record a frame, evicting the oldest if full.
    pub fn push(&mut self, iter: usize, y: &Matrix) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(Snapshot { iter, y: y.clone() });
        self.recorded += 1;
    }

    /// Most recent frame, if any.
    pub fn latest(&self) -> Option<&Snapshot> {
        self.buf.back()
    }

    /// The nearest recorded frame at or before `iter` — the principled
    /// lookup for "give me the embedding as of iteration N". Returns
    /// `None` when every held frame is newer than `iter` (the ring may
    /// have evicted the requested history) or the buffer is empty.
    pub fn at_or_before(&self, iter: usize) -> Option<&Snapshot> {
        // Frames are pushed in iteration order, so scanning from the
        // back finds the newest frame that is not too new.
        self.buf.iter().rev().find(|s| s.iter <= iter)
    }

    /// Frames currently held, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &Snapshot> {
        self.buf.iter()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total frames ever recorded (including evicted ones).
    pub fn total_recorded(&self) -> u64 {
        self.recorded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_evicts_oldest() {
        let mut b = SnapshotBuffer::new(3);
        let y = Matrix::zeros(4, 2);
        for it in 1..=5 {
            b.push(it * 10, &y);
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.capacity(), 3);
        assert_eq!(b.total_recorded(), 5);
        let iters: Vec<usize> = b.iter().map(|s| s.iter).collect();
        assert_eq!(iters, vec![30, 40, 50]);
        assert_eq!(b.latest().unwrap().iter, 50);
    }

    #[test]
    fn at_or_before_picks_nearest_not_newer() {
        let mut b = SnapshotBuffer::new(8);
        let y = Matrix::zeros(4, 2);
        for it in [10, 20, 30] {
            b.push(it, &y);
        }
        assert!(b.at_or_before(9).is_none(), "before the first frame");
        assert_eq!(b.at_or_before(10).unwrap().iter, 10);
        assert_eq!(b.at_or_before(19).unwrap().iter, 10);
        assert_eq!(b.at_or_before(20).unwrap().iter, 20);
        assert_eq!(b.at_or_before(29).unwrap().iter, 20);
        assert_eq!(b.at_or_before(30).unwrap().iter, 30);
        assert_eq!(b.at_or_before(usize::MAX).unwrap().iter, 30);
    }

    #[test]
    fn at_or_before_after_ring_wraparound() {
        // Capacity 3, pushes at 10..=60: frames 10/20/30 are evicted,
        // the ring holds 40/50/60 with its head in the middle of the
        // backing storage.
        let mut b = SnapshotBuffer::new(3);
        let y = Matrix::zeros(2, 2);
        for it in 1..=6 {
            b.push(it * 10, &y);
        }
        assert_eq!(b.total_recorded(), 6);
        assert!(b.at_or_before(39).is_none(), "evicted history must not resolve");
        assert_eq!(b.at_or_before(40).unwrap().iter, 40);
        assert_eq!(b.at_or_before(55).unwrap().iter, 50);
        assert_eq!(b.at_or_before(60).unwrap().iter, 60);
        assert_eq!(b.at_or_before(1000).unwrap().iter, 60);
    }

    #[test]
    fn at_or_before_empty_buffer() {
        let b = SnapshotBuffer::new(4);
        assert!(b.at_or_before(100).is_none());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut b = SnapshotBuffer::new(0);
        let y = Matrix::zeros(2, 2);
        b.push(1, &y);
        b.push(2, &y);
        assert_eq!(b.len(), 1);
        assert_eq!(b.latest().unwrap().iter, 2);
    }

    #[test]
    fn closures_are_sinks() {
        let mut count = 0usize;
        {
            let mut sink = |_e: &Event| count += 1;
            sink.on_event(&Event::Paused { iter: 0 });
            sink.on_event(&Event::Resumed { iter: 1 });
        }
        assert_eq!(count, 2);
    }
}
