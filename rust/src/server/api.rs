//! The REST surface: routes HTTP requests onto the stepper channel and
//! translates between JSON payloads and the typed session API.
//!
//! | Route                               | Meaning                                   |
//! |-------------------------------------|-------------------------------------------|
//! | `GET  /healthz`                     | liveness (round-trips the stepper)        |
//! | `GET  /metrics`                     | Prometheus text-format counters           |
//! | `GET  /debug/trace`                 | Chrome trace-event JSON (Perfetto)        |
//! | `POST /sessions`                    | create from inline `rows` or a `path`     |
//! | `GET  /sessions`                    | list live sessions                        |
//! | `GET  /sessions/:id`                | the session resource (same view as stats) |
//! | `GET  /sessions/:id/stats`          | config, counters, last step error         |
//! | `GET  /sessions/:id/embedding`      | live frame, or `?iter=N` nearest snapshot |
//! | `GET  /sessions/:id/stream`         | chunked binary frame stream (push)        |
//! | `POST /sessions/:id/commands`       | queue a typed [`Command`]                 |
//! | `POST /sessions/:id/checkpoint`     | force a durable snapshot now              |
//! | `DELETE /sessions/:id`              | remove the session (and its state files)  |
//!
//! `GET /sessions/:id/embedding` supports conditional polling: every
//! response carries an `ETag` pinned to the frame's iteration (and the
//! engine's structural epoch), and a request whose `If-None-Match`
//! matches gets `304 Not Modified` without re-encoding the JSON body.
//! `GET /sessions/:id/stream` upgrades the connection to a chunked
//! `application/octet-stream` of binary frames (`docs/wire-format.md`).
//!
//! Command payloads mirror [`Command`] variants by snake-case name:
//! `{"command":"set_alpha","value":0.5}`,
//! `{"command":"insert_points","rows":[[...],...]}`,
//! `{"command":"move_point","index":3,"row":[...]}`, etc.

use super::http::{Handler, Reply, Request, Response, StreamStart};
use super::json::{self, Json};
use super::stepper::{
    CreateSpec, EmbeddingFrame, ServiceError, ServiceMetrics, ServiceResult, SessionView,
    StepperRequest,
};
use crate::data::Matrix;
use crate::engine::PhaseMicros;
use crate::knn::iterative::CandidateRoutes;
use crate::metrics::probe::QualityReport;
use crate::obs::{expo, trace, Obs, PhaseQuantiles};
use crate::session::{Command, Session};
use crate::util::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long a handler waits for the stepper to reply before reporting
/// the service unavailable (the stepper answers between sweeps, so
/// this bounds one sweep plus queueing).
const REPLY_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-worker request handler; clone one per [`crate::runtime::WorkerPool`]
/// slot (the channel sender is cloneable, the counters are shared).
#[derive(Clone)]
pub struct Api {
    tx: Sender<StepperRequest>,
    http_requests: Arc<AtomicU64>,
    started: Instant,
    /// Default `snapshot_stride` for sessions that don't specify one
    /// (the CLI's `--snapshot-every`).
    default_snapshot_stride: usize,
    /// Shared observability registry (HTTP latency histograms, trace
    /// spans, `/debug/trace` export).
    obs: Arc<Obs>,
    /// This handler's worker-slot index — its trace `tid`.
    worker: usize,
}

impl Api {
    pub fn new(
        tx: Sender<StepperRequest>,
        http_requests: Arc<AtomicU64>,
        default_snapshot_stride: usize,
        obs: Arc<Obs>,
        worker: usize,
    ) -> Api {
        Api { tx, http_requests, started: Instant::now(), default_snapshot_stride, obs, worker }
    }

    /// Send one request to the stepper and wait for its typed reply.
    fn ask<T>(
        &self,
        make: impl FnOnce(Sender<ServiceResult<T>>) -> StepperRequest,
    ) -> ServiceResult<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| ServiceError::Unavailable("stepper thread is gone".to_string()))?;
        reply_rx
            .recv_timeout(REPLY_TIMEOUT)
            .map_err(|_| ServiceError::Unavailable("stepper did not reply".to_string()))?
    }

    /// Same as [`Api::ask`] for the two replies that are not
    /// `ServiceResult`-wrapped (list, metrics).
    fn ask_infallible<T>(
        &self,
        make: impl FnOnce(Sender<T>) -> StepperRequest,
    ) -> ServiceResult<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| ServiceError::Unavailable("stepper thread is gone".to_string()))?;
        reply_rx
            .recv_timeout(REPLY_TIMEOUT)
            .map_err(|_| ServiceError::Unavailable("stepper did not reply".to_string()))
    }

    fn route(&mut self, req: &Request) -> ServiceResult<Reply> {
        let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        match (req.method.as_str(), segments.as_slice()) {
            ("GET", ["healthz"]) => self.healthz().map(Into::into),
            ("GET", ["metrics"]) => self.metrics().map(Into::into),
            ("GET", ["debug", "trace"]) => self.debug_trace().map(Into::into),
            ("POST", ["sessions"]) => self.create_session(req).map(Into::into),
            ("GET", ["sessions"]) => self.list_sessions().map(Into::into),
            // The session resource itself (the url `POST /sessions`
            // returns) answers with the same view as /stats.
            ("GET", ["sessions", id]) | ("GET", ["sessions", id, "stats"]) => {
                let id = parse_id(id)?;
                let view = self.ask(|r| StepperRequest::Stats(id, r))?;
                Ok(Response::json(200, &view_json(&view)).into())
            }
            ("GET", ["sessions", id, "embedding"]) => {
                let id = parse_id(id)?;
                let iter = req
                    .query_usize("iter")
                    .map_err(|e| ServiceError::Invalid(e.to_string()))?;
                let frame = self.ask(|r| StepperRequest::Embedding(id, iter, r))?;
                let etag = frame_etag(id, &frame);
                if req
                    .headers
                    .get("if-none-match")
                    .is_some_and(|h| etag_matches(h, &etag))
                {
                    // Identical frame: skip the JSON re-encode (the
                    // dominant cost at large n) and send headers only.
                    return Ok(Response::empty(304).header("ETag", etag).into());
                }
                Ok(Response::json(200, &frame_json(id, &frame)).header("ETag", etag).into())
            }
            ("GET", ["sessions", id, "stream"]) => {
                let id = parse_id(id)?;
                let sub = self.ask(|r| StepperRequest::Subscribe(id, r))?;
                Ok(Reply::Stream(StreamStart {
                    status: 200,
                    content_type: "application/octet-stream",
                    source: Box::new(sub),
                }))
            }
            ("POST", ["sessions", id, "commands"]) => {
                let id = parse_id(id)?;
                let body = parse_body(req)?;
                let command = command_from_json(&body).map_err(ServiceError::Invalid)?;
                let description = command.describe();
                self.ask(|r| StepperRequest::Enqueue(id, command, r))?;
                let body = Json::obj(vec![
                    ("status", "queued".into()),
                    ("command", description.into()),
                ]);
                Ok(Response::json(202, &body).into())
            }
            ("POST", ["sessions", id, "checkpoint"]) => {
                let id = parse_id(id)?;
                let info = self.ask(|r| StepperRequest::Checkpoint(id, r))?;
                let body = Json::obj(vec![
                    ("status", "checkpointed".into()),
                    ("bytes", info.bytes.into()),
                    ("iter", info.iter.into()),
                    ("wal_seq", info.wal_seq.into()),
                ]);
                Ok(Response::json(200, &body).into())
            }
            ("DELETE", ["sessions", id]) => {
                let id = parse_id(id)?;
                self.ask(|r| StepperRequest::Delete(id, r))?;
                Ok(Response::json(200, &Json::obj(vec![("deleted", true.into())])).into())
            }
            // Known paths with the wrong method get 405; anything else
            // (including typo'd subresources) is a plain 404.
            (_, ["healthz" | "metrics"])
            | (_, ["debug", "trace"])
            | (_, ["sessions"])
            | (_, ["sessions", _])
            | (_, ["sessions", _, "stats" | "embedding" | "commands" | "stream" | "checkpoint"]) => {
                Ok(Response::json(
                    405,
                    &Json::obj(vec![(
                        "error",
                        format!("method {} not allowed on {}", req.method, req.path).into(),
                    )]),
                )
                .into())
            }
            _ => Err(ServiceError::NotFound(format!("no route for {}", req.path))),
        }
    }

    fn healthz(&self) -> ServiceResult<Response> {
        // Round-trip the stepper so "ok" proves the loop is live, not
        // just that the socket accepts.
        let m = self.ask_infallible(StepperRequest::Metrics)?;
        Ok(Response::json(
            200,
            &Json::obj(vec![
                ("status", "ok".into()),
                ("sessions", m.sessions.into()),
                ("sweeps", m.sweeps.into()),
                ("uptime_ms", (self.started.elapsed().as_millis() as u64).into()),
            ]),
        ))
    }

    fn metrics(&self) -> ServiceResult<Response> {
        let m = self.ask_infallible(StepperRequest::Metrics)?;
        let text = render_prometheus(&m, &self.http_requests, self.started, &self.obs);
        Ok(Response::text(200, text))
    }

    /// The buffered trace ring as Chrome trace-event JSON. Always 200:
    /// with observability off the document is empty but well-formed
    /// (`otherData.enabled` says why), so tooling can probe safely.
    fn debug_trace(&self) -> ServiceResult<Response> {
        let (events, dropped) = self.obs.tracer_snapshot();
        let doc = trace::chrome_trace_json(&events, self.obs.enabled(), dropped);
        Ok(Response::json(200, &doc))
    }

    fn create_session(&self, req: &Request) -> ServiceResult<Response> {
        let body = parse_body(req)?;
        let spec = create_spec_from_json(&body, self.default_snapshot_stride)?;
        let view = self.ask(|r| StepperRequest::Create(Box::new(spec), r))?;
        let mut obj = match view_json(&view) {
            Json::Obj(m) => m,
            _ => unreachable!("view_json returns an object"),
        };
        obj.insert("url".to_string(), format!("/sessions/{}", view.id).into());
        Ok(Response::json(201, &Json::Obj(obj)))
    }

    fn list_sessions(&self) -> ServiceResult<Response> {
        let views = self.ask_infallible(StepperRequest::List)?;
        let items: Vec<Json> = views.iter().map(view_json).collect();
        Ok(Response::json(200, &Json::obj(vec![("sessions", items.into())])))
    }
}

impl Handler for Api {
    fn handle(&mut self, req: &Request) -> Reply {
        self.http_requests.fetch_add(1, Ordering::Relaxed);
        match self.route(req) {
            Ok(reply) => reply,
            Err(e) => {
                Response::json(e.status(), &Json::obj(vec![("error", e.message().into())])).into()
            }
        }
    }

    fn observe(&mut self, req: &Request, status: u16, micros: u64) {
        self.obs.observe_http(&req.method, &req.path, status, micros, self.worker);
    }
}

/// Strong validator for an embedding frame: source, iteration, shape
/// and the engine's structural epoch pin the JSON body exactly (a
/// same-iter poll after an insert/remove changes `version`, so it
/// still misses).
fn frame_etag(id: u64, frame: &EmbeddingFrame) -> String {
    format!(
        "\"s{id}-{}-i{}-n{}x{}-v{}\"",
        frame.source, frame.iter, frame.n, frame.d, frame.version
    )
}

/// RFC 9110 `If-None-Match`: a comma-separated list of entity-tags, or
/// `*`. Comparison is weak (a `W/` prefix on either side is ignored),
/// which is what cache revalidation on GET calls for.
fn etag_matches(header: &str, etag: &str) -> bool {
    let bare = etag.strip_prefix("W/").unwrap_or(etag);
    header
        .split(',')
        .map(str::trim)
        .any(|t| t == "*" || t.strip_prefix("W/").unwrap_or(t) == bare)
}

fn parse_id(raw: &str) -> ServiceResult<u64> {
    raw.parse::<u64>()
        .map_err(|_| ServiceError::Invalid(format!("bad session id {raw:?}")))
}

fn parse_body(req: &Request) -> ServiceResult<Json> {
    let text = req.body_str().map_err(|e| ServiceError::Invalid(e.to_string()))?;
    if text.trim().is_empty() {
        return Err(ServiceError::Invalid("empty request body (expected JSON)".to_string()));
    }
    json::parse(text).map_err(|e| ServiceError::Invalid(format!("bad JSON: {e}")))
}

/// `{"rows": [[...], ...]}` → row-major [`Matrix`].
fn matrix_from_rows(rows: &Json) -> Result<Matrix, String> {
    let rows = rows.as_arr().ok_or("\"rows\" must be an array of arrays")?;
    if rows.is_empty() {
        return Err("\"rows\" is empty".to_string());
    }
    let d = rows[0].as_arr().ok_or("\"rows\" must be an array of arrays")?.len();
    if d == 0 {
        return Err("rows must have at least one column".to_string());
    }
    let mut data = Vec::with_capacity(rows.len() * d);
    for (i, row) in rows.iter().enumerate() {
        let row = row.as_arr().ok_or_else(|| format!("row {i} is not an array"))?;
        if row.len() != d {
            return Err(format!("row {i} has {} values, expected {d}", row.len()));
        }
        for v in row {
            data.push(v.as_f64().ok_or_else(|| format!("row {i} has a non-number"))? as f32);
        }
    }
    Matrix::from_vec(data, rows.len(), d).map_err(|e| e.to_string())
}

fn f32_vec(v: &Json, what: &str) -> Result<Vec<f32>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what} must be an array of numbers"))?
        .iter()
        .map(|x| x.as_f64().map(|f| f as f32).ok_or_else(|| format!("{what} has a non-number")))
        .collect()
}

fn num_field(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("command needs a numeric {key:?} field"))
}

fn usize_field(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("command needs a non-negative integer {key:?} field"))
}

/// Map a JSON command object onto the typed [`Command`] enum.
pub fn command_from_json(v: &Json) -> Result<Command, String> {
    let name = v
        .get("command")
        .and_then(Json::as_str)
        .ok_or("missing string \"command\" field")?;
    match name {
        "set_alpha" => Ok(Command::SetAlpha(num_field(v, "value")?)),
        "set_perplexity" => Ok(Command::SetPerplexity(num_field(v, "value")?)),
        "set_attraction" => Ok(Command::SetAttraction(num_field(v, "value")?)),
        "set_repulsion" => Ok(Command::SetRepulsion(num_field(v, "value")?)),
        "set_routes" => {
            let mut routes = CandidateRoutes::default();
            if let Some(b) = v.get("same_space").and_then(Json::as_bool) {
                routes.same_space = b;
            }
            if let Some(b) = v.get("cross_space").and_then(Json::as_bool) {
                routes.cross_space = b;
            }
            if let Some(b) = v.get("random").and_then(Json::as_bool) {
                routes.random = b;
            }
            Ok(Command::SetRoutes(routes))
        }
        "insert_points" => {
            let rows = v.get("rows").ok_or("insert_points needs a \"rows\" field")?;
            Ok(Command::InsertPoints(matrix_from_rows(rows)?))
        }
        "remove_point" => Ok(Command::RemovePoint(usize_field(v, "index")?)),
        "move_point" => {
            let index = usize_field(v, "index")?;
            let row = v.get("row").ok_or("move_point needs a \"row\" field")?;
            Ok(Command::MovePoint(index, f32_vec(row, "\"row\"")?))
        }
        "implode" => Ok(Command::Implode),
        "pause" => Ok(Command::Pause),
        "resume" => Ok(Command::Resume),
        other => Err(format!(
            "unknown command {other:?} (set_alpha, set_perplexity, set_attraction, \
             set_repulsion, set_routes, insert_points, remove_point, move_point, \
             implode, pause, resume)"
        )),
    }
}

/// Build a [`CreateSpec`] from the `POST /sessions` body.
fn create_spec_from_json(v: &Json, default_stride: usize) -> ServiceResult<CreateSpec> {
    let x = match (v.get("rows"), v.get("path")) {
        (Some(rows), None) => matrix_from_rows(rows).map_err(ServiceError::Invalid)?,
        (None, Some(path)) => {
            let path = path
                .as_str()
                .ok_or_else(|| ServiceError::Invalid("\"path\" must be a string".to_string()))?;
            // Extension check comes first — before any filesystem
            // access — so the endpoint cannot be used to probe
            // arbitrary server-side files. (Path-based creation is
            // inherently trusting; see the README's loopback note.)
            let lower = path.to_ascii_lowercase();
            if !lower.ends_with(".npy") && !lower.ends_with(".csv") {
                return Err(ServiceError::Invalid(
                    "\"path\" must name a .npy or .csv file".to_string(),
                ));
            }
            // Server-side loads get the same size budget as inline
            // bodies — checked up front so a huge file never reaches
            // read_to_string and OOMs the process. The exact size is
            // deliberately not echoed back.
            let cap = super::http::MAX_BODY_BYTES as u64;
            if std::fs::metadata(path).map(|m| m.len()).unwrap_or(0) > cap {
                return Err(ServiceError::Invalid(format!(
                    "{path:?} exceeds the {cap}-byte limit"
                )));
            }
            let (data, n, d) = io::read_matrix_f32(std::path::Path::new(path))
                .map_err(|e| ServiceError::Invalid(format!("loading {path:?}: {e:?}")))?;
            Matrix::from_vec(data, n, d).map_err(|e| ServiceError::Invalid(e.to_string()))?
        }
        _ => {
            return Err(ServiceError::Invalid(
                "provide exactly one of \"rows\" (inline data) or \"path\" (.npy/.csv)"
                    .to_string(),
            ))
        }
    };
    let n = x.n();
    let mut builder = Session::builder().dataset(x);

    let get_usize = |key: &str| -> ServiceResult<Option<usize>> {
        match v.get(key) {
            None => Ok(None),
            Some(j) => j.as_usize().map(Some).ok_or_else(|| {
                ServiceError::Invalid(format!("{key:?} must be a non-negative integer"))
            }),
        }
    };
    let get_f64 = |key: &str| -> ServiceResult<Option<f64>> {
        match v.get(key) {
            None => Ok(None),
            Some(j) => j
                .as_f64()
                .map(Some)
                .ok_or_else(|| ServiceError::Invalid(format!("{key:?} must be a number"))),
        }
    };

    if let Some(d) = get_usize("ld_dim")? {
        builder = builder.ld_dim(d);
    }
    if let Some(a) = get_f64("alpha")? {
        builder = builder.alpha(a);
    }
    // Clamp the neighbour-set knobs to the dataset like the CLI does
    // (`cmd_embed`): k_hd never exceeds n-1, and perplexity rides down
    // with an explicit k_hd so the default perplexity (30) cannot fail
    // `k_hd >= perplexity` validation on a small requested k_hd.
    let p_req = get_f64("perplexity")?;
    match get_usize("k_hd")? {
        Some(k) => {
            let k = k.min(n.saturating_sub(1)).max(2);
            builder = builder.k_hd(k);
            let default_p = crate::config::EmbedConfig::default().perplexity;
            builder = builder.perplexity(p_req.unwrap_or(default_p).min(k as f64));
        }
        None => {
            if let Some(p) = p_req {
                builder = builder.perplexity(p);
            }
        }
    }
    if let Some(k) = get_usize("k_ld")? {
        builder = builder.k_ld(k.min(n.saturating_sub(1)).max(1));
    }
    if let Some(m) = get_usize("n_neg")? {
        builder = builder.n_neg(m);
    }
    if let Some(lr) = get_f64("lr")? {
        builder = builder.lr(lr);
    }
    if let Some(a) = get_f64("attraction")? {
        builder = builder.attraction(a);
    }
    if let Some(r) = get_f64("repulsion")? {
        builder = builder.repulsion(r);
    }
    if let Some(s) = get_usize("seed")? {
        builder = builder.seed(s as u64);
    }
    if let Some(t) = get_usize("threads")? {
        builder = builder.threads(t);
    }
    if let Some(i) = get_usize("n_iters")? {
        builder = builder.n_iters(i);
    }
    if let Some(i) = get_usize("jumpstart_iters")? {
        builder = builder.jumpstart_iters(i);
    }
    if let Some(i) = get_usize("early_exag_iters")? {
        builder = builder.early_exag_iters(i);
    }
    if let Some(d) = get_usize("pca_max_dim")? {
        builder = builder.pca_max_dim(d);
    }
    if let Some(p) = get_usize("probe_every")? {
        builder = builder.probe_every(p);
    }
    if let Some(a) = get_usize("probe_anchors")? {
        builder = builder.probe_anchors(a);
    }
    if let Some(name) = v.get("backend") {
        let name = name
            .as_str()
            .ok_or_else(|| ServiceError::Invalid("\"backend\" must be a string".to_string()))?;
        builder = builder.backend_name(name);
    }
    let stride = get_usize("snapshot_stride")?.unwrap_or(default_stride);
    builder = builder.snapshot_stride(stride);
    builder = builder.snapshot_capacity(get_usize("snapshot_capacity")?.unwrap_or(64));
    let max_iters = get_usize("max_iters")?.unwrap_or(0);
    Ok(CreateSpec { builder, max_iters })
}

fn view_json(v: &SessionView) -> Json {
    Json::obj(vec![
        ("id", v.id.into()),
        ("iter", v.iter.into()),
        ("n", v.n.into()),
        ("hd_dim", v.hd_dim.into()),
        ("ld_dim", v.ld_dim.into()),
        ("paused", v.paused.into()),
        ("queued", v.queued.into()),
        ("commands_applied", v.commands_applied.into()),
        ("commands_rejected", v.commands_rejected.into()),
        ("backend", v.backend.into()),
        ("alpha", v.alpha.into()),
        ("perplexity", v.perplexity.into()),
        ("attraction", v.attraction.into()),
        ("repulsion", v.repulsion.into()),
        ("snapshots_held", v.snapshots_held.into()),
        ("snapshots_total", v.snapshots_total.into()),
        ("max_iters", v.max_iters.into()),
        (
            "last_error",
            v.last_error.as_ref().map_or(Json::Null, |e| e.as_str().into()),
        ),
        ("quality", v.quality.as_ref().map_or(Json::Null, quality_json)),
        ("phase_micros", phase_json(&v.phase_micros)),
        ("latency", latency_json(&v.latency)),
        ("durable", v.durable.into()),
        ("checkpoint_iter", v.checkpoint_iter.into()),
        (
            "checkpoint_error",
            v.checkpoint_error.as_ref().map_or(Json::Null, |e| e.as_str().into()),
        ),
    ])
}

/// The per-phase step-latency quantiles object, `null` until
/// observability is on and the session has stepped:
/// `{"step": {"samples": .., "p50_us": .., "p95_us": .., "p99_us": ..},
///   "refine_ld": {...}, ...}`.
fn latency_json(latency: &[PhaseQuantiles]) -> Json {
    if latency.is_empty() {
        return Json::Null;
    }
    Json::obj(
        latency
            .iter()
            .map(|q| {
                let obj = Json::obj(vec![
                    ("samples", q.samples.into()),
                    ("p50_us", q.p50_us.into()),
                    ("p95_us", q.p95_us.into()),
                    ("p99_us", q.p99_us.into()),
                ]);
                (q.phase, obj)
            })
            .collect(),
    )
}

fn quality_json(q: &QualityReport) -> Json {
    Json::obj(vec![
        ("iter", q.iter.into()),
        ("anchors", q.anchors.into()),
        ("k", q.k.into()),
        ("knn_recall", q.knn_recall.into()),
        ("trustworthiness", q.trustworthiness.into()),
        ("continuity", q.continuity.into()),
        ("knn_recall_hd", q.knn_recall_hd.into()),
    ])
}

fn phase_json(p: &PhaseMicros) -> Json {
    Json::obj(p.named().into_iter().map(|(name, us)| (name, us.into())).collect())
}

fn frame_json(id: u64, frame: &EmbeddingFrame) -> Json {
    let points: Vec<Json> = frame
        .data
        .chunks_exact(frame.d.max(1))
        .map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect()))
        .collect();
    Json::obj(vec![
        ("id", id.into()),
        ("iter", frame.iter.into()),
        ("n", frame.n.into()),
        ("d", frame.d.into()),
        ("source", frame.source.into()),
        ("points", points.into()),
    ])
}

fn render_prometheus(
    m: &ServiceMetrics,
    http_requests: &AtomicU64,
    started: Instant,
    obs: &Obs,
) -> String {
    let mut out = String::new();
    let mut metric = |name: &str, kind: &str, help: &str, value: String| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n{value}\n"));
    };
    metric(
        "funcsne_sessions",
        "gauge",
        "Live embedding sessions.",
        format!("funcsne_sessions {}", m.sessions),
    );
    metric(
        "funcsne_steps_total",
        "counter",
        "Engine iterations run across all sessions.",
        format!("funcsne_steps_total {}", m.steps),
    );
    metric(
        "funcsne_sweeps_total",
        "counter",
        "Round-robin step_all sweeps.",
        format!("funcsne_sweeps_total {}", m.sweeps),
    );
    metric(
        "funcsne_session_failures_total",
        "counter",
        "Session steps that errored (session auto-paused).",
        format!("funcsne_session_failures_total {}", m.step_failures),
    );
    metric(
        "funcsne_commands_queued_total",
        "counter",
        "Commands accepted over HTTP.",
        format!("funcsne_commands_queued_total {}", m.commands_queued),
    );
    metric(
        "funcsne_sessions_created_total",
        "counter",
        "Sessions created.",
        format!("funcsne_sessions_created_total {}", m.sessions_created),
    );
    metric(
        "funcsne_sessions_deleted_total",
        "counter",
        "Sessions deleted.",
        format!("funcsne_sessions_deleted_total {}", m.sessions_deleted),
    );
    metric(
        "funcsne_http_requests_total",
        "counter",
        "HTTP requests handled.",
        format!("funcsne_http_requests_total {}", http_requests.load(Ordering::Relaxed)),
    );
    metric(
        "funcsne_stream_subscribers",
        "gauge",
        "Live frame-stream subscribers across all sessions.",
        format!("funcsne_stream_subscribers {}", m.stream_subscribers_total),
    );
    metric(
        "funcsne_frames_sent_total",
        "counter",
        "Binary frames enqueued to stream subscribers.",
        format!("funcsne_frames_sent_total {}", m.frames_sent),
    );
    metric(
        "funcsne_frames_dropped_total",
        "counter",
        "Binary frames dropped by per-subscriber backpressure.",
        format!("funcsne_frames_dropped_total {}", m.frames_dropped),
    );
    metric(
        "funcsne_uptime_seconds",
        "gauge",
        "Seconds since the server started.",
        format!("funcsne_uptime_seconds {}", started.elapsed().as_secs()),
    );
    if m.durable {
        // Durability families only exist on servers started with
        // --state-dir, keeping the default scrape byte-compatible
        // with non-durable deployments.
        metric(
            "funcsne_checkpoints_total",
            "counter",
            "Session snapshots published successfully.",
            format!("funcsne_checkpoints_total {}", m.checkpoints_total),
        );
        metric(
            "funcsne_checkpoint_failures_total",
            "counter",
            "Checkpoint attempts that failed (retried with backoff).",
            format!("funcsne_checkpoint_failures_total {}", m.checkpoint_failures_total),
        );
        metric(
            "funcsne_checkpoint_bytes_total",
            "counter",
            "Total bytes of session snapshot published.",
            format!("funcsne_checkpoint_bytes_total {}", m.checkpoint_bytes_total),
        );
        metric(
            "funcsne_restored_sessions",
            "gauge",
            "Sessions restored from the state dir at boot.",
            format!("funcsne_restored_sessions {}", m.restored_sessions),
        );
        metric(
            "funcsne_skipped_state_files",
            "gauge",
            "State files the boot scan skipped as corrupt or orphaned.",
            format!("funcsne_skipped_state_files {}", m.skipped_state_files),
        );
        // Checkpoint latency/size histograms are recorded even with
        // observability off (checkpoints are rare and off the hot
        // path), so they render whenever durability is on.
        let micros = obs
            .checkpoint_micros
            .snapshot()
            .prometheus_lines("funcsne_checkpoint_micros", "");
        if !micros.trim().is_empty() {
            metric(
                "funcsne_checkpoint_micros",
                "histogram",
                "Checkpoint (snapshot publish + WAL truncate) wall time (microseconds).",
                micros.trim_end().to_string(),
            );
        }
        let bytes =
            obs.checkpoint_bytes.snapshot().prometheus_lines("funcsne_checkpoint_bytes", "");
        if !bytes.trim().is_empty() {
            metric(
                "funcsne_checkpoint_bytes",
                "histogram",
                "Published snapshot size (bytes).",
                bytes.trim_end().to_string(),
            );
        }
    }
    if !m.session_iters.is_empty() {
        let lines: Vec<String> = m
            .session_iters
            .iter()
            .map(|(id, iter)| format!("funcsne_session_iterations{{id=\"{id}\"}} {iter}"))
            .collect();
        metric(
            "funcsne_session_iterations",
            "gauge",
            "Iterations completed per live session.",
            lines.join("\n"),
        );
    }
    if !m.session_quality.is_empty() {
        type Get = fn(&QualityReport) -> f64;
        let gauges: [(&str, &str, Get); 4] = [
            (
                "funcsne_quality_recall",
                "Sampled embedding KNN recall@k per session.",
                |q| q.knn_recall,
            ),
            (
                "funcsne_quality_trustworthiness",
                "Sampled trustworthiness per session.",
                |q| q.trustworthiness,
            ),
            (
                "funcsne_quality_continuity",
                "Sampled continuity per session.",
                |q| q.continuity,
            ),
            (
                "funcsne_knn_recall",
                "Iterative-KNN recall vs anchor HD ground truth per session.",
                |q| q.knn_recall_hd,
            ),
        ];
        for (name, help, get) in gauges {
            let lines: Vec<String> = m
                .session_quality
                .iter()
                .map(|(id, q)| format!("{name}{{id=\"{id}\"}} {}", get(q)))
                .collect();
            metric(name, "gauge", help, lines.join("\n"));
        }
    }
    if !m.session_phase.is_empty() {
        let lines: Vec<String> = m
            .session_phase
            .iter()
            .flat_map(|(id, p)| {
                p.named()
                    .into_iter()
                    .map(move |(phase, us)| {
                        format!("funcsne_phase_micros{{id=\"{id}\",phase=\"{phase}\"}} {us}")
                    })
                    .collect::<Vec<_>>()
            })
            .collect();
        metric(
            "funcsne_phase_micros",
            "gauge",
            "Cumulative engine wall-clock per step phase (microseconds).",
            lines.join("\n"),
        );
    }
    if !m.stream_subscribers.is_empty() {
        let lines: Vec<String> = m
            .stream_subscribers
            .iter()
            .map(|(id, subs)| format!("funcsne_stream_session_subscribers{{id=\"{id}\"}} {subs}"))
            .collect();
        metric(
            "funcsne_stream_session_subscribers",
            "gauge",
            "Live frame-stream subscribers per session.",
            lines.join("\n"),
        );
    }
    if !m.session_budget.is_empty() {
        let lines: Vec<String> = m
            .session_budget
            .iter()
            .map(|(id, budget)| format!("funcsne_step_budget{{id=\"{id}\"}} {budget}"))
            .collect();
        metric(
            "funcsne_step_budget",
            "gauge",
            "Steps the fair scheduler granted per session last sweep.",
            lines.join("\n"),
        );
    }
    if !m.session_states.is_empty() {
        let lines: Vec<String> = m
            .session_states
            .iter()
            .map(|(id, state)| {
                format!("funcsne_session_state{{id=\"{id}\",state=\"{state}\"}} 1")
            })
            .collect();
        metric(
            "funcsne_session_state",
            "gauge",
            "Session state (running/paused/failed), one labelled sample per session.",
            lines.join("\n"),
        );
    }
    if obs.enabled() {
        // Histogram families — only while observability is on, so the
        // default scrape stays byte-compatible with earlier releases.
        let mut hist = |name: &str, help: &str, body: String| {
            if !body.is_empty() {
                metric(name, "histogram", help, body.trim_end().to_string());
            }
        };
        let mut phase_lines = String::new();
        for (i, phase) in PhaseMicros::NAMES.iter().enumerate() {
            let labels = format!("phase=\"{phase}\"");
            let snap = obs.step_phase[i].snapshot();
            phase_lines.push_str(&snap.prometheus_lines("funcsne_step_phase_micros", &labels));
        }
        hist(
            "funcsne_step_phase_micros",
            "Engine step time by phase (microseconds).",
            phase_lines,
        );
        hist(
            "funcsne_step_micros",
            "Whole engine step wall time (microseconds).",
            obs.step.snapshot().prometheus_lines("funcsne_step_micros", ""),
        );
        hist(
            "funcsne_sweep_micros",
            "Stepper sweep duration (microseconds).",
            obs.sweep.snapshot().prometheus_lines("funcsne_sweep_micros", ""),
        );
        let mut http_lines = String::new();
        for (route, class, snap) in obs.http_snapshots() {
            let labels = format!("route=\"{}\",status=\"{class}\"", expo::escape_label(route));
            http_lines.push_str(&snap.prometheus_lines("funcsne_http_request_micros", &labels));
        }
        hist(
            "funcsne_http_request_micros",
            "HTTP request latency by route and status class (microseconds).",
            http_lines,
        );
        hist(
            "funcsne_frame_encode_micros",
            "Stream frame encode time (microseconds).",
            obs.frame_encode.snapshot().prometheus_lines("funcsne_frame_encode_micros", ""),
        );
        hist(
            "funcsne_frame_bytes",
            "Encoded stream frame size (bytes).",
            obs.frame_bytes.snapshot().prometheus_lines("funcsne_frame_bytes", ""),
        );
        hist(
            "funcsne_stream_queue_depth",
            "Subscriber queue depth after each enqueued frame.",
            obs.queue_depth.snapshot().prometheus_lines("funcsne_stream_queue_depth", ""),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd(text: &str) -> Result<Command, String> {
        command_from_json(&json::parse(text).unwrap())
    }

    #[test]
    fn commands_map_from_json() {
        assert!(matches!(
            cmd("{\"command\":\"set_alpha\",\"value\":0.5}").unwrap(),
            Command::SetAlpha(v) if v == 0.5
        ));
        assert!(matches!(
            cmd("{\"command\":\"set_perplexity\",\"value\":40}").unwrap(),
            Command::SetPerplexity(v) if v == 40.0
        ));
        assert!(matches!(
            cmd("{\"command\":\"remove_point\",\"index\":7}").unwrap(),
            Command::RemovePoint(7)
        ));
        assert!(matches!(cmd("{\"command\":\"pause\"}").unwrap(), Command::Pause));
        assert!(matches!(cmd("{\"command\":\"resume\"}").unwrap(), Command::Resume));
        assert!(matches!(cmd("{\"command\":\"implode\"}").unwrap(), Command::Implode));
    }

    #[test]
    fn insert_and_move_carry_payloads() {
        let c = cmd("{\"command\":\"insert_points\",\"rows\":[[1,2],[3,4],[5,6]]}").unwrap();
        match c {
            Command::InsertPoints(m) => {
                assert_eq!((m.n(), m.d()), (3, 2));
                assert_eq!(m.row(2), &[5.0, 6.0]);
            }
            other => panic!("wrong command {other:?}"),
        }
        let c = cmd("{\"command\":\"move_point\",\"index\":2,\"row\":[9,8,7]}").unwrap();
        match c {
            Command::MovePoint(i, row) => {
                assert_eq!(i, 2);
                assert_eq!(row, vec![9.0, 8.0, 7.0]);
            }
            other => panic!("wrong command {other:?}"),
        }
    }

    #[test]
    fn set_routes_defaults_then_overrides() {
        let c = cmd("{\"command\":\"set_routes\",\"random\":false}").unwrap();
        match c {
            Command::SetRoutes(r) => {
                assert!(r.same_space && r.cross_space && !r.random);
            }
            other => panic!("wrong command {other:?}"),
        }
    }

    #[test]
    fn malformed_commands_are_rejected() {
        assert!(cmd("{\"value\":1}").is_err(), "missing command");
        assert!(cmd("{\"command\":\"warp_speed\"}").is_err(), "unknown command");
        assert!(cmd("{\"command\":\"set_alpha\"}").is_err(), "missing value");
        assert!(cmd("{\"command\":\"remove_point\",\"index\":-1}").is_err());
        assert!(cmd("{\"command\":\"insert_points\",\"rows\":[[1],[2,3]]}").is_err(), "ragged");
        assert!(cmd("{\"command\":\"insert_points\",\"rows\":[]}").is_err(), "empty");
        assert!(cmd("{\"command\":\"move_point\",\"index\":0,\"row\":[\"x\"]}").is_err());
    }

    #[test]
    fn create_spec_requires_exactly_one_source() {
        let stride = 25;
        let err = create_spec_from_json(&json::parse("{}").unwrap(), stride).unwrap_err();
        assert_eq!(err.status(), 400);
        let both = json::parse("{\"rows\":[[1]],\"path\":\"x.npy\"}").unwrap();
        assert_eq!(create_spec_from_json(&both, stride).unwrap_err().status(), 400);
        let ok = json::parse("{\"rows\":[[1,2],[3,4],[5,6],[7,8]]}").unwrap();
        let spec = create_spec_from_json(&ok, stride).unwrap();
        assert_eq!(spec.max_iters, 0);
    }

    #[test]
    fn prometheus_rendering_has_counters() {
        let m = ServiceMetrics {
            sessions: 2,
            sweeps: 10,
            steps: 17,
            step_failures: 1,
            commands_queued: 3,
            sessions_created: 2,
            sessions_deleted: 0,
            stream_subscribers_total: 3,
            stream_subscribers: vec![(1, 3)],
            frames_sent: 120,
            frames_dropped: 4,
            session_iters: vec![(0, 9), (1, 8)],
            session_budget: vec![(0, 12), (1, 1)],
            session_quality: vec![(
                1,
                QualityReport {
                    iter: 8,
                    anchors: 64,
                    k: 10,
                    knn_recall: 0.75,
                    trustworthiness: 0.875,
                    continuity: 0.9375,
                    knn_recall_hd: 0.5,
                },
            )],
            session_phase: vec![(
                1,
                PhaseMicros {
                    refine_ld: 100,
                    refine_hd: 200,
                    recalibrate: 30,
                    forces: 400,
                    update: 50,
                },
            )],
            session_states: vec![(0, "running"), (1, "failed")],
            ..Default::default()
        };
        let reqs = AtomicU64::new(5);
        let text = render_prometheus(&m, &reqs, Instant::now(), &Obs::new(false));
        assert!(text.contains("# TYPE funcsne_sessions gauge"), "{text}");
        assert!(text.contains("funcsne_sessions 2"));
        assert!(text.contains("funcsne_steps_total 17"));
        assert!(text.contains("funcsne_session_failures_total 1"));
        assert!(text.contains("funcsne_http_requests_total 5"));
        assert!(text.contains("funcsne_session_iterations{id=\"1\"} 8"));
        assert!(text.contains("# TYPE funcsne_quality_recall gauge"), "{text}");
        assert!(text.contains("funcsne_quality_recall{id=\"1\"} 0.75"), "{text}");
        assert!(text.contains("funcsne_quality_trustworthiness{id=\"1\"} 0.875"), "{text}");
        assert!(text.contains("funcsne_quality_continuity{id=\"1\"} 0.9375"), "{text}");
        assert!(text.contains("funcsne_knn_recall{id=\"1\"} 0.5"), "{text}");
        assert!(text.contains("# TYPE funcsne_phase_micros gauge"), "{text}");
        assert!(
            text.contains("funcsne_phase_micros{id=\"1\",phase=\"refine_ld\"} 100"),
            "{text}"
        );
        assert!(
            text.contains("funcsne_phase_micros{id=\"1\",phase=\"forces\"} 400"),
            "{text}"
        );
        assert!(
            text.contains("funcsne_phase_micros{id=\"1\",phase=\"update\"} 50"),
            "{text}"
        );
        assert!(text.contains("funcsne_stream_subscribers 3"), "{text}");
        assert!(text.contains("funcsne_frames_sent_total 120"), "{text}");
        assert!(text.contains("funcsne_frames_dropped_total 4"), "{text}");
        assert!(text.contains("funcsne_stream_session_subscribers{id=\"1\"} 3"), "{text}");
        assert!(text.contains("funcsne_step_budget{id=\"0\"} 12"), "{text}");
        assert!(
            text.contains("funcsne_session_state{id=\"0\",state=\"running\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("funcsne_session_state{id=\"1\",state=\"failed\"} 1"),
            "{text}"
        );
        assert!(!text.contains("funcsne_step_micros"), "no histograms while disabled");
        expo::check_exposition(&text).expect("well-formed exposition");
    }

    #[test]
    fn prometheus_renders_histogram_families_when_observing() {
        let obs = Obs::new(true);
        obs.step.record(120);
        obs.step_phase[3].record(80); // forces
        obs.sweep.record(900);
        obs.observe_http("GET", "/sessions/1/stats", 200, 65, 0);
        obs.record_frame(12, 4_000);
        obs.record_queue_depth(2);
        let m = ServiceMetrics::default();
        let reqs = AtomicU64::new(1);
        let text = render_prometheus(&m, &reqs, Instant::now(), &obs);
        expo::check_exposition(&text).expect("well-formed exposition with histograms");
        assert!(text.contains("# TYPE funcsne_step_micros histogram"), "{text}");
        assert!(text.contains("funcsne_step_micros_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("funcsne_step_micros_sum 120"), "{text}");
        assert!(text.contains("funcsne_step_micros_count 1"), "{text}");
        assert!(
            text.contains("funcsne_step_phase_micros_bucket{phase=\"forces\",le=\"100\"} 1"),
            "{text}"
        );
        assert!(text.contains("# TYPE funcsne_sweep_micros histogram"), "{text}");
        let http = "funcsne_http_request_micros_bucket\
                    {route=\"GET /sessions/:id/stats\",status=\"2xx\",le=\"100\"} 1";
        assert!(text.contains(http), "{text}");
        assert!(text.contains("funcsne_frame_bytes_sum 4000"), "{text}");
        assert!(text.contains("funcsne_stream_queue_depth_count 1"), "{text}");
    }

    #[test]
    fn etag_matching_follows_if_none_match_semantics() {
        let frame = EmbeddingFrame {
            iter: 42,
            n: 10,
            d: 2,
            data: vec![0.0; 20],
            source: "live",
            version: 1,
        };
        let etag = frame_etag(3, &frame);
        assert_eq!(etag, "\"s3-live-i42-n10x2-v1\"");
        assert!(etag_matches(&etag, &etag));
        assert!(etag_matches("*", &etag));
        assert!(etag_matches(&format!("\"zzz\", {etag}"), &etag), "list member");
        assert!(etag_matches(&format!("W/{etag}"), &etag), "weak comparison");
        assert!(!etag_matches("\"s3-live-i41-n10x2-v1\"", &etag), "different iter");
        // Same iter, bumped structural epoch (insert/remove) → miss.
        let moved = EmbeddingFrame { version: 2, ..frame };
        assert!(!etag_matches(&frame_etag(3, &moved), &etag));
    }

    #[test]
    fn prometheus_durability_families_follow_the_state_dir_flag() {
        let obs = Obs::new(false);
        let reqs = AtomicU64::new(0);
        // Without --state-dir the scrape is byte-compatible with
        // non-durable deployments: no checkpoint families at all.
        let off = ServiceMetrics::default();
        let text = render_prometheus(&off, &reqs, Instant::now(), &obs);
        assert!(!text.contains("funcsne_checkpoint"), "{text}");
        // With it, counters render (even at zero) and the histograms
        // appear once a checkpoint has been recorded — independent of
        // the observability flag.
        obs.record_checkpoint(1_500, 64_000);
        let on = ServiceMetrics {
            durable: true,
            checkpoints_total: 3,
            checkpoint_failures_total: 1,
            checkpoint_bytes_total: 192_000,
            restored_sessions: 2,
            skipped_state_files: 1,
            ..Default::default()
        };
        let text = render_prometheus(&on, &reqs, Instant::now(), &obs);
        expo::check_exposition(&text).expect("well-formed exposition");
        assert!(text.contains("funcsne_checkpoints_total 3"), "{text}");
        assert!(text.contains("funcsne_checkpoint_failures_total 1"), "{text}");
        assert!(text.contains("funcsne_checkpoint_bytes_total 192000"), "{text}");
        assert!(text.contains("funcsne_restored_sessions 2"), "{text}");
        assert!(text.contains("funcsne_skipped_state_files 1"), "{text}");
        assert!(text.contains("# TYPE funcsne_checkpoint_micros histogram"), "{text}");
        assert!(text.contains("funcsne_checkpoint_bytes_count 1"), "{text}");
    }

    #[test]
    fn prometheus_omits_quality_when_no_session_has_reports() {
        let m = ServiceMetrics { sessions: 1, session_iters: vec![(0, 3)], ..Default::default() };
        let reqs = AtomicU64::new(0);
        let text = render_prometheus(&m, &reqs, Instant::now(), &Obs::new(false));
        assert!(!text.contains("funcsne_quality_recall"), "{text}");
    }

    #[test]
    fn latency_json_reports_quantiles_per_phase() {
        let qs = vec![PhaseQuantiles {
            phase: "step",
            samples: 12,
            p50_us: 100.0,
            p95_us: 200.0,
            p99_us: 500.0,
        }];
        let j = latency_json(&qs);
        let step = j.get("step").expect("step object");
        assert_eq!(step.get("samples").and_then(Json::as_usize), Some(12));
        assert_eq!(step.get("p50_us").and_then(Json::as_f64), Some(100.0));
        assert_eq!(step.get("p99_us").and_then(Json::as_f64), Some(500.0));
    }

    #[test]
    fn view_json_carries_quality_object() {
        let view = SessionView {
            id: 3,
            iter: 40,
            n: 100,
            hd_dim: 8,
            ld_dim: 2,
            paused: false,
            queued: 0,
            commands_applied: 0,
            commands_rejected: 0,
            backend: "native",
            alpha: 1.0,
            perplexity: 30.0,
            attraction: 1.0,
            repulsion: 1.0,
            snapshots_held: 0,
            snapshots_total: 0,
            max_iters: 0,
            last_error: None,
            quality: Some(QualityReport {
                iter: 40,
                anchors: 32,
                k: 10,
                knn_recall: 0.625,
                trustworthiness: 1.0,
                continuity: 1.0,
                knn_recall_hd: 0.25,
            }),
            phase_micros: PhaseMicros {
                refine_ld: 11,
                refine_hd: 22,
                recalibrate: 3,
                forces: 44,
                update: 5,
            },
            latency: Vec::new(),
            durable: false,
            checkpoint_iter: 0,
            checkpoint_error: None,
        };
        let j = view_json(&view);
        assert_eq!(j.get("latency"), Some(&Json::Null), "no samples yet");
        let q = j.get("quality").expect("quality present");
        assert_eq!(q.get("iter").and_then(Json::as_usize), Some(40));
        assert_eq!(q.get("knn_recall").and_then(Json::as_f64), Some(0.625));
        assert_eq!(q.get("knn_recall_hd").and_then(Json::as_f64), Some(0.25));
        let p = j.get("phase_micros").expect("phase split present");
        assert_eq!(p.get("refine_ld").and_then(Json::as_usize), Some(11));
        assert_eq!(p.get("refine_hd").and_then(Json::as_usize), Some(22));
        assert_eq!(p.get("recalibrate").and_then(Json::as_usize), Some(3));
        assert_eq!(p.get("forces").and_then(Json::as_usize), Some(44));
        assert_eq!(p.get("update").and_then(Json::as_usize), Some(5));
        let view = SessionView { quality: None, ..view };
        assert_eq!(view_json(&view).get("quality"), Some(&Json::Null));
    }
}
