//! The stepping thread: a dedicated background thread that owns the
//! [`SessionManager`] and the streaming [`FrameHub`], continuously
//! sweeping sessions while HTTP handlers talk to it through a
//! command/reply channel.
//!
//! [`crate::session::Session`] is deliberately `!Send`, so sessions
//! are created *on* this thread (the [`SessionBuilder`] spec crosses
//! the channel; the built session never does) and never migrate.
//! Request handling is interleaved with stepping — every pending
//! request drains before each sweep — so a slow client can never
//! back-pressure the optimisation, and stepping never blocks on
//! socket I/O.
//!
//! # Fair scheduling
//!
//! A sweep is no longer one-step-per-session round-robin: each session
//! gets a **step budget** for the sweep, computed from its share of a
//! fixed per-sweep time budget. Shares are weighted by subscriber
//! count (watched sessions feel interactive) and divided by the
//! session's recent per-step cost (an EWMA over the engine's own
//! `phase_micros` clock), so a million-point session burning 50 ms per
//! step gets one step per sweep while a toy session next to it gets
//! many — neither starves the other, and request latency stays bounded
//! by roughly [`SWEEP_BUDGET_MICROS`].
//!
//! When nothing stepped at all (no sessions, or all paused/failed),
//! the loop **parks** in a blocking `recv` instead of spinning over
//! empty queues; any request — including a stream subscribe — wakes
//! it.
//!
//! After each sweep the loop broadcasts one encoded frame per watched
//! session through the [`FrameHub`]; subscribers consume them from
//! bounded queues on the HTTP workers, so a stalled viewer drops
//! frames rather than stalling this thread.
//!
//! Known trade-off: `POST /sessions` builds the session (KNN tables,
//! calibration, optional PCA) on this thread, so a very large create
//! stalls other sessions for its duration. Moving construction onto
//! the HTTP workers would require splitting `SessionBuilder::build`
//! at the `Send` boundary (engine construction is `Send`, backend
//! attachment is not) — worth doing if create latency ever matters
//! more than implementation weight.

use crate::engine::PhaseMicros;
use crate::metrics::probe::QualityReport;
use crate::obs::{Obs, PhaseQuantiles, SessionLatency, StepTrace};
use crate::persist;
use crate::server::frames::{FrameHub, StreamConfig, StreamSubscription, SubscribeError};
use crate::session::{Command, Session, SessionBuilder, SessionId, SessionManager};
use crate::util::stats::Ewma;
use crate::util::timer::PhaseClock;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-sweep stepping time budget, µs: the fair scheduler hands each
/// session a slice of this, so a full sweep (and therefore request
/// latency) stays near this bound no matter how many cheap sessions
/// want to run.
const SWEEP_BUDGET_MICROS: f64 = 20_000.0;
/// Hard cap on steps one session may take in one sweep, whatever its
/// budget works out to (keeps a mis-measured tiny session from
/// monopolising a sweep).
const MAX_STEPS_PER_SWEEP: u32 = 64;
/// EWMA weight of the newest per-step cost sample.
const COST_EWMA_NEW: f64 = 0.3;
/// Assumed per-step cost before the first measurement, µs.
const DEFAULT_STEP_COST_US: f64 = 500.0;
/// First retry delay after a failed checkpoint; doubles per
/// consecutive failure up to [`CHECKPOINT_BACKOFF_CAP`].
const CHECKPOINT_BACKOFF_BASE: Duration = Duration::from_millis(500);
/// Ceiling on the checkpoint retry delay.
const CHECKPOINT_BACKOFF_CAP: Duration = Duration::from_secs(30);

/// Durable-session settings (the `serve --state-dir` flags). When
/// present, the stepper restores every session found under
/// `state_dir` at boot and checkpoints live sessions on a cadence,
/// on pause, and at shutdown.
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Directory holding `session-<id>.snap` / `session-<id>.wal`
    /// pairs.
    pub state_dir: PathBuf,
    /// Checkpoint a running session after this many iterations of
    /// progress (0 disables the cadence; pause / explicit-request /
    /// shutdown checkpoints still fire).
    pub checkpoint_every: usize,
    /// AOT artifact directory used to rebuild compute backends when
    /// restoring sessions.
    pub artifact_dir: PathBuf,
}

/// A service-level failure, carrying the HTTP status it maps to.
#[derive(Clone, Debug)]
pub enum ServiceError {
    /// Unknown session / snapshot not available.
    NotFound(String),
    /// Malformed or semantically invalid request payload.
    Invalid(String),
    /// The `--max-sessions` capacity limit was hit, or a stream
    /// subscriber cap.
    Full(String),
    /// The stepper thread is gone or unresponsive.
    Unavailable(String),
}

impl ServiceError {
    /// The HTTP status code this error renders as.
    pub fn status(&self) -> u16 {
        match self {
            ServiceError::NotFound(_) => 404,
            ServiceError::Invalid(_) => 400,
            ServiceError::Full(_) => 429,
            ServiceError::Unavailable(_) => 503,
        }
    }

    /// The human-readable message.
    pub fn message(&self) -> &str {
        match self {
            ServiceError::NotFound(m)
            | ServiceError::Invalid(m)
            | ServiceError::Full(m)
            | ServiceError::Unavailable(m) => m,
        }
    }
}

/// `Result` with a [`ServiceError`] (what reply channels carry).
pub type ServiceResult<T> = Result<T, ServiceError>;

/// One embedding frame handed back to a client.
#[derive(Clone, Debug)]
pub struct EmbeddingFrame {
    /// Iteration the frame was taken at.
    pub iter: usize,
    pub n: usize,
    pub d: usize,
    /// Row-major N × d coordinates.
    pub data: Vec<f32>,
    /// `"live"` (current embedding) or `"snapshot"` (ring buffer).
    pub source: &'static str,
    /// The engine's structural epoch for live frames (0 for
    /// snapshots, whose identity is already pinned by `iter`). Feeds
    /// the `ETag` so a same-iter poll after an insert/remove still
    /// misses the cache.
    pub version: u64,
}

/// Per-session state surfaced by `GET /sessions/:id/stats`.
#[derive(Clone, Debug)]
pub struct SessionView {
    pub id: u64,
    pub iter: usize,
    pub n: usize,
    pub hd_dim: usize,
    pub ld_dim: usize,
    pub paused: bool,
    pub queued: usize,
    pub commands_applied: u64,
    pub commands_rejected: u64,
    pub backend: &'static str,
    pub alpha: f64,
    pub perplexity: f64,
    pub attraction: f64,
    pub repulsion: f64,
    pub snapshots_held: usize,
    pub snapshots_total: u64,
    /// Auto-pause budget (0 = step until paused or deleted). Fires
    /// once; a `resume` command afterwards overrides it.
    pub max_iters: usize,
    /// The most recent step error, if the session has ever failed
    /// (cleared by a successful step after a `Resume`).
    pub last_error: Option<String>,
    /// Latest online quality-probe report (`None` while probing is off
    /// or before the first probe iteration).
    pub quality: Option<QualityReport>,
    /// Cumulative per-phase wall-clock split of the engine's `step`
    /// (refine_ld / refine_hd / recalibrate / forces / update), µs.
    pub phase_micros: PhaseMicros,
    /// Step-latency p50/p95/p99 per phase (whole-step `step` first).
    /// Empty until observability is enabled and a step has run.
    pub latency: Vec<PhaseQuantiles>,
    /// The session's command log is attached and healthy (always
    /// false on a server without `--state-dir`).
    pub durable: bool,
    /// Iteration of the last published snapshot (0 before the first).
    pub checkpoint_iter: usize,
    /// Why the last checkpoint or WAL append failed, if durability is
    /// currently degraded (cleared by the next successful checkpoint).
    pub checkpoint_error: Option<String>,
}

/// What a completed checkpoint covered (the reply to
/// `POST /sessions/:id/checkpoint`).
#[derive(Clone, Copy, Debug)]
pub struct CheckpointInfo {
    /// Published snapshot size in bytes.
    pub bytes: u64,
    /// Iteration the image was taken at.
    pub iter: usize,
    /// Last command sequence number folded into the image.
    pub wal_seq: u64,
}

/// Service-wide counters surfaced by `GET /metrics`.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    pub sessions: usize,
    pub sweeps: u64,
    pub steps: u64,
    pub step_failures: u64,
    pub commands_queued: u64,
    pub sessions_created: u64,
    pub sessions_deleted: u64,
    /// Live stream subscribers across all sessions.
    pub stream_subscribers_total: usize,
    /// `(id, live subscriber count)` per session with subscribers.
    pub stream_subscribers: Vec<(u64, usize)>,
    /// Frames enqueued to subscribers, ever.
    pub frames_sent: u64,
    /// Frames dropped by stream backpressure, ever.
    pub frames_dropped: u64,
    /// `(id, iteration)` per live session.
    pub session_iters: Vec<(u64, usize)>,
    /// `(id, latest probe report)` per live session that has one.
    pub session_quality: Vec<(u64, QualityReport)>,
    /// `(id, cumulative phase split)` per live session.
    pub session_phase: Vec<(u64, PhaseMicros)>,
    /// `(id, last scheduler step budget)` per live session.
    pub session_budget: Vec<(u64, u32)>,
    /// `(id, "running" | "paused" | "failed")` per live session —
    /// failed means the last step errored (and force-paused the
    /// session) with no clean step since.
    pub session_states: Vec<(u64, &'static str)>,
    /// The server was started with `--state-dir` (durability on).
    pub durable: bool,
    /// Snapshots published successfully, ever.
    pub checkpoints_total: u64,
    /// Checkpoint attempts that failed, ever.
    pub checkpoint_failures_total: u64,
    /// Bytes of snapshot published, ever.
    pub checkpoint_bytes_total: u64,
    /// Sessions brought back from disk at boot.
    pub restored_sessions: u64,
    /// State files the boot scan skipped (corrupt / orphaned).
    pub skipped_state_files: u64,
}

/// Everything needed to create a session on the stepper thread.
pub struct CreateSpec {
    pub builder: SessionBuilder,
    /// Force-pause after this many iterations (0 = unbounded). One-
    /// shot: a `resume` command after the pause overrides the budget.
    pub max_iters: usize,
}

/// The channel protocol between request handlers and the stepper.
pub enum StepperRequest {
    Create(Box<CreateSpec>, Sender<ServiceResult<SessionView>>),
    Enqueue(u64, Command, Sender<ServiceResult<()>>),
    Embedding(u64, Option<usize>, Sender<ServiceResult<EmbeddingFrame>>),
    Stats(u64, Sender<ServiceResult<SessionView>>),
    List(Sender<Vec<SessionView>>),
    Delete(u64, Sender<ServiceResult<()>>),
    Metrics(Sender<ServiceMetrics>),
    /// Open a frame stream on a session: the reply carries the
    /// consumer half of a bounded broadcast queue.
    Subscribe(u64, Sender<ServiceResult<StreamSubscription>>),
    /// Force a checkpoint now (`POST /sessions/:id/checkpoint`),
    /// bypassing the failure backoff.
    Checkpoint(u64, Sender<ServiceResult<CheckpointInfo>>),
    Shutdown,
}

// Everything crossing the channel must be Send (the Session itself
// never does). Compile-time proof, so a refactor that sneaks a
// non-Send field into the builder or a command fails here, loudly.
const fn assert_send<T: Send>() {}
const _: () = {
    assert_send::<StepperRequest>();
    assert_send::<SessionBuilder>();
    assert_send::<Command>();
    assert_send::<StreamSubscription>();
};

/// Handle to a running stepper thread. Dropping it (or calling
/// [`Stepper::shutdown`]) stops the loop and joins the thread.
pub struct Stepper {
    tx: Sender<StepperRequest>,
    join: Option<JoinHandle<()>>,
}

impl Stepper {
    /// Spawn the stepping thread with default stream settings and
    /// observability off. `max_sessions` bounds concurrent sessions
    /// (creates beyond it are refused with [`ServiceError::Full`]).
    /// Errs only if the OS refuses to create the thread.
    pub fn spawn(max_sessions: usize) -> Result<Stepper> {
        Stepper::spawn_with(max_sessions, StreamConfig::default(), Arc::new(Obs::new(false)), None)
    }

    /// [`Stepper::spawn`] with explicit streaming limits, a shared
    /// observability registry (sweep/step histograms + trace spans
    /// land there when it is enabled), and optional durability: with
    /// a [`DurabilityConfig`] the thread restores persisted sessions
    /// before serving its first request and checkpoints thereafter.
    pub fn spawn_with(
        max_sessions: usize,
        streams: StreamConfig,
        obs: Arc<Obs>,
        durability: Option<DurabilityConfig>,
    ) -> Result<Stepper> {
        let (tx, rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("funcsne-stepper".to_string())
            .spawn(move || run_loop(rx, max_sessions, streams, obs, durability))
            .context("spawn stepper thread")?;
        Ok(Stepper { tx, join: Some(join) })
    }

    /// A cloneable sender for request handlers (one per HTTP worker).
    pub fn sender(&self) -> Sender<StepperRequest> {
        self.tx.clone()
    }

    /// Stop the loop and join the thread (also what `Drop` does).
    pub fn shutdown(self) {
        // Drop impl does the work.
    }
}

impl Drop for Stepper {
    fn drop(&mut self) {
        let _ = self.tx.send(StepperRequest::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Side-table entries the manager doesn't know about.
struct SessionMeta {
    max_iters: usize,
    /// The budget fires **once**: after the auto-pause, an explicit
    /// `resume` command is an override and the session runs unbounded
    /// (otherwise resume would be silently re-paused every sweep).
    budget_fired: bool,
    last_error: Option<String>,
    /// EWMA of per-step cost in µs, measured from the engine's own
    /// `phase_micros` clock (0 until the first measured step). Shares
    /// [`Ewma`] with the engine's telemetry; retention is
    /// `1 - COST_EWMA_NEW`.
    cost_ewma: Ewma,
    /// The step budget the scheduler granted last sweep (gauge).
    budget: u32,
    /// Per-phase step-latency histograms behind the stats-JSON
    /// `latency` object (only fed while observability is enabled).
    latency: SessionLatency,
    /// Iteration covered by the last published snapshot.
    last_checkpoint_iter: usize,
    /// Command sequence folded into the last published snapshot.
    last_checkpoint_seq: u64,
    /// Consecutive checkpoint failures (drives the retry backoff).
    ckpt_failures: u32,
    /// Don't retry a failed checkpoint before this instant.
    ckpt_next_retry: Option<Instant>,
    /// Why the last checkpoint failed, if durability is degraded.
    checkpoint_error: Option<String>,
}

impl SessionMeta {
    /// Meta for a session whose durable image (if any) currently
    /// covers iteration `ckpt_iter` / sequence `ckpt_seq`.
    fn new(max_iters: usize, ckpt_iter: usize, ckpt_seq: u64) -> SessionMeta {
        SessionMeta {
            max_iters,
            budget_fired: false,
            last_error: None,
            cost_ewma: Ewma::new(1.0 - COST_EWMA_NEW),
            budget: 0,
            latency: SessionLatency::default(),
            last_checkpoint_iter: ckpt_iter,
            last_checkpoint_seq: ckpt_seq,
            ckpt_failures: 0,
            ckpt_next_retry: None,
            checkpoint_error: None,
        }
    }
}

struct Service {
    mgr: SessionManager,
    meta: BTreeMap<u64, SessionMeta>,
    hub: FrameHub,
    obs: Arc<Obs>,
    max_sessions: usize,
    durability: Option<DurabilityConfig>,
    sweeps: u64,
    steps: u64,
    step_failures: u64,
    commands_queued: u64,
    sessions_created: u64,
    sessions_deleted: u64,
    checkpoints: u64,
    checkpoint_failures: u64,
    checkpoint_bytes: u64,
    restored_sessions: u64,
    skipped_state_files: u64,
}

fn run_loop(
    rx: Receiver<StepperRequest>,
    max_sessions: usize,
    streams: StreamConfig,
    obs: Arc<Obs>,
    durability: Option<DurabilityConfig>,
) {
    let mut svc = Service {
        mgr: SessionManager::new(),
        meta: BTreeMap::new(),
        hub: FrameHub::new(streams, Arc::clone(&obs)),
        obs,
        max_sessions,
        durability,
        sweeps: 0,
        steps: 0,
        step_failures: 0,
        commands_queued: 0,
        sessions_created: 0,
        sessions_deleted: 0,
        checkpoints: 0,
        checkpoint_failures: 0,
        checkpoint_bytes: 0,
        restored_sessions: 0,
        skipped_state_files: 0,
    };
    // 0. Boot-time crash recovery: bring every persisted session back
    //    under its original id before the first request is served, so
    //    clients reconnecting after a restart find their URLs intact.
    svc.restore_at_boot();
    loop {
        // 1. Drain every pending request: client latency is bounded by
        //    one sweep, and bursts don't queue behind stepping.
        loop {
            match rx.try_recv() {
                Ok(StepperRequest::Shutdown) => return svc.teardown(),
                Ok(req) => svc.handle(req),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return svc.teardown(),
            }
        }
        // 2. One fair, budgeted sweep over every live session.
        let stepped = svc.sweep();
        // 3. Enforce per-session iteration budgets.
        svc.enforce_budgets();
        // 4. Checkpoint sessions whose durable image fell behind.
        svc.checkpoint_due();
        // 5. Push one frame per watched session.
        svc.broadcast_frames();
        // 6. Fully idle (no session stepped — none exist, or all are
        //    paused/failed)? Park until a request arrives instead of
        //    spinning over empty queues. Any request wakes the loop,
        //    including Subscribe and Enqueue(resume).
        if stepped == 0 {
            match rx.recv() {
                Ok(StepperRequest::Shutdown) => return svc.teardown(),
                Ok(req) => svc.handle(req),
                Err(_) => return svc.teardown(),
            }
        }
    }
}

impl Service {
    fn handle(&mut self, req: StepperRequest) {
        match req {
            StepperRequest::Create(spec, reply) => {
                let _ = reply.send(self.create(*spec));
            }
            StepperRequest::Enqueue(id, command, reply) => {
                let result = match self.mgr.enqueue(SessionId(id), command) {
                    Ok(()) => {
                        self.commands_queued += 1;
                        Ok(())
                    }
                    Err(e) => Err(ServiceError::NotFound(e.to_string())),
                };
                let _ = reply.send(result);
            }
            StepperRequest::Embedding(id, iter, reply) => {
                let _ = reply.send(self.embedding(id, iter));
            }
            StepperRequest::Stats(id, reply) => {
                let result = match self.mgr.get(SessionId(id)) {
                    Some(session) => Ok(self.view(id, session)),
                    None => Err(not_found(id)),
                };
                let _ = reply.send(result);
            }
            StepperRequest::List(reply) => {
                let views: Vec<SessionView> = self
                    .mgr
                    .ids()
                    .into_iter()
                    .filter_map(|sid| self.mgr.get(sid).map(|s| self.view(sid.0, s)))
                    .collect();
                let _ = reply.send(views);
            }
            StepperRequest::Delete(id, reply) => {
                let result = match self.mgr.remove(SessionId(id)) {
                    Some(_) => {
                        self.meta.remove(&id);
                        self.hub.drop_session(id);
                        self.sessions_deleted += 1;
                        // Deleting the session deletes its durable
                        // identity too — otherwise the next boot would
                        // resurrect it. Removal failure can't undo the
                        // in-memory delete, so report and move on.
                        if let Some(d) = &self.durability {
                            let paths = persist::session_paths(&d.state_dir, id);
                            if let Err(e) = persist::remove_session_files(&paths) {
                                eprintln!(
                                    "warning: state files for deleted session {id} not removed: {e}"
                                );
                            }
                        }
                        Ok(())
                    }
                    None => Err(not_found(id)),
                };
                let _ = reply.send(result);
            }
            StepperRequest::Metrics(reply) => {
                let _ = reply.send(self.metrics());
            }
            StepperRequest::Subscribe(id, reply) => {
                let _ = reply.send(self.subscribe(id));
            }
            StepperRequest::Checkpoint(id, reply) => {
                let result = if self.durability.is_none() {
                    Err(ServiceError::Invalid(
                        "server was started without --state-dir; checkpoints are disabled"
                            .to_string(),
                    ))
                } else if self.mgr.get(SessionId(id)).is_none() {
                    Err(not_found(id))
                } else {
                    self.checkpoint_one(id)
                };
                let _ = reply.send(result);
            }
            StepperRequest::Shutdown => unreachable!("handled by the loop"),
        }
    }

    fn create(&mut self, spec: CreateSpec) -> ServiceResult<SessionView> {
        if self.mgr.len() >= self.max_sessions {
            return Err(ServiceError::Full(format!(
                "session limit reached ({} live, max {})",
                self.mgr.len(),
                self.max_sessions
            )));
        }
        let session = spec
            .builder
            .build()
            .map_err(|e| ServiceError::Invalid(format!("session build failed: {e:?}")))?;
        let sid = self.mgr.add(session);
        self.meta.insert(sid.0, SessionMeta::new(spec.max_iters, 0, 0));
        self.sessions_created += 1;
        // A durable session gets its first snapshot (and an attached
        // WAL) immediately: from here on, every accepted command is
        // logged before it applies, and a `.wal` with no `.snap`
        // beside it can only mean a crash inside this window — the
        // boot scan reports it as orphaned rather than guessing.
        // Failure degrades gracefully: the session runs undurable,
        // the error lands in its stats, and the cadence retries.
        if self.durability.is_some() {
            let _ = self.checkpoint_one(sid.0);
        }
        // The session was inserted two statements ago on this same
        // thread; a miss here is a manager bug, but a 5xx beats a
        // poisoned stepper loop.
        let session = self
            .mgr
            .get(sid)
            .ok_or_else(|| {
                ServiceError::Unavailable("session vanished immediately after insert".to_string())
            })?;
        Ok(self.view(sid.0, session))
    }

    fn embedding(&self, id: u64, iter: Option<usize>) -> ServiceResult<EmbeddingFrame> {
        let session = self.mgr.get(SessionId(id)).ok_or_else(|| not_found(id))?;
        match iter {
            None => {
                let (at, version, y) = session.frame_source();
                Ok(EmbeddingFrame {
                    iter: at,
                    n: y.n(),
                    d: y.d(),
                    data: y.data().to_vec(),
                    source: "live",
                    version,
                })
            }
            Some(at) => match session.snapshots().at_or_before(at) {
                Some(snap) => Ok(EmbeddingFrame {
                    iter: snap.iter,
                    n: snap.y.n(),
                    d: snap.y.d(),
                    data: snap.y.data().to_vec(),
                    source: "snapshot",
                    version: 0,
                }),
                None => Err(ServiceError::NotFound(format!(
                    "no snapshot at or before iteration {at} for session {id} \
                     ({} held; was the session created with snapshot_stride > 0?)",
                    session.snapshots().len()
                ))),
            },
        }
    }

    fn subscribe(&mut self, id: u64) -> ServiceResult<StreamSubscription> {
        if self.mgr.get(SessionId(id)).is_none() {
            return Err(not_found(id));
        }
        let sub = self.hub.subscribe(id).map_err(|e| match e {
            SubscribeError::SessionFull => {
                ServiceError::Full(format!("session {id} is at its stream subscriber limit"))
            }
            SubscribeError::GlobalFull => {
                ServiceError::Full("server-wide stream subscriber limit reached".to_string())
            }
        })?;
        // Broadcast right away so the new subscriber's first frame (a
        // keyframe — subscribe forces one) arrives even if the session
        // is paused and the sweep loop is parked.
        if let Some(session) = self.mgr.get(SessionId(id)) {
            let (iter, version, y) = session.frame_source();
            self.hub.broadcast(id, iter as u64, y, version);
        }
        Ok(sub)
    }

    /// One fair sweep: grant each session a step budget proportional
    /// to `(1 + subscribers) / recent step cost` and bounded so the
    /// whole sweep stays near [`SWEEP_BUDGET_MICROS`]. Every session
    /// gets at least one `step()` call per sweep, so paused sessions
    /// still drain queued commands. Returns total steps taken.
    fn sweep(&mut self) -> u64 {
        self.sweeps += 1;
        let ids = self.mgr.ids();
        if ids.is_empty() {
            return 0;
        }
        // One branch when observability is off; timestamps + clocks
        // only exist when it is on.
        let observing = self.obs.enabled();
        let sweep_clock = observing.then(|| (self.obs.now_us(), PhaseClock::start()));
        // Plan first (immutable pass): weights need the hub, budgets
        // need the cost EWMAs.
        let mut plan: Vec<(u64, f64)> = Vec::with_capacity(ids.len());
        let mut total_weight = 0.0f64;
        for sid in &ids {
            let weight = 1.0 + self.hub.subscriber_count(sid.0) as f64;
            plan.push((sid.0, weight));
            total_weight += weight;
        }
        let mut total_steps = 0u64;
        for (id, weight) in plan {
            let cost = self
                .meta
                .get(&id)
                .map(|m| m.cost_ewma.get())
                .filter(|&c| c > 0.0)
                .unwrap_or(DEFAULT_STEP_COST_US)
                .max(1.0);
            let share = SWEEP_BUDGET_MICROS * weight / total_weight;
            let budget = ((share / cost).round() as i64).clamp(1, i64::from(MAX_STEPS_PER_SWEEP))
                as u32;
            // One-shot iteration budget: stop *at* max_iters so
            // `enforce_budgets` pauses exactly there (a multi-step
            // sweep must not overshoot the way one-step-per-sweep
            // never could).
            let iter_cap = self
                .meta
                .get(&id)
                .filter(|m| !m.budget_fired)
                .map_or(0, |m| m.max_iters);
            let Some(session) = self.mgr.get_mut(SessionId(id)) else { continue };
            let before_us = session.stats().phase_micros.total();
            let mut steps_here = 0u64;
            let mut failure: Option<String> = None;
            let mut traces: Vec<StepTrace> = Vec::new();
            for _ in 0..budget {
                if iter_cap > 0 && session.iterations() >= iter_cap {
                    break;
                }
                let step_clock = observing.then(|| {
                    (session.stats().phase_micros, self.obs.now_us(), PhaseClock::start())
                });
                match session.step() {
                    Ok(true) => {
                        steps_here += 1;
                        if let Some((phase0, ts_us, clock)) = step_clock {
                            traces.push(StepTrace {
                                iter: session.iterations(),
                                ts_us,
                                wall_us: clock.elapsed_ns() / 1_000,
                                phases: session.stats().phase_micros.delta(&phase0),
                            });
                        }
                    }
                    Ok(false) => break, // paused: queue drained, nothing to run
                    Err(e) => {
                        session.force_pause();
                        failure = Some(e.to_string());
                        break;
                    }
                }
            }
            let after_us = session.stats().phase_micros.total();
            if let Some(meta) = self.meta.get_mut(&id) {
                meta.budget = budget;
                if steps_here > 0 {
                    let per_step = after_us.saturating_sub(before_us) as f64 / steps_here as f64;
                    meta.cost_ewma.update(per_step);
                    // A clean step means any recorded error is stale
                    // (e.g. the client fixed the cause and resumed).
                    meta.last_error = None;
                }
                if let Some(err) = failure {
                    self.step_failures += 1;
                    meta.last_error = Some(err);
                }
                for st in &traces {
                    self.obs.record_step(id, self.sweeps, st);
                    meta.latency.record(st);
                }
            }
            total_steps += steps_here;
        }
        self.steps += total_steps;
        if let Some((ts_us, clock)) = sweep_clock {
            self.obs.record_sweep(self.sweeps, total_steps, ts_us, clock.elapsed_ns() / 1_000);
        }
        total_steps
    }

    /// Encode and fan out one frame per session that has subscribers.
    fn broadcast_frames(&mut self) {
        for sid in self.mgr.ids() {
            if !self.hub.wants_frames(sid.0) {
                continue;
            }
            if let Some(session) = self.mgr.get(sid) {
                let (iter, version, y) = session.frame_source();
                self.hub.broadcast(sid.0, iter as u64, y, version);
            }
        }
    }

    fn view(&self, id: u64, session: &Session) -> SessionView {
        let cfg = session.config();
        let (applied, rejected) = session.command_counts();
        let meta = self.meta.get(&id);
        SessionView {
            id,
            iter: session.iterations(),
            n: session.n(),
            hd_dim: session.engine().x.d(),
            ld_dim: cfg.ld_dim,
            paused: session.is_paused(),
            queued: session.queued(),
            commands_applied: applied,
            commands_rejected: rejected,
            backend: session.backend_name(),
            alpha: cfg.alpha,
            perplexity: cfg.perplexity,
            attraction: cfg.attraction,
            repulsion: cfg.repulsion,
            snapshots_held: session.snapshots().len(),
            snapshots_total: session.snapshots().total_recorded(),
            max_iters: meta.map_or(0, |m| m.max_iters),
            last_error: meta.and_then(|m| m.last_error.clone()),
            quality: session.quality().copied(),
            phase_micros: session.stats().phase_micros,
            latency: meta.map_or_else(Vec::new, |m| m.latency.quantiles()),
            durable: session.wal_attached(),
            checkpoint_iter: meta.map_or(0, |m| m.last_checkpoint_iter),
            checkpoint_error: meta
                .and_then(|m| m.checkpoint_error.clone())
                .or_else(|| session.wal_error().map(str::to_string)),
        }
    }

    fn metrics(&self) -> ServiceMetrics {
        ServiceMetrics {
            sessions: self.mgr.len(),
            sweeps: self.sweeps,
            steps: self.steps,
            step_failures: self.step_failures,
            commands_queued: self.commands_queued,
            sessions_created: self.sessions_created,
            sessions_deleted: self.sessions_deleted,
            stream_subscribers_total: self.hub.total_subscribers(),
            stream_subscribers: self.hub.subscriber_counts(),
            frames_sent: self.hub.frames_sent(),
            frames_dropped: self.hub.frames_dropped(),
            session_iters: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| self.mgr.get(sid).map(|s| (sid.0, s.iterations())))
                .collect(),
            session_quality: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| {
                    self.mgr.get(sid).and_then(|s| s.quality().copied().map(|q| (sid.0, q)))
                })
                .collect(),
            session_phase: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| {
                    self.mgr.get(sid).map(|s| (sid.0, s.stats().phase_micros))
                })
                .collect(),
            session_budget: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| self.meta.get(&sid.0).map(|m| (sid.0, m.budget)))
                .collect(),
            session_states: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| {
                    let session = self.mgr.get(sid)?;
                    let failed = self.meta.get(&sid.0).is_some_and(|m| m.last_error.is_some());
                    let state = if failed {
                        "failed"
                    } else if session.is_paused() {
                        "paused"
                    } else {
                        "running"
                    };
                    Some((sid.0, state))
                })
                .collect(),
            durable: self.durability.is_some(),
            checkpoints_total: self.checkpoints,
            checkpoint_failures_total: self.checkpoint_failures,
            checkpoint_bytes_total: self.checkpoint_bytes,
            restored_sessions: self.restored_sessions,
            skipped_state_files: self.skipped_state_files,
        }
    }

    fn enforce_budgets(&mut self) {
        for (id, meta) in self.meta.iter_mut() {
            if meta.max_iters == 0 || meta.budget_fired {
                continue;
            }
            if let Some(session) = self.mgr.get_mut(SessionId(*id)) {
                if !session.is_paused() && session.iterations() >= meta.max_iters {
                    session.force_pause();
                    meta.budget_fired = true;
                }
            }
        }
    }

    /// Boot-time crash recovery: restore every `session-<id>.snap` /
    /// `.wal` pair under the state dir, re-registering each session
    /// under its original id. Never fatal — corrupt or orphaned files
    /// are reported to stderr, counted, and left in place for
    /// post-mortem inspection.
    fn restore_at_boot(&mut self) {
        let Some(d) = self.durability.clone() else { return };
        let boot = persist::restore_all(&d.state_dir, &d.artifact_dir);
        for sk in &boot.skipped {
            eprintln!("state restore: skipping {}: {}", sk.path.display(), sk.reason);
        }
        self.skipped_state_files = boot.skipped.len() as u64;
        for (id, restored) in boot.sessions {
            if let Some(w) = &restored.wal_warning {
                eprintln!("session-{id}: discarded torn WAL tail: {w}");
            }
            // The restored session *is* its durable image (snapshot +
            // replayed tail, log compacted), so the checkpoint marks
            // start at the current position — nothing is dirty yet.
            let iter = restored.session.iterations();
            let seq = restored.session.wal_seq();
            match self.mgr.add_with_id(SessionId(id), restored.session) {
                Ok(()) => {
                    self.meta.insert(id, SessionMeta::new(0, iter, seq));
                    self.restored_sessions += 1;
                    eprintln!(
                        "session-{id}: restored at iteration {iter} \
                         ({} logged command(s) replayed)",
                        restored.replayed
                    );
                }
                Err(e) => eprintln!("session-{id}: restore discarded: {e}"),
            }
        }
    }

    /// Checkpoint one session now. Updates the durability counters,
    /// the session's checkpoint marks, and — on failure — its error
    /// and retry backoff. The caller has verified the session exists
    /// and durability is configured.
    fn checkpoint_one(&mut self, id: u64) -> ServiceResult<CheckpointInfo> {
        let Some(d) = &self.durability else {
            return Err(ServiceError::Invalid("durability is not configured".to_string()));
        };
        let paths = persist::session_paths(&d.state_dir, id);
        let session = self.mgr.get_mut(SessionId(id)).ok_or_else(|| not_found(id))?;
        let clock = PhaseClock::start();
        let result = persist::checkpoint_session(session, &paths);
        let (iter, seq) = (session.iterations(), session.wal_seq());
        match result {
            Ok(bytes) => {
                self.obs.record_checkpoint(clock.elapsed_ns() / 1_000, bytes);
                self.checkpoints += 1;
                self.checkpoint_bytes += bytes;
                if let Some(m) = self.meta.get_mut(&id) {
                    m.last_checkpoint_iter = iter;
                    m.last_checkpoint_seq = seq;
                    m.ckpt_failures = 0;
                    m.ckpt_next_retry = None;
                    m.checkpoint_error = None;
                }
                Ok(CheckpointInfo { bytes, iter, wal_seq: seq })
            }
            Err(e) => {
                self.checkpoint_failures += 1;
                let msg = e.to_string();
                if let Some(m) = self.meta.get_mut(&id) {
                    m.ckpt_failures = m.ckpt_failures.saturating_add(1);
                    // 0.5 s, 1 s, 2 s, … capped at 30 s: a full disk
                    // must not turn every sweep into an fsync storm.
                    let shift = (m.ckpt_failures - 1).min(10);
                    let delay = CHECKPOINT_BACKOFF_BASE
                        .saturating_mul(1u32 << shift)
                        .min(CHECKPOINT_BACKOFF_CAP);
                    m.ckpt_next_retry = Some(Instant::now() + delay);
                    m.checkpoint_error = Some(msg.clone());
                }
                Err(ServiceError::Unavailable(format!("checkpoint failed: {msg}")))
            }
        }
    }

    /// The checkpoint cadence, run once per loop cycle: a running
    /// session is re-imaged every `checkpoint_every` iterations of
    /// progress (bounding recovery recompute), and a paused session
    /// is imaged as soon as it has *anything* unsaved — pause is the
    /// natural quiesce point, and the loop parks right after, so this
    /// is the last chance before a potentially long idle stretch.
    fn checkpoint_due(&mut self) {
        if self.durability.is_none() {
            return;
        }
        let every = self.durability.as_ref().map_or(0, |d| d.checkpoint_every);
        for sid in self.mgr.ids() {
            let id = sid.0;
            let Some(session) = self.mgr.get(sid) else { continue };
            let (iter, seq, paused) =
                (session.iterations(), session.wal_seq(), session.is_paused());
            let Some(m) = self.meta.get(&id) else { continue };
            let progressed = iter.saturating_sub(m.last_checkpoint_iter);
            let dirty = iter != m.last_checkpoint_iter || seq != m.last_checkpoint_seq;
            let due = (every > 0 && progressed >= every) || (paused && dirty);
            if !due {
                continue;
            }
            if m.ckpt_next_retry.is_some_and(|t| Instant::now() < t) {
                continue; // failing: wait out the backoff
            }
            let _ = self.checkpoint_one(id); // failure recorded in meta
        }
    }

    /// Graceful teardown, shared by `Shutdown` and channel disconnect:
    /// make every session durable, hand each watched session's
    /// subscribers one final self-contained keyframe, then close all
    /// streams. Checkpoint failures are reported but never block the
    /// exit.
    fn teardown(&mut self) {
        if self.durability.is_some() {
            for sid in self.mgr.ids() {
                if let Err(e) = self.checkpoint_one(sid.0) {
                    eprintln!("shutdown checkpoint for session {}: {}", sid.0, e.message());
                }
            }
        }
        for sid in self.mgr.ids() {
            if !self.hub.wants_frames(sid.0) {
                continue;
            }
            self.hub.force_keyframe(sid.0);
            if let Some(session) = self.mgr.get(sid) {
                let (iter, version, y) = session.frame_source();
                self.hub.broadcast(sid.0, iter as u64, y, version);
            }
        }
        self.hub.drop_all();
    }
}

fn not_found(id: u64) -> ServiceError {
    ServiceError::NotFound(format!("unknown session {id}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::server::frames::{decode, FrameDecoder, NextFrame};
    use crate::session::Session;
    use std::time::{Duration, Instant};

    fn spec(seed: u64, max_iters: usize) -> Box<CreateSpec> {
        let ds = datasets::blobs(80, 5, 3, 0.5, 8.0, seed);
        let builder = Session::builder()
            .dataset(ds.x)
            .k_hd(10)
            .k_ld(6)
            .perplexity(6.0)
            .jumpstart_iters(2)
            .snapshot_stride(4)
            .snapshot_capacity(8)
            .seed(seed);
        Box::new(CreateSpec { builder, max_iters })
    }

    fn ask<T>(
        tx: &Sender<StepperRequest>,
        make: impl FnOnce(Sender<ServiceResult<T>>) -> StepperRequest,
    ) -> ServiceResult<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(make(reply_tx)).expect("stepper alive");
        reply_rx.recv_timeout(Duration::from_secs(30)).expect("stepper reply")
    }

    fn wait_until<F: FnMut() -> bool>(mut cond: F, what: &str) {
        let deadline = Instant::now() + Duration::from_secs(60);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn stepper_steps_in_background_and_applies_commands() {
        let stepper = Stepper::spawn(8).unwrap();
        let tx = stepper.sender();
        let view = ask(&tx, |r| StepperRequest::Create(spec(1, 0), r)).unwrap();
        assert_eq!(view.n, 80);
        assert_eq!(view.iter, 0);
        let id = view.id;

        // The background thread steps without any further requests.
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().iter >= 5,
            "background stepping",
        );

        // Mid-run hyperparameter change lands between iterations.
        ask(&tx, |r| StepperRequest::Enqueue(id, Command::SetAlpha(0.5), r)).unwrap();
        wait_until(
            || {
                let v = ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap();
                v.alpha == 0.5 && v.commands_applied >= 1
            },
            "alpha change to drain",
        );

        // Live embedding reflects the current iteration.
        let frame = ask(&tx, |r| StepperRequest::Embedding(id, None, r)).unwrap();
        assert_eq!((frame.n, frame.d), (80, 2));
        assert_eq!(frame.source, "live");
        assert_eq!(frame.data.len(), 160);

        // Snapshot lookup resolves to the nearest recorded frame.
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().snapshots_total >= 2,
            "snapshots to record",
        );
        let snap = ask(&tx, |r| StepperRequest::Embedding(id, Some(1_000_000), r)).unwrap();
        assert_eq!(snap.source, "snapshot");
        assert_eq!(snap.iter % 4, 0, "stride-4 snapshot");

        ask(&tx, |r| StepperRequest::Delete(id, r)).unwrap();
        let err = ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap_err();
        assert_eq!(err.status(), 404);
        stepper.shutdown();
    }

    #[test]
    fn max_iters_budget_auto_pauses() {
        let stepper = Stepper::spawn(8).unwrap();
        let tx = stepper.sender();
        let id = ask(&tx, |r| StepperRequest::Create(spec(2, 6), r)).unwrap().id;
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().paused,
            "budget pause",
        );
        let v = ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap();
        assert!((6..=7).contains(&v.iter), "stopped at the budget, got {}", v.iter);
        // A budget-paused session still drains queued commands, so it
        // stays steerable (and resumable) — never deadlocked. This also
        // exercises the idle park: with its only session paused the
        // loop is blocked in `recv`, and the Enqueue must wake it.
        ask(&tx, |r| StepperRequest::Enqueue(id, Command::SetRepulsion(1.5), r)).unwrap();
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().repulsion == 1.5,
            "command drain while paused",
        );
        stepper.shutdown();
    }

    #[test]
    fn session_capacity_is_enforced() {
        let stepper = Stepper::spawn(1).unwrap();
        let tx = stepper.sender();
        ask(&tx, |r| StepperRequest::Create(spec(3, 0), r)).unwrap();
        let err = ask(&tx, |r| StepperRequest::Create(spec(4, 0), r)).unwrap_err();
        assert_eq!(err.status(), 429);
        stepper.shutdown();
    }

    #[test]
    fn invalid_spec_is_rejected_not_fatal() {
        let stepper = Stepper::spawn(4).unwrap();
        let tx = stepper.sender();
        let bad = Box::new(CreateSpec {
            builder: Session::builder(), // no dataset
            max_iters: 0,
        });
        let err = ask(&tx, |r| StepperRequest::Create(bad, r)).unwrap_err();
        assert_eq!(err.status(), 400);
        // The loop survived; metrics still answer.
        let (mtx, mrx) = mpsc::channel();
        tx.send(StepperRequest::Metrics(mtx)).unwrap();
        let m = mrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(m.sessions, 0);
        stepper.shutdown();
    }

    #[test]
    fn subscribe_unknown_session_is_404() {
        let stepper = Stepper::spawn(4).unwrap();
        let tx = stepper.sender();
        let err = ask(&tx, |r| StepperRequest::Subscribe(99, r)).unwrap_err();
        assert_eq!(err.status(), 404);
        stepper.shutdown();
    }

    #[test]
    fn paused_session_still_delivers_first_keyframe() {
        let stepper = Stepper::spawn(4).unwrap();
        let tx = stepper.sender();
        // max_iters 3: the session pauses almost immediately, after
        // which the loop parks. Subscribe must still yield a keyframe.
        let id = ask(&tx, |r| StepperRequest::Create(spec(5, 3), r)).unwrap().id;
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().paused,
            "budget pause",
        );
        let mut sub = ask(&tx, |r| StepperRequest::Subscribe(id, r)).unwrap();
        let frame = match sub.next(Duration::from_secs(10)) {
            NextFrame::Frame(bytes) => decode(&bytes).unwrap(),
            _ => panic!("expected an immediate keyframe"),
        };
        assert!(frame.keyframe);
        assert_eq!((frame.n, frame.d), (80, 2));
        let mut dec = FrameDecoder::new();
        dec.apply(&frame).unwrap();
        assert_eq!(dec.coords().len(), 160);
        stepper.shutdown();
    }

    #[test]
    fn stream_follows_a_stepping_session() {
        let stepper = Stepper::spawn(4).unwrap();
        let tx = stepper.sender();
        let id = ask(&tx, |r| StepperRequest::Create(spec(6, 0), r)).unwrap().id;
        let mut sub = ask(&tx, |r| StepperRequest::Subscribe(id, r)).unwrap();
        let mut dec = FrameDecoder::new();
        let mut frames = 0usize;
        let deadline = Instant::now() + Duration::from_secs(60);
        while frames < 5 {
            assert!(Instant::now() < deadline, "timed out collecting frames");
            match sub.next(Duration::from_millis(250)) {
                NextFrame::Frame(bytes) => {
                    dec.apply(&decode(&bytes).unwrap()).unwrap();
                    frames += 1;
                }
                NextFrame::Idle => {}
                NextFrame::Closed => panic!("stream closed early"),
            }
        }
        assert!(dec.ready());
        assert!(dec.iter() > 0, "frames track live iterations");
        // Deleting the session closes the stream.
        ask(&tx, |r| StepperRequest::Delete(id, r)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            assert!(Instant::now() < deadline, "timed out waiting for close");
            match sub.next(Duration::from_millis(250)) {
                NextFrame::Closed => break,
                NextFrame::Frame(_) | NextFrame::Idle => {}
            }
        }
        stepper.shutdown();
    }
}
