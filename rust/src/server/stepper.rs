//! The stepping thread: a dedicated background thread that owns the
//! [`SessionManager`] and continuously sweeps
//! [`SessionManager::step_all_detailed`], while HTTP handlers talk to
//! it through a command/reply channel.
//!
//! [`crate::session::Session`] is deliberately `!Send`, so sessions
//! are created *on* this thread (the [`SessionBuilder`] spec crosses
//! the channel; the built session never does) and never migrate.
//! Request handling is interleaved with stepping — every pending
//! request drains before each sweep — so a slow client can never
//! back-pressure the optimisation, and stepping never blocks on
//! socket I/O.
//!
//! Known trade-off: `POST /sessions` builds the session (KNN tables,
//! calibration, optional PCA) on this thread, so a very large create
//! stalls other sessions for its duration. Moving construction onto
//! the HTTP workers would require splitting `SessionBuilder::build`
//! at the `Send` boundary (engine construction is `Send`, backend
//! attachment is not) — worth doing if create latency ever matters
//! more than implementation weight.

use crate::engine::PhaseMicros;
use crate::metrics::probe::QualityReport;
use crate::session::{Command, Session, SessionBuilder, SessionId, SessionManager};
use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Duration;

/// How long the loop naps when no session is actively stepping.
const IDLE_WAIT: Duration = Duration::from_millis(25);

/// A service-level failure, carrying the HTTP status it maps to.
#[derive(Clone, Debug)]
pub enum ServiceError {
    /// Unknown session / snapshot not available.
    NotFound(String),
    /// Malformed or semantically invalid request payload.
    Invalid(String),
    /// The `--max-sessions` capacity limit was hit.
    Full(String),
    /// The stepper thread is gone or unresponsive.
    Unavailable(String),
}

impl ServiceError {
    /// The HTTP status code this error renders as.
    pub fn status(&self) -> u16 {
        match self {
            ServiceError::NotFound(_) => 404,
            ServiceError::Invalid(_) => 400,
            ServiceError::Full(_) => 429,
            ServiceError::Unavailable(_) => 503,
        }
    }

    /// The human-readable message.
    pub fn message(&self) -> &str {
        match self {
            ServiceError::NotFound(m)
            | ServiceError::Invalid(m)
            | ServiceError::Full(m)
            | ServiceError::Unavailable(m) => m,
        }
    }
}

/// `Result` with a [`ServiceError`] (what reply channels carry).
pub type ServiceResult<T> = Result<T, ServiceError>;

/// One embedding frame handed back to a client.
#[derive(Clone, Debug)]
pub struct EmbeddingFrame {
    /// Iteration the frame was taken at.
    pub iter: usize,
    pub n: usize,
    pub d: usize,
    /// Row-major N × d coordinates.
    pub data: Vec<f32>,
    /// `"live"` (current embedding) or `"snapshot"` (ring buffer).
    pub source: &'static str,
}

/// Per-session state surfaced by `GET /sessions/:id/stats`.
#[derive(Clone, Debug)]
pub struct SessionView {
    pub id: u64,
    pub iter: usize,
    pub n: usize,
    pub hd_dim: usize,
    pub ld_dim: usize,
    pub paused: bool,
    pub queued: usize,
    pub commands_applied: u64,
    pub commands_rejected: u64,
    pub backend: &'static str,
    pub alpha: f64,
    pub perplexity: f64,
    pub attraction: f64,
    pub repulsion: f64,
    pub snapshots_held: usize,
    pub snapshots_total: u64,
    /// Auto-pause budget (0 = step until paused or deleted). Fires
    /// once; a `resume` command afterwards overrides it.
    pub max_iters: usize,
    /// The most recent step error, if the session has ever failed
    /// (cleared by a successful step after a `Resume`).
    pub last_error: Option<String>,
    /// Latest online quality-probe report (`None` while probing is off
    /// or before the first probe iteration).
    pub quality: Option<QualityReport>,
    /// Cumulative per-phase wall-clock split of the engine's `step`
    /// (refine_ld / refine_hd / recalibrate / forces / update), µs.
    pub phase_micros: PhaseMicros,
}

/// Service-wide counters surfaced by `GET /metrics`.
#[derive(Clone, Debug, Default)]
pub struct ServiceMetrics {
    pub sessions: usize,
    pub sweeps: u64,
    pub steps: u64,
    pub step_failures: u64,
    pub commands_queued: u64,
    pub sessions_created: u64,
    pub sessions_deleted: u64,
    /// `(id, iteration)` per live session.
    pub session_iters: Vec<(u64, usize)>,
    /// `(id, latest probe report)` per live session that has one.
    pub session_quality: Vec<(u64, QualityReport)>,
    /// `(id, cumulative phase split)` per live session.
    pub session_phase: Vec<(u64, PhaseMicros)>,
}

/// Everything needed to create a session on the stepper thread.
pub struct CreateSpec {
    pub builder: SessionBuilder,
    /// Force-pause after this many iterations (0 = unbounded). One-
    /// shot: a `resume` command after the pause overrides the budget.
    pub max_iters: usize,
}

/// The channel protocol between request handlers and the stepper.
pub enum StepperRequest {
    Create(Box<CreateSpec>, Sender<ServiceResult<SessionView>>),
    Enqueue(u64, Command, Sender<ServiceResult<()>>),
    Embedding(u64, Option<usize>, Sender<ServiceResult<EmbeddingFrame>>),
    Stats(u64, Sender<ServiceResult<SessionView>>),
    List(Sender<Vec<SessionView>>),
    Delete(u64, Sender<ServiceResult<()>>),
    Metrics(Sender<ServiceMetrics>),
    Shutdown,
}

// Everything crossing the channel must be Send (the Session itself
// never does). Compile-time proof, so a refactor that sneaks a
// non-Send field into the builder or a command fails here, loudly.
const fn assert_send<T: Send>() {}
const _: () = {
    assert_send::<StepperRequest>();
    assert_send::<SessionBuilder>();
    assert_send::<Command>();
};

/// Handle to a running stepper thread. Dropping it (or calling
/// [`Stepper::shutdown`]) stops the loop and joins the thread.
pub struct Stepper {
    tx: Sender<StepperRequest>,
    join: Option<JoinHandle<()>>,
}

impl Stepper {
    /// Spawn the stepping thread. `max_sessions` bounds concurrent
    /// sessions (creates beyond it are refused with
    /// [`ServiceError::Full`]).
    pub fn spawn(max_sessions: usize) -> Stepper {
        let (tx, rx) = mpsc::channel();
        let join = std::thread::Builder::new()
            .name("funcsne-stepper".to_string())
            .spawn(move || run_loop(rx, max_sessions))
            .expect("spawn stepper thread");
        Stepper { tx, join: Some(join) }
    }

    /// A cloneable sender for request handlers (one per HTTP worker).
    pub fn sender(&self) -> Sender<StepperRequest> {
        self.tx.clone()
    }

    /// Stop the loop and join the thread (also what `Drop` does).
    pub fn shutdown(self) {
        // Drop impl does the work.
    }
}

impl Drop for Stepper {
    fn drop(&mut self) {
        let _ = self.tx.send(StepperRequest::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Side-table entries the manager doesn't know about.
struct SessionMeta {
    max_iters: usize,
    /// The budget fires **once**: after the auto-pause, an explicit
    /// `resume` command is an override and the session runs unbounded
    /// (otherwise resume would be silently re-paused every sweep).
    budget_fired: bool,
    last_error: Option<String>,
}

struct Service {
    mgr: SessionManager,
    meta: BTreeMap<u64, SessionMeta>,
    max_sessions: usize,
    sweeps: u64,
    steps: u64,
    step_failures: u64,
    commands_queued: u64,
    sessions_created: u64,
    sessions_deleted: u64,
}

fn run_loop(rx: Receiver<StepperRequest>, max_sessions: usize) {
    let mut svc = Service {
        mgr: SessionManager::new(),
        meta: BTreeMap::new(),
        max_sessions,
        sweeps: 0,
        steps: 0,
        step_failures: 0,
        commands_queued: 0,
        sessions_created: 0,
        sessions_deleted: 0,
    };
    loop {
        // 1. Drain every pending request: client latency is bounded by
        //    one sweep, and bursts don't queue behind stepping.
        loop {
            match rx.try_recv() {
                Ok(StepperRequest::Shutdown) => return,
                Ok(req) => svc.handle(req),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return,
            }
        }
        // 2. One fair sweep over every live session.
        let outcome = svc.mgr.step_all_detailed();
        svc.sweeps += 1;
        svc.steps += outcome.stepped as u64;
        for (id, err) in &outcome.failed {
            svc.step_failures += 1;
            if let Some(meta) = svc.meta.get_mut(&id.0) {
                meta.last_error = Some(err.clone());
            }
        }
        // A session that is unpaused and absent from `failed` stepped
        // cleanly this sweep — a recorded error is stale, clear it
        // (e.g. the client fixed the cause and sent `resume`).
        for (id, meta) in svc.meta.iter_mut() {
            if meta.last_error.is_some()
                && !outcome.failed.iter().any(|(fid, _)| fid.0 == *id)
                && svc.mgr.get(SessionId(*id)).is_some_and(|s| !s.is_paused())
            {
                meta.last_error = None;
            }
        }
        // 3. Enforce per-session iteration budgets.
        svc.enforce_budgets();
        // 4. Nothing running? Block briefly instead of spinning.
        if outcome.stepped == 0 {
            match rx.recv_timeout(IDLE_WAIT) {
                Ok(StepperRequest::Shutdown) => return,
                Ok(req) => svc.handle(req),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    }
}

impl Service {
    fn handle(&mut self, req: StepperRequest) {
        match req {
            StepperRequest::Create(spec, reply) => {
                let _ = reply.send(self.create(*spec));
            }
            StepperRequest::Enqueue(id, command, reply) => {
                let result = match self.mgr.enqueue(SessionId(id), command) {
                    Ok(()) => {
                        self.commands_queued += 1;
                        Ok(())
                    }
                    Err(e) => Err(ServiceError::NotFound(e.to_string())),
                };
                let _ = reply.send(result);
            }
            StepperRequest::Embedding(id, iter, reply) => {
                let _ = reply.send(self.embedding(id, iter));
            }
            StepperRequest::Stats(id, reply) => {
                let result = match self.mgr.get(SessionId(id)) {
                    Some(session) => Ok(self.view(id, session)),
                    None => Err(not_found(id)),
                };
                let _ = reply.send(result);
            }
            StepperRequest::List(reply) => {
                let views: Vec<SessionView> = self
                    .mgr
                    .ids()
                    .into_iter()
                    .filter_map(|sid| self.mgr.get(sid).map(|s| self.view(sid.0, s)))
                    .collect();
                let _ = reply.send(views);
            }
            StepperRequest::Delete(id, reply) => {
                let result = match self.mgr.remove(SessionId(id)) {
                    Some(_) => {
                        self.meta.remove(&id);
                        self.sessions_deleted += 1;
                        Ok(())
                    }
                    None => Err(not_found(id)),
                };
                let _ = reply.send(result);
            }
            StepperRequest::Metrics(reply) => {
                let _ = reply.send(self.metrics());
            }
            StepperRequest::Shutdown => unreachable!("handled by the loop"),
        }
    }

    fn create(&mut self, spec: CreateSpec) -> ServiceResult<SessionView> {
        if self.mgr.len() >= self.max_sessions {
            return Err(ServiceError::Full(format!(
                "session limit reached ({} live, max {})",
                self.mgr.len(),
                self.max_sessions
            )));
        }
        let session = spec
            .builder
            .build()
            .map_err(|e| ServiceError::Invalid(format!("session build failed: {e:?}")))?;
        let sid = self.mgr.add(session);
        let meta =
            SessionMeta { max_iters: spec.max_iters, budget_fired: false, last_error: None };
        self.meta.insert(sid.0, meta);
        self.sessions_created += 1;
        let session = self.mgr.get(sid).expect("just inserted");
        Ok(self.view(sid.0, session))
    }

    fn embedding(&self, id: u64, iter: Option<usize>) -> ServiceResult<EmbeddingFrame> {
        let session = self.mgr.get(SessionId(id)).ok_or_else(|| not_found(id))?;
        match iter {
            None => {
                let y = session.embedding();
                Ok(EmbeddingFrame {
                    iter: session.iterations(),
                    n: y.n(),
                    d: y.d(),
                    data: y.data().to_vec(),
                    source: "live",
                })
            }
            Some(at) => match session.snapshots().at_or_before(at) {
                Some(snap) => Ok(EmbeddingFrame {
                    iter: snap.iter,
                    n: snap.y.n(),
                    d: snap.y.d(),
                    data: snap.y.data().to_vec(),
                    source: "snapshot",
                }),
                None => Err(ServiceError::NotFound(format!(
                    "no snapshot at or before iteration {at} for session {id} \
                     ({} held; was the session created with snapshot_stride > 0?)",
                    session.snapshots().len()
                ))),
            },
        }
    }

    fn view(&self, id: u64, session: &Session) -> SessionView {
        let cfg = session.config();
        let (applied, rejected) = session.command_counts();
        let meta = self.meta.get(&id);
        SessionView {
            id,
            iter: session.iterations(),
            n: session.n(),
            hd_dim: session.engine().x.d(),
            ld_dim: cfg.ld_dim,
            paused: session.is_paused(),
            queued: session.queued(),
            commands_applied: applied,
            commands_rejected: rejected,
            backend: session.backend_name(),
            alpha: cfg.alpha,
            perplexity: cfg.perplexity,
            attraction: cfg.attraction,
            repulsion: cfg.repulsion,
            snapshots_held: session.snapshots().len(),
            snapshots_total: session.snapshots().total_recorded(),
            max_iters: meta.map_or(0, |m| m.max_iters),
            last_error: meta.and_then(|m| m.last_error.clone()),
            quality: session.quality().copied(),
            phase_micros: session.stats().phase_micros,
        }
    }

    fn metrics(&self) -> ServiceMetrics {
        ServiceMetrics {
            sessions: self.mgr.len(),
            sweeps: self.sweeps,
            steps: self.steps,
            step_failures: self.step_failures,
            commands_queued: self.commands_queued,
            sessions_created: self.sessions_created,
            sessions_deleted: self.sessions_deleted,
            session_iters: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| self.mgr.get(sid).map(|s| (sid.0, s.iterations())))
                .collect(),
            session_quality: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| {
                    self.mgr.get(sid).and_then(|s| s.quality().copied().map(|q| (sid.0, q)))
                })
                .collect(),
            session_phase: self
                .mgr
                .ids()
                .into_iter()
                .filter_map(|sid| {
                    self.mgr.get(sid).map(|s| (sid.0, s.stats().phase_micros))
                })
                .collect(),
        }
    }

    fn enforce_budgets(&mut self) {
        for (id, meta) in self.meta.iter_mut() {
            if meta.max_iters == 0 || meta.budget_fired {
                continue;
            }
            if let Some(session) = self.mgr.get_mut(SessionId(*id)) {
                if !session.is_paused() && session.iterations() >= meta.max_iters {
                    session.force_pause();
                    meta.budget_fired = true;
                }
            }
        }
    }
}

fn not_found(id: u64) -> ServiceError {
    ServiceError::NotFound(format!("unknown session {id}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::session::Session;
    use std::time::Instant;

    fn spec(seed: u64, max_iters: usize) -> Box<CreateSpec> {
        let ds = datasets::blobs(80, 5, 3, 0.5, 8.0, seed);
        let builder = Session::builder()
            .dataset(ds.x)
            .k_hd(10)
            .k_ld(6)
            .perplexity(6.0)
            .jumpstart_iters(2)
            .snapshot_stride(4)
            .snapshot_capacity(8)
            .seed(seed);
        Box::new(CreateSpec { builder, max_iters })
    }

    fn ask<T>(
        tx: &Sender<StepperRequest>,
        make: impl FnOnce(Sender<ServiceResult<T>>) -> StepperRequest,
    ) -> ServiceResult<T> {
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(make(reply_tx)).expect("stepper alive");
        reply_rx.recv_timeout(Duration::from_secs(30)).expect("stepper reply")
    }

    fn wait_until<F: FnMut() -> bool>(mut cond: F, what: &str) {
        let deadline = Instant::now() + Duration::from_secs(60);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn stepper_steps_in_background_and_applies_commands() {
        let stepper = Stepper::spawn(8);
        let tx = stepper.sender();
        let view = ask(&tx, |r| StepperRequest::Create(spec(1, 0), r)).unwrap();
        assert_eq!(view.n, 80);
        assert_eq!(view.iter, 0);
        let id = view.id;

        // The background thread steps without any further requests.
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().iter >= 5,
            "background stepping",
        );

        // Mid-run hyperparameter change lands between iterations.
        ask(&tx, |r| StepperRequest::Enqueue(id, Command::SetAlpha(0.5), r)).unwrap();
        wait_until(
            || {
                let v = ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap();
                v.alpha == 0.5 && v.commands_applied >= 1
            },
            "alpha change to drain",
        );

        // Live embedding reflects the current iteration.
        let frame = ask(&tx, |r| StepperRequest::Embedding(id, None, r)).unwrap();
        assert_eq!((frame.n, frame.d), (80, 2));
        assert_eq!(frame.source, "live");
        assert_eq!(frame.data.len(), 160);

        // Snapshot lookup resolves to the nearest recorded frame.
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().snapshots_total >= 2,
            "snapshots to record",
        );
        let snap = ask(&tx, |r| StepperRequest::Embedding(id, Some(1_000_000), r)).unwrap();
        assert_eq!(snap.source, "snapshot");
        assert_eq!(snap.iter % 4, 0, "stride-4 snapshot");

        ask(&tx, |r| StepperRequest::Delete(id, r)).unwrap();
        let err = ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap_err();
        assert_eq!(err.status(), 404);
        stepper.shutdown();
    }

    #[test]
    fn max_iters_budget_auto_pauses() {
        let stepper = Stepper::spawn(8);
        let tx = stepper.sender();
        let id = ask(&tx, |r| StepperRequest::Create(spec(2, 6), r)).unwrap().id;
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().paused,
            "budget pause",
        );
        let v = ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap();
        assert!((6..=7).contains(&v.iter), "stopped at the budget, got {}", v.iter);
        // A budget-paused session still drains queued commands, so it
        // stays steerable (and resumable) — never deadlocked.
        ask(&tx, |r| StepperRequest::Enqueue(id, Command::SetRepulsion(1.5), r)).unwrap();
        wait_until(
            || ask(&tx, |r| StepperRequest::Stats(id, r)).unwrap().repulsion == 1.5,
            "command drain while paused",
        );
        stepper.shutdown();
    }

    #[test]
    fn session_capacity_is_enforced() {
        let stepper = Stepper::spawn(1);
        let tx = stepper.sender();
        ask(&tx, |r| StepperRequest::Create(spec(3, 0), r)).unwrap();
        let err = ask(&tx, |r| StepperRequest::Create(spec(4, 0), r)).unwrap_err();
        assert_eq!(err.status(), 429);
        stepper.shutdown();
    }

    #[test]
    fn invalid_spec_is_rejected_not_fatal() {
        let stepper = Stepper::spawn(4);
        let tx = stepper.sender();
        let bad = Box::new(CreateSpec {
            builder: Session::builder(), // no dataset
            max_iters: 0,
        });
        let err = ask(&tx, |r| StepperRequest::Create(bad, r)).unwrap_err();
        assert_eq!(err.status(), 400);
        // The loop survived; metrics still answer.
        let (mtx, mrx) = mpsc::channel();
        tx.send(StepperRequest::Metrics(mtx)).unwrap();
        let m = mrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(m.sessions, 0);
        stepper.shutdown();
    }
}
