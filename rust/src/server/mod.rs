//! The embedding service: a zero-dependency HTTP/1.1 + JSON layer over
//! the [`crate::session`] facade, turning the crate from a library
//! into a deployable server.
//!
//! The paper's headline feature is *interactive* neighbour embedding —
//! steering a running optimisation between any two iterations. The
//! session layer provides that in-process; this module puts it on the
//! wire so GUI/web frontends (and load generators) can create
//! sessions, change hyperparameters mid-run, stream embedding frames,
//! and tear sessions down, all over plain HTTP. Everything is `std`:
//! the listener ([`http`]), the JSON codec ([`json`]), the REST
//! routing ([`api`]) and the stepping thread ([`stepper`]).
//!
//! Architecture:
//!
//! ```text
//!        TcpListener (non-blocking)
//!        │  one connection-handler per WorkerPool slot
//!        ▼
//!   http::serve ── Api (per worker) ──┐ mpsc commands / replies
//!                                     ▼
//!                        stepper thread: owns SessionManager + FrameHub,
//!                        loops { drain requests; fair budgeted sweep;
//!                                broadcast frames; park when idle }
//! ```
//!
//! [`crate::session::Session`] is `!Send` by design, so sessions live
//! only on the stepper thread; HTTP workers exchange plain-data specs,
//! commands and frames with it over channels. Stepping therefore never
//! blocks on a slow client, and a client never observes a session
//! mid-iteration.
//!
//! # Running as a service
//!
//! ```sh
//! funcsne serve --addr 127.0.0.1:7878 --threads 4 --max-sessions 64
//! ```
//!
//! ```sh
//! # create a session from inline rows (or {"path": "data.npy"|"data.csv"})
//! curl -s -X POST localhost:7878/sessions \
//!      -d '{"rows": [[0,1],[1,0],[1,1],[0,0]], "perplexity": 3, "k_hd": 3}'
//! # steer it mid-run
//! curl -s -X POST localhost:7878/sessions/0/commands \
//!      -d '{"command": "set_alpha", "value": 0.5}'
//! # fetch the live embedding, or the nearest snapshot ≤ iteration 500
//! curl -s localhost:7878/sessions/0/embedding
//! curl -s 'localhost:7878/sessions/0/embedding?iter=500'
//! # push: a chunked stream of compact binary frames (docs/wire-format.md)
//! curl -sN localhost:7878/sessions/0/stream -o frames.bin
//! curl -s localhost:7878/sessions/0/stats
//! curl -s localhost:7878/healthz
//! curl -s localhost:7878/metrics     # Prometheus text format
//! curl -s -X DELETE localhost:7878/sessions/0
//! ```

pub mod api;
pub mod frames;
pub mod http;
pub mod json;
pub mod stepper;

pub use api::Api;
pub use frames::StreamConfig;
pub use http::{Request, Response};
pub use json::Json;
pub use stepper::{DurabilityConfig, ServiceError, Stepper, StepperRequest};

use crate::coordinator::driver::default_artifact_dir;
use crate::obs::Obs;
use crate::runtime::WorkerPool;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Service configuration (the CLI `serve` flags).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`Server::local_addr`]).
    pub addr: String,
    /// HTTP worker slots (0 = auto-detect hardware parallelism).
    pub threads: usize,
    /// Maximum concurrent sessions; creates beyond it get HTTP 429.
    pub max_sessions: usize,
    /// Default snapshot stride for sessions that don't specify one
    /// (how often `GET ...?iter=` history is recorded).
    pub snapshot_every: usize,
    /// Maximum concurrent stream subscribers across all sessions;
    /// subscribes beyond it get HTTP 429. Note each streaming client
    /// also pins one HTTP worker slot for the stream's lifetime.
    pub max_streams: usize,
    /// Maximum concurrent stream subscribers on one session.
    pub max_streams_per_session: usize,
    /// Per-subscriber frame queue bound (frames beyond it are dropped
    /// and the client resyncs via keyframe).
    pub stream_queue: usize,
    /// Emit a stream keyframe after this many delta frames.
    pub keyframe_every: usize,
    /// Enable observability: latency histograms on `/metrics`, span
    /// tracing on `GET /debug/trace`, per-phase latency quantiles in
    /// stats JSON. Defaults to the `FUNCSNE_TRACE` env var; off keeps
    /// the hot path free of clock reads.
    pub trace: bool,
    /// Durable sessions: persist every session under this directory
    /// (snapshot + write-ahead command log) and restore them at boot.
    /// `None` (default) keeps sessions purely in-memory.
    pub state_dir: Option<PathBuf>,
    /// Checkpoint a running durable session after this many
    /// iterations of progress (0 = only on pause/delete/shutdown and
    /// explicit `POST .../checkpoint`). Ignored without `state_dir`.
    pub checkpoint_every: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        let streams = StreamConfig::default();
        ServerConfig {
            addr: "127.0.0.1:7878".to_string(),
            threads: 4,
            max_sessions: 64,
            snapshot_every: 25,
            max_streams: streams.max_global,
            max_streams_per_session: streams.max_per_session,
            stream_queue: streams.queue_frames,
            keyframe_every: streams.keyframe_every,
            trace: Obs::env_enabled(),
            state_dir: None,
            checkpoint_every: 500,
        }
    }
}

/// A bound (but not yet serving) embedding service.
///
/// [`Server::bind`] reserves the port and spawns the stepper thread;
/// [`Server::run`] blocks serving requests until a [`ServerHandle`]
/// fires. Tests and embedders run `run()` on a spawned thread.
pub struct Server {
    listener: TcpListener,
    local_addr: SocketAddr,
    stepper: Stepper,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
    http_requests: Arc<AtomicU64>,
    obs: Arc<Obs>,
}

impl Server {
    /// Bind the listener and spawn the stepping thread.
    pub fn bind(cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        // Non-blocking accept lets workers poll the shutdown flag; the
        // accepted streams are switched back to blocking mode.
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let local_addr = listener.local_addr().context("local_addr")?;
        let streams = StreamConfig {
            max_per_session: cfg.max_streams_per_session.max(1),
            max_global: cfg.max_streams.max(1),
            queue_frames: cfg.stream_queue.max(1),
            keyframe_every: cfg.keyframe_every.max(1),
        };
        let obs = Arc::new(Obs::new(cfg.trace));
        let durability = match &cfg.state_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create state dir {}", dir.display()))?;
                Some(DurabilityConfig {
                    state_dir: dir.clone(),
                    checkpoint_every: cfg.checkpoint_every,
                    artifact_dir: default_artifact_dir(),
                })
            }
            None => None,
        };
        let stepper =
            Stepper::spawn_with(cfg.max_sessions.max(1), streams, Arc::clone(&obs), durability)
                .context("spawn stepper")?;
        Ok(Server {
            listener,
            local_addr,
            stepper,
            cfg,
            shutdown: Arc::new(AtomicBool::new(false)),
            http_requests: Arc::new(AtomicU64::new(0)),
            obs,
        })
    }

    /// The shared observability registry (for embedders and benches
    /// that want histogram snapshots without scraping `/metrics`).
    pub fn obs(&self) -> Arc<Obs> {
        Arc::clone(&self.obs)
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A handle that can stop [`Server::run`] from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shutdown: Arc::clone(&self.shutdown) }
    }

    /// Serve until the [`ServerHandle`] fires: one connection-handler
    /// per worker slot, all feeding the stepper thread. Joins the
    /// stepper on the way out.
    pub fn run(self) -> Result<()> {
        let slots = WorkerPool::with_auto(self.cfg.threads).threads();
        let handlers: Vec<Api> = (0..slots)
            .map(|worker| {
                Api::new(
                    self.stepper.sender(),
                    Arc::clone(&self.http_requests),
                    self.cfg.snapshot_every,
                    Arc::clone(&self.obs),
                    worker,
                )
            })
            .collect();
        http::serve(&self.listener, &self.shutdown, handlers);
        self.stepper.shutdown();
        Ok(())
    }
}

/// Stops a running [`Server`]; cheap to clone across threads.
#[derive(Clone)]
pub struct ServerHandle {
    shutdown: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Ask the server to stop. Workers notice within their poll
    /// interval (~10 ms); `Server::run` then joins the stepper and
    /// returns.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}
