//! Binary frame codec: pixel-quantized keyframes and per-point deltas.
//!
//! The insight (borrowed from PixelSNE, see PAPERS.md) is that a
//! *visual* consumer of an embedding never needs f32 precision — a
//! screen has at most a few thousand pixels per axis, so 16 bits of
//! fixed-point per coordinate on a per-frame bounding grid is already
//! ~30× finer than any display. Quantizing to `u16` shrinks a 2-D
//! point from 8 bytes (2×f32) to 4 and, more importantly, makes
//! "did this point move?" a well-posed integer question: a delta frame
//! ships only the points whose quantized cell changed, which late in an
//! embedding run is a small fraction of `n`.
//!
//! # Wire format (version 1, all integers little-endian)
//!
//! ```text
//! offset  size  field
//!      0     4  magic  b"FSNE"
//!      4     1  version  (1)
//!      5     1  flags    bit0: 1 = keyframe, 0 = delta
//!      6     2  d        u16  LD dimensionality
//!      8     4  n        u32  points in the embedding
//!     12     4  changed  u32  records in the payload (= n for keyframes)
//!     16     8  iter     u64  iteration this frame depicts
//!     24     8  base_iter u64 keyframe: == iter; delta: iter of the
//!                             immediately preceding frame in the stream
//!     32   8·d  bbox     d × (min f32, max f32) quantization grid
//! 32+8d     …  payload
//! ```
//!
//! Keyframe payload: `n·d` u16 coordinates, point-major. Delta
//! payload: `changed` records of (u32 point index, `d` u16 coords).
//! A decoder can therefore start at any keyframe and fold deltas
//! forward as long as `base_iter` chains and the bbox is unchanged;
//! anything else (resize, rescale, gap) forces a keyframe, which the
//! encoder emits on its own for exactly those events.

use crate::data::Matrix;

/// Wire magic — first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"FSNE";
/// Current wire version.
pub const VERSION: u8 = 1;
/// Flags bit 0: set for keyframes, clear for deltas.
pub const FLAG_KEYFRAME: u8 = 1;
/// Header length before the bbox: magic..=base_iter.
pub const FIXED_HEADER: usize = 32;
/// Fraction of the data extent padded onto each bbox side so points
/// can drift a little between keyframes without leaving the grid.
const BBOX_PAD: f32 = 0.05;

fn header_len(d: usize) -> usize {
    FIXED_HEADER + 8 * d
}

/// One axis of the quantization grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Axis {
    pub min: f32,
    pub max: f32,
}

impl Axis {
    /// f32 → u16 on this axis. Degenerate axes (max ≤ min) collapse to
    /// cell 0 so a constant coordinate round-trips to its own value.
    ///
    /// Non-finite input clamps deterministically: NaN and −Inf map to
    /// cell 0 (the bbox minimum after [`Axis::dequantize`]), +Inf to
    /// cell 65535 (the bbox maximum). Together with `fit_bbox` fitting
    /// over finite values only and `any_outside_bbox` ignoring
    /// non-finite values, this is the codec's whole non-finite policy:
    /// a blown-up point pins to a bbox edge, every finite point keeps
    /// its precision, and the decoder can trust any frame the encoder
    /// produced.
    pub fn quantize(&self, v: f32) -> u16 {
        let span = self.max - self.min;
        if !(span > 0.0) {
            return 0;
        }
        let t = (v - self.min) / span * 65535.0;
        if !(t > 0.0) {
            0
        } else if t >= 65535.0 {
            65535
        } else {
            (t + 0.5) as u16
        }
    }

    /// u16 → f32 (cell centre by construction of [`Axis::quantize`]).
    pub fn dequantize(&self, q: u16) -> f32 {
        let span = self.max - self.min;
        if !(span > 0.0) {
            return self.min;
        }
        self.min + f32::from(q) / 65535.0 * span
    }

    /// Width of one grid cell (the quantization error bound is half
    /// of this).
    pub fn cell(&self) -> f32 {
        let span = self.max - self.min;
        if span > 0.0 {
            span / 65535.0
        } else {
            0.0
        }
    }
}

/// A decoded frame header + payload, as parsed by [`decode`].
#[derive(Clone, Debug)]
pub struct Frame {
    pub keyframe: bool,
    pub d: usize,
    pub n: usize,
    pub iter: u64,
    pub base_iter: u64,
    pub bbox: Vec<Axis>,
    /// Keyframe: empty. Delta: the changed point indices, ascending.
    pub indices: Vec<u32>,
    /// Quantized coords: keyframe `n·d`, delta `indices.len()·d`.
    pub coords: Vec<u16>,
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn get_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn get_u64(b: &[u8], at: usize) -> u64 {
    let mut raw = [0u8; 8];
    raw.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(raw)
}

fn get_f32(b: &[u8], at: usize) -> f32 {
    f32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

fn write_header(
    buf: &mut Vec<u8>,
    keyframe: bool,
    d: usize,
    n: usize,
    changed: usize,
    iter: u64,
    base_iter: u64,
    bbox: &[Axis],
) {
    buf.extend_from_slice(&MAGIC);
    buf.push(VERSION);
    buf.push(if keyframe { FLAG_KEYFRAME } else { 0 });
    put_u16(buf, d as u16);
    put_u32(buf, n as u32);
    put_u32(buf, changed as u32);
    put_u64(buf, iter);
    put_u64(buf, base_iter);
    for ax in bbox {
        put_f32(buf, ax.min);
        put_f32(buf, ax.max);
    }
}

/// Parse and validate one frame. Rejects wrong magic/version, truncated
/// or oversized buffers, non-finite or inverted bboxes, and delta
/// indices out of `0..n`.
pub fn decode(bytes: &[u8]) -> Result<Frame, String> {
    if bytes.len() < FIXED_HEADER {
        return Err(format!("frame truncated: {} bytes < {FIXED_HEADER}-byte header", bytes.len()));
    }
    if bytes[0..4] != MAGIC {
        return Err("bad magic (not an FSNE frame)".into());
    }
    if bytes[4] != VERSION {
        return Err(format!("unsupported frame version {}", bytes[4]));
    }
    let flags = bytes[5];
    let keyframe = flags & FLAG_KEYFRAME != 0;
    let d = get_u16(bytes, 6) as usize;
    let n = get_u32(bytes, 8) as usize;
    let changed = get_u32(bytes, 12) as usize;
    let iter = get_u64(bytes, 16);
    let base_iter = get_u64(bytes, 24);
    if d == 0 {
        return Err("frame has d = 0".into());
    }
    let hdr = header_len(d);
    if bytes.len() < hdr {
        return Err(format!("frame truncated: {} bytes < {hdr}-byte header (d = {d})", bytes.len()));
    }
    let mut bbox = Vec::with_capacity(d);
    for axis in 0..d {
        let min = get_f32(bytes, FIXED_HEADER + 8 * axis);
        let max = get_f32(bytes, FIXED_HEADER + 8 * axis + 4);
        if !min.is_finite() || !max.is_finite() || min > max {
            return Err(format!("axis {axis} bbox invalid: [{min}, {max}]"));
        }
        bbox.push(Axis { min, max });
    }
    let payload = &bytes[hdr..];
    if keyframe {
        if changed != n {
            return Err(format!("keyframe changed = {changed} but n = {n}"));
        }
        let want = n * d * 2;
        if payload.len() != want {
            return Err(format!("keyframe payload {} bytes, expected {want}", payload.len()));
        }
        if base_iter != iter {
            return Err(format!("keyframe base_iter {base_iter} != iter {iter}"));
        }
        let mut coords = Vec::with_capacity(n * d);
        for p in 0..n * d {
            coords.push(get_u16(payload, 2 * p));
        }
        Ok(Frame { keyframe, d, n, iter, base_iter, bbox, indices: Vec::new(), coords })
    } else {
        if changed > n {
            return Err(format!("delta changed = {changed} exceeds n = {n}"));
        }
        let record = 4 + 2 * d;
        let want = changed * record;
        if payload.len() != want {
            return Err(format!("delta payload {} bytes, expected {want}", payload.len()));
        }
        let mut indices = Vec::with_capacity(changed);
        let mut coords = Vec::with_capacity(changed * d);
        for r in 0..changed {
            let at = r * record;
            let idx = get_u32(payload, at);
            if idx as usize >= n {
                return Err(format!("delta index {idx} out of range (n = {n})"));
            }
            indices.push(idx);
            for axis in 0..d {
                coords.push(get_u16(payload, at + 4 + 2 * axis));
            }
        }
        Ok(Frame { keyframe, d, n, iter, base_iter, bbox, indices, coords })
    }
}

/// Stateful encoder: one per streamed session. Decides keyframe vs
/// delta, owns the quantization grid, and emits ready-to-send frames.
pub struct FrameEncoder {
    /// Emit a keyframe after this many consecutive deltas (resync
    /// bound for late joiners and lossy subscribers).
    keyframe_every: usize,
    deltas_since_key: usize,
    force_key: bool,
    started: bool,
    n: usize,
    d: usize,
    structure_version: u64,
    last_iter: u64,
    bbox: Vec<Axis>,
    /// Quantized coordinates of the last emitted frame, `n·d`.
    grid: Vec<u16>,
}

impl FrameEncoder {
    pub fn new(keyframe_every: usize) -> FrameEncoder {
        FrameEncoder {
            keyframe_every: keyframe_every.max(1),
            deltas_since_key: 0,
            force_key: true,
            started: false,
            n: 0,
            d: 0,
            structure_version: 0,
            last_iter: 0,
            bbox: Vec::new(),
            grid: Vec::new(),
        }
    }

    /// Make the next [`FrameEncoder::encode`] emit a keyframe
    /// unconditionally (used to resync lagged subscribers — the
    /// keyframe goes to *everyone*, keeping the shared byte sequence
    /// identical across clients).
    pub fn force_keyframe(&mut self) {
        self.force_key = true;
    }

    /// Would `encode(iter, …)` produce a new frame? False only when the
    /// stream is caught up: same iteration as the last frame and no
    /// pending resync.
    pub fn should_emit(&self, iter: u64) -> bool {
        !self.started || self.force_key || iter != self.last_iter
    }

    /// Encode the embedding at `iter` into a frame, or `None` when
    /// nothing changed ([`FrameEncoder::should_emit`] is false, or a
    /// delta would carry zero moved points).
    pub fn encode(&mut self, iter: u64, y: &Matrix, structure_version: u64) -> Option<Vec<u8>> {
        if !self.should_emit(iter) && structure_version == self.structure_version {
            return None;
        }
        let (n, d) = (y.n(), y.d());
        if n == 0 || d == 0 || d > usize::from(u16::MAX) {
            return None;
        }
        let key = self.force_key
            || !self.started
            || n != self.n
            || d != self.d
            || structure_version != self.structure_version
            || self.deltas_since_key >= self.keyframe_every
            || self.any_outside_bbox(y);
        if key {
            Some(self.encode_keyframe(iter, y, structure_version))
        } else {
            self.encode_delta(iter, y)
        }
    }

    fn any_outside_bbox(&self, y: &Matrix) -> bool {
        debug_assert_eq!(self.bbox.len(), y.d());
        for row in 0..y.n() {
            let p = y.row(row);
            for (axis, &v) in self.bbox.iter().zip(p) {
                if !v.is_finite() {
                    // Non-finite coordinates quantize to a deterministic
                    // clamp (NaN/−Inf → cell 0, +Inf → cell 65535) inside
                    // *any* grid, so they can never justify a reframe —
                    // and `fit_bbox` ignores them anyway, so reframing
                    // would produce the same bbox. Treating them as
                    // "outside" here used to force a keyframe on every
                    // frame while a single NaN point existed, silently
                    // killing delta compression for the whole stream.
                    continue;
                }
                if v < axis.min || v > axis.max {
                    return true;
                }
            }
        }
        false
    }

    fn fit_bbox(y: &Matrix) -> Vec<Axis> {
        let d = y.d();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for row in 0..y.n() {
            for (axis, &v) in y.row(row).iter().enumerate() {
                if v.is_finite() {
                    lo[axis] = lo[axis].min(v);
                    hi[axis] = hi[axis].max(v);
                }
            }
        }
        (0..d)
            .map(|axis| {
                let (mut min, mut max) = (lo[axis], hi[axis]);
                if !min.is_finite() || !max.is_finite() || min > max {
                    // No finite data on this axis — any grid will do.
                    return Axis { min: 0.0, max: 1.0 };
                }
                // Pad so inter-keyframe drift stays inside the grid;
                // the epsilon keeps degenerate (constant) axes usable.
                let pad = (max - min) * BBOX_PAD + 1e-6;
                min -= pad;
                max += pad;
                Axis { min, max }
            })
            .collect()
    }

    fn encode_keyframe(&mut self, iter: u64, y: &Matrix, structure_version: u64) -> Vec<u8> {
        let (n, d) = (y.n(), y.d());
        self.bbox = FrameEncoder::fit_bbox(y);
        self.grid.clear();
        self.grid.reserve(n * d);
        for row in 0..y.n() {
            for (axis, &v) in self.bbox.iter().zip(y.row(row)) {
                self.grid.push(axis.quantize(v));
            }
        }
        let mut buf = Vec::with_capacity(header_len(d) + n * d * 2);
        write_header(&mut buf, true, d, n, n, iter, iter, &self.bbox);
        for &q in &self.grid {
            put_u16(&mut buf, q);
        }
        self.n = n;
        self.d = d;
        self.structure_version = structure_version;
        self.last_iter = iter;
        self.started = true;
        self.force_key = false;
        self.deltas_since_key = 0;
        buf
    }

    fn encode_delta(&mut self, iter: u64, y: &Matrix) -> Option<Vec<u8>> {
        let (n, d) = (self.n, self.d);
        let mut fresh = Vec::with_capacity(n * d);
        for row in 0..y.n() {
            for (axis, &v) in self.bbox.iter().zip(y.row(row)) {
                fresh.push(axis.quantize(v));
            }
        }
        let mut changed: Vec<u32> = Vec::new();
        for row in 0..n {
            if fresh[row * d..(row + 1) * d] != self.grid[row * d..(row + 1) * d] {
                changed.push(row as u32);
            }
        }
        // A delta bigger than the keyframe it replaces is pointless —
        // reset the grid too while we're at it.
        if changed.len() * (4 + 2 * d) >= n * 2 * d {
            self.force_key = true;
            let sv = self.structure_version;
            return Some(self.encode_keyframe(iter, y, sv));
        }
        if changed.is_empty() {
            // Nothing moved a whole grid cell: no frame. `last_iter`
            // stays at the last *emitted* frame so the next delta's
            // base_iter matches what subscribers actually received.
            return None;
        }
        let base_iter = self.last_iter;
        self.grid = fresh;
        self.last_iter = iter;
        self.deltas_since_key += 1;
        let mut buf = Vec::with_capacity(header_len(d) + changed.len() * (4 + 2 * d));
        write_header(&mut buf, false, d, n, changed.len(), iter, base_iter, &self.bbox);
        for &idx in &changed {
            put_u32(&mut buf, idx);
            let at = idx as usize * d;
            for axis in 0..d {
                put_u16(&mut buf, self.grid[at + axis]);
            }
        }
        Some(buf)
    }
}

/// Stateful decoder: folds a keyframe + delta sequence back into f32
/// coordinates. The mirror of [`FrameEncoder`] for clients and tests.
#[derive(Default)]
pub struct FrameDecoder {
    started: bool,
    n: usize,
    d: usize,
    iter: u64,
    bbox: Vec<Axis>,
    grid: Vec<u16>,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Has a keyframe arrived yet?
    pub fn ready(&self) -> bool {
        self.started
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Iteration of the last applied frame.
    pub fn iter(&self) -> u64 {
        self.iter
    }

    /// Fold one decoded frame into the running state. Deltas must chain
    /// (`base_iter` equals the last applied frame's iter, same n/d/bbox)
    /// — a broken chain means the caller lost frames and should wait
    /// for the next keyframe.
    pub fn apply(&mut self, frame: &Frame) -> Result<(), String> {
        if frame.keyframe {
            self.n = frame.n;
            self.d = frame.d;
            self.iter = frame.iter;
            self.bbox = frame.bbox.clone();
            self.grid = frame.coords.clone();
            self.started = true;
            return Ok(());
        }
        if !self.started {
            return Err("delta before any keyframe".into());
        }
        if frame.n != self.n || frame.d != self.d {
            return Err(format!(
                "delta shape {}x{} does not match state {}x{}",
                frame.n, frame.d, self.n, self.d
            ));
        }
        if frame.base_iter != self.iter {
            return Err(format!(
                "delta base_iter {} does not chain from state iter {}",
                frame.base_iter, self.iter
            ));
        }
        if frame.bbox != self.bbox {
            return Err("delta bbox differs from keyframe bbox".into());
        }
        for (r, &idx) in frame.indices.iter().enumerate() {
            let at = idx as usize * self.d;
            self.grid[at..at + self.d]
                .copy_from_slice(&frame.coords[r * self.d..(r + 1) * self.d]);
        }
        self.iter = frame.iter;
        Ok(())
    }

    /// Dequantized coordinates, `n·d` row-major.
    pub fn coords(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n * self.d);
        for row in 0..self.n {
            for (axis, ax) in self.bbox.iter().enumerate() {
                out.push(ax.dequantize(self.grid[row * self.d + axis]));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.row_mut(r)[c] = f(r, c);
            }
        }
        m
    }

    #[test]
    fn keyframe_round_trips_within_half_cell() {
        let y = matrix(50, 2, |r, c| (r as f32).mul_add(0.37, c as f32 * 5.0) - 9.0);
        let mut enc = FrameEncoder::new(30);
        let bytes = enc.encode(3, &y, 0).expect("first frame is a keyframe");
        let frame = decode(&bytes).unwrap();
        assert!(frame.keyframe);
        assert_eq!((frame.n, frame.d, frame.iter), (50, 2, 3));
        let mut dec = FrameDecoder::new();
        dec.apply(&frame).unwrap();
        let coords = dec.coords();
        for r in 0..50 {
            for c in 0..2 {
                let err = (coords[r * 2 + c] - y.row(r)[c]).abs();
                let cell = frame.bbox[c].cell();
                assert!(err <= cell * 0.5 + 1e-6, "err {err} > half cell {cell} at ({r},{c})");
            }
        }
    }

    #[test]
    fn unchanged_embedding_emits_nothing() {
        let y = matrix(20, 2, |r, c| r as f32 + c as f32);
        let mut enc = FrameEncoder::new(30);
        assert!(enc.encode(1, &y, 0).is_some());
        assert!(enc.encode(1, &y, 0).is_none(), "same iter, no resync → no frame");
        assert!(enc.encode(2, &y, 0).is_none(), "new iter but nothing moved a cell");
    }

    #[test]
    fn small_motion_yields_small_delta() {
        let mut y = matrix(100, 2, |r, c| (r * 2 + c) as f32);
        let mut enc = FrameEncoder::new(30);
        enc.encode(1, &y, 0).unwrap();
        // Move exactly one point far enough to cross many cells.
        y.row_mut(7)[0] += 3.0;
        let bytes = enc.encode(2, &y, 0).expect("one moved point → delta");
        let frame = decode(&bytes).unwrap();
        assert!(!frame.keyframe);
        assert_eq!(frame.indices, vec![7]);
        assert_eq!(frame.base_iter, 1);
        assert_eq!(frame.iter, 2);
    }

    #[test]
    fn structure_version_change_forces_keyframe() {
        let mut y = matrix(30, 2, |r, c| (r + c) as f32);
        let mut enc = FrameEncoder::new(1000);
        enc.encode(1, &y, 0).unwrap();
        y.row_mut(3)[1] += 2.0;
        let bytes = enc.encode(2, &y, 1).unwrap();
        assert!(decode(&bytes).unwrap().keyframe, "structural epoch bump must resync");
    }

    #[test]
    fn keyframe_interval_is_honoured() {
        let mut y = matrix(40, 2, |r, c| (r * 3 + c) as f32);
        let mut enc = FrameEncoder::new(2);
        enc.encode(0, &y, 0).unwrap();
        let mut kinds = Vec::new();
        for it in 1..=6u64 {
            y.row_mut((it as usize) % 40)[0] += 5.0;
            if let Some(bytes) = enc.encode(it, &y, 0) {
                kinds.push(decode(&bytes).unwrap().keyframe);
            }
        }
        // Two deltas, then a keyframe, repeating.
        assert_eq!(kinds, vec![false, false, true, false, false, true]);
    }

    #[test]
    fn decode_rejects_garbage() {
        let y = matrix(10, 2, |r, c| (r + c) as f32);
        let mut enc = FrameEncoder::new(30);
        let good = enc.encode(1, &y, 0).unwrap();
        assert!(decode(&[]).is_err());
        assert!(decode(&good[..10]).is_err(), "truncated header");
        assert!(decode(&good[..good.len() - 1]).is_err(), "truncated payload");
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(decode(&bad).is_err(), "bad magic");
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(decode(&bad).is_err(), "future version");
    }

    #[test]
    fn nan_point_does_not_poison_finite_points() {
        // One NaN coordinate: the bbox must fit the finite data, the
        // finite points must keep full precision, and the NaN pins
        // deterministically to the axis minimum.
        let mut y = matrix(50, 2, |r, c| (r as f32) * 0.5 + c as f32);
        y.row_mut(13)[0] = f32::NAN;
        let mut enc = FrameEncoder::new(30);
        let frame = decode(&enc.encode(0, &y, 0).unwrap()).unwrap();
        assert!(frame.keyframe);
        for axis in &frame.bbox {
            assert!(axis.min.is_finite() && axis.max.is_finite() && axis.min < axis.max);
        }
        let mut dec = FrameDecoder::new();
        dec.apply(&frame).unwrap();
        let coords = dec.coords();
        for r in 0..50 {
            for c in 0..2 {
                if r == 13 && c == 0 {
                    assert_eq!(coords[r * 2 + c], frame.bbox[0].min, "NaN must pin to bbox min");
                    continue;
                }
                let err = (coords[r * 2 + c] - y.row(r)[c]).abs();
                assert!(err <= frame.bbox[c].cell() * 0.5 + 1e-6, "poisoned at ({r},{c})");
            }
        }
    }

    #[test]
    fn persistent_nan_still_allows_delta_frames() {
        // Regression: a point stuck at NaN used to read as "outside the
        // bbox" and force a keyframe on *every* encode, silently
        // disabling delta compression for the whole stream.
        let mut y = matrix(100, 2, |r, c| (r * 2 + c) as f32);
        y.row_mut(4)[1] = f32::NAN;
        let mut enc = FrameEncoder::new(30);
        assert!(decode(&enc.encode(0, &y, 0).unwrap()).unwrap().keyframe);
        y.row_mut(7)[0] += 3.0; // one finite point moves
        let frame = decode(&enc.encode(1, &y, 0).unwrap()).unwrap();
        assert!(!frame.keyframe, "a persistent NaN must not force keyframes");
        assert_eq!(frame.indices, vec![7]);
        // And a NaN that merely sits still emits nothing at all.
        assert!(enc.encode(2, &y, 0).is_none());
    }

    #[test]
    fn infinities_clamp_to_bbox_edges() {
        let mut y = matrix(20, 2, |r, c| (r + c) as f32);
        y.row_mut(3)[0] = f32::INFINITY;
        y.row_mut(5)[1] = f32::NEG_INFINITY;
        let mut enc = FrameEncoder::new(30);
        let frame = decode(&enc.encode(0, &y, 0).unwrap()).unwrap();
        let mut dec = FrameDecoder::new();
        dec.apply(&frame).unwrap();
        let coords = dec.coords();
        // +Inf lands in cell 65535, whose reconstruction is min + span —
        // within one rounding step of the axis max.
        let top = frame.bbox[0];
        assert!((coords[3 * 2] - top.max).abs() <= top.cell(), "+Inf pins to bbox max");
        assert!(coords[3 * 2].is_finite());
        assert_eq!(coords[5 * 2 + 1], frame.bbox[1].min, "−Inf pins to bbox min");
        // Neighbouring finite values stay accurate.
        let err = (coords[3 * 2 + 1] - y.row(3)[1]).abs();
        assert!(err <= frame.bbox[1].cell() * 0.5 + 1e-6);
    }

    #[test]
    fn all_non_finite_frame_encodes_and_decodes() {
        // Every coordinate non-finite: fit_bbox falls back to the unit
        // axis, everything pins to an edge, and decode still trusts the
        // frame instead of erroring out mid-stream.
        let y = matrix(6, 2, |r, c| {
            if (r + c) % 2 == 0 {
                f32::NAN
            } else {
                f32::INFINITY
            }
        });
        let mut enc = FrameEncoder::new(30);
        let frame = decode(&enc.encode(0, &y, 0).unwrap()).unwrap();
        assert!(frame.keyframe);
        let mut dec = FrameDecoder::new();
        dec.apply(&frame).unwrap();
        for (t, &v) in dec.coords().iter().enumerate() {
            assert!(v.is_finite(), "decoded coord {t} must be finite");
            let axis = &frame.bbox[t % 2];
            assert!(v == axis.min || v == axis.max, "coord {t} must pin to a bbox edge");
        }
    }

    #[test]
    fn degenerate_axis_round_trips() {
        // All points share x = 4: the axis is (near) degenerate but the
        // epsilon pad keeps the reconstruction at the right value.
        let y = matrix(8, 2, |r, c| if c == 0 { 4.0 } else { r as f32 });
        let mut enc = FrameEncoder::new(30);
        let frame = decode(&enc.encode(0, &y, 0).unwrap()).unwrap();
        let mut dec = FrameDecoder::new();
        dec.apply(&frame).unwrap();
        for r in 0..8 {
            assert!((dec.coords()[r * 2] - 4.0).abs() < 1e-3);
        }
    }
}
