//! Broadcast hub: fan frames out to subscribers with per-client
//! backpressure.
//!
//! The stepper thread owns the hub and calls [`FrameHub::broadcast`]
//! after each sweep; HTTP workers own [`StreamSubscription`]s and block
//! on [`StreamSubscription::next`] while writing chunked responses.
//! The two sides meet in a small `DebugMutex<VecDeque> + DebugCondvar`
//! pair per subscriber (the checked wrappers from
//! [`crate::runtime::sync`]: lock-order tracking in debug builds,
//! centralized poison recovery) — the only state that crosses
//! threads. All subscriber queues share one lock class, which the
//! order checker enforces is never nested. Frames are
//! encoded **once** per session per sweep into an `Arc<Vec<u8>>` and
//! shared by every subscriber, so fan-out cost is queue pushes, not
//! copies.
//!
//! # Backpressure
//!
//! Each subscriber has a bounded queue. When a slow client lets it
//! fill, the hub clears the whole queue (counting every dropped frame),
//! marks the subscriber *lagged*, and keeps dropping delta frames —
//! a delta is useless without its predecessors. The next keyframe
//! clears the lag and is enqueued, so every byte sequence a client
//! actually receives is decodable from its first keyframe. After a
//! broadcast leaves anyone lagged, the hub forces the session's encoder
//! to emit a keyframe next sweep: resync is bounded by one sweep, not
//! by the keyframe interval, and — because the keyframe goes to every
//! subscriber — healthy clients still see the exact same byte sequence
//! as each other.

use super::codec::FrameEncoder;
use crate::data::Matrix;
use crate::obs::Obs;
use crate::runtime::sync::{DebugCondvar, DebugMutex};
use crate::util::timer::PhaseClock;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Tunables for the streaming subsystem (wired from the server config
/// / CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    /// Max concurrent subscribers on one session.
    pub max_per_session: usize,
    /// Max concurrent subscribers across all sessions.
    pub max_global: usize,
    /// Per-subscriber queue bound, in frames.
    pub queue_frames: usize,
    /// Emit a keyframe after this many delta frames.
    pub keyframe_every: usize,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig { max_per_session: 8, max_global: 64, queue_frames: 8, keyframe_every: 30 }
    }
}

/// Queue state shared between the hub (producer) and one subscriber
/// (consumer).
struct QueueState {
    frames: VecFrames,
    /// Subscriber overflowed and is waiting for a keyframe to resync.
    lagged: bool,
    /// Set by either side on teardown (client gone / session deleted /
    /// server shutdown).
    closed: bool,
}

type VecFrames = std::collections::VecDeque<Arc<Vec<u8>>>;

struct Shared {
    state: DebugMutex<QueueState>,
    ready: DebugCondvar,
}

/// Lock class for every subscriber queue. One shared class is
/// deliberate: the order checker then guarantees no code path ever
/// holds two subscriber queues at once (the hub pushes to them
/// strictly one at a time).
const QUEUE_LOCK_CLASS: &str = "frames.subscriber_queue";

/// What [`StreamSubscription::next`] yielded.
pub enum NextFrame {
    /// A frame to forward to the client.
    Frame(Arc<Vec<u8>>),
    /// Nothing arrived within the timeout; poll again (lets the HTTP
    /// worker re-check server shutdown between waits).
    Idle,
    /// The stream is over: session deleted or hub dropped.
    Closed,
}

/// The consumer half of one stream: lives on an HTTP worker thread and
/// feeds a chunked response. Dropping it unsubscribes.
pub struct StreamSubscription {
    shared: Arc<Shared>,
}

impl StreamSubscription {
    /// Block up to `timeout` for the next frame.
    pub fn next(&mut self, timeout: Duration) -> NextFrame {
        let mut st = self.shared.state.lock();
        loop {
            if let Some(frame) = st.frames.pop_front() {
                return NextFrame::Frame(frame);
            }
            if st.closed {
                return NextFrame::Closed;
            }
            let (next, res) = self.shared.ready.wait_timeout(st, timeout);
            st = next;
            if res.timed_out() && st.frames.is_empty() && !st.closed {
                return NextFrame::Idle;
            }
        }
    }
}

impl Drop for StreamSubscription {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock();
        st.closed = true;
        st.frames.clear();
    }
}

/// A subscription *is* the byte source of a chunked HTTP response:
/// one frame per chunk.
impl crate::server::http::ChunkSource for StreamSubscription {
    fn next(&mut self, timeout: Duration) -> crate::server::http::NextChunk {
        match StreamSubscription::next(self, timeout) {
            NextFrame::Frame(bytes) => crate::server::http::NextChunk::Data(bytes),
            NextFrame::Idle => crate::server::http::NextChunk::Idle,
            NextFrame::Closed => crate::server::http::NextChunk::Closed,
        }
    }
}

/// The producer's handle on one subscriber.
struct SubscriberSlot {
    shared: Arc<Shared>,
}

/// What one [`SubscriberSlot::push`] did.
struct PushOutcome {
    /// Frames this subscriber lost (queued frames cleared on overflow
    /// plus the offered frame when it was skipped mid-lag).
    dropped: u64,
    /// The offered frame made it onto the queue.
    enqueued: bool,
    /// Subscriber is (still) waiting for a keyframe to resync.
    lagged: bool,
    /// Queue length right after the push (0 unless `enqueued`) — the
    /// depth signal behind the `funcsne_stream_queue_depth` histogram.
    depth: u64,
}

impl SubscriberSlot {
    fn is_closed(&self) -> bool {
        self.shared.state.lock().closed
    }

    fn close(&self) {
        let mut st = self.shared.state.lock();
        st.closed = true;
        self.shared.ready.notify_all();
    }

    /// Push one frame onto this subscriber's queue, applying the
    /// drop-oldest-then-resync policy.
    fn push(&self, frame: &Arc<Vec<u8>>, keyframe: bool, queue_frames: usize) -> PushOutcome {
        let mut st = self.shared.state.lock();
        if st.closed {
            return PushOutcome { dropped: 0, enqueued: false, lagged: false, depth: 0 };
        }
        let mut dropped = 0u64;
        if st.lagged {
            if !keyframe {
                // Deltas are useless mid-lag; count and skip.
                return PushOutcome { dropped: 1, enqueued: false, lagged: true, depth: 0 };
            }
            st.lagged = false;
        }
        if st.frames.len() >= queue_frames {
            // Overflow: drop everything queued and require a keyframe
            // to restart — a partial queue of deltas with a hole in the
            // middle could never be decoded anyway.
            dropped += st.frames.len() as u64;
            st.frames.clear();
            if !keyframe {
                st.lagged = true;
                self.shared.ready.notify_all();
                return PushOutcome {
                    dropped: dropped + 1,
                    enqueued: false,
                    lagged: true,
                    depth: 0,
                };
            }
        }
        st.frames.push_back(Arc::clone(frame));
        self.shared.ready.notify_all();
        PushOutcome { dropped, enqueued: true, lagged: false, depth: st.frames.len() as u64 }
    }
}

/// Per-session streaming state: the shared encoder plus the fan-out
/// list.
struct SessionHub {
    encoder: FrameEncoder,
    subscribers: Vec<SubscriberSlot>,
}

/// Owns every session's encoder and subscriber list. Lives on the
/// stepper thread; never crosses threads itself (only
/// [`StreamSubscription`]s do).
pub struct FrameHub {
    cfg: StreamConfig,
    sessions: BTreeMap<u64, SessionHub>,
    obs: Arc<Obs>,
    frames_sent: u64,
    frames_dropped: u64,
}

/// Why a subscribe was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubscribeError {
    /// This session is at `max_per_session`.
    SessionFull,
    /// The whole server is at `max_global`.
    GlobalFull,
}

impl FrameHub {
    /// `obs` receives frame encode time/size and subscriber queue
    /// depth (histogram-only — the hub never touches the trace ring,
    /// so recording is lock-free and safe under the queue mutex).
    pub fn new(cfg: StreamConfig, obs: Arc<Obs>) -> FrameHub {
        FrameHub { cfg, sessions: BTreeMap::new(), obs, frames_sent: 0, frames_dropped: 0 }
    }

    /// Frames enqueued to subscribers, ever.
    pub fn frames_sent(&self) -> u64 {
        self.frames_sent
    }

    /// Frames dropped by backpressure, ever.
    pub fn frames_dropped(&self) -> u64 {
        self.frames_dropped
    }

    /// Live subscriber count for one session.
    pub fn subscriber_count(&self, session: u64) -> usize {
        self.sessions.get(&session).map_or(0, |s| s.subscribers.len())
    }

    /// Live subscriber count across all sessions.
    pub fn total_subscribers(&self) -> usize {
        self.sessions.values().map(|s| s.subscribers.len()).sum()
    }

    /// Per-session subscriber counts (for /metrics).
    pub fn subscriber_counts(&self) -> Vec<(u64, usize)> {
        self.sessions
            .iter()
            .filter(|(_, s)| !s.subscribers.is_empty())
            .map(|(&id, s)| (id, s.subscribers.len()))
            .collect()
    }

    /// Register a new subscriber on `session`. The caller must have
    /// checked the session exists. The next broadcast emits a keyframe
    /// so the new client can start decoding immediately.
    pub fn subscribe(&mut self, session: u64) -> Result<StreamSubscription, SubscribeError> {
        self.prune();
        if self.total_subscribers() >= self.cfg.max_global {
            return Err(SubscribeError::GlobalFull);
        }
        let hub = self.sessions.entry(session).or_insert_with(|| SessionHub {
            encoder: FrameEncoder::new(self.cfg.keyframe_every),
            subscribers: Vec::new(),
        });
        if hub.subscribers.len() >= self.cfg.max_per_session {
            return Err(SubscribeError::SessionFull);
        }
        let shared = Arc::new(Shared {
            state: DebugMutex::new(
                QUEUE_LOCK_CLASS,
                QueueState { frames: VecFrames::new(), lagged: false, closed: false },
            ),
            ready: DebugCondvar::new(),
        });
        hub.subscribers.push(SubscriberSlot { shared: Arc::clone(&shared) });
        hub.encoder.force_keyframe();
        Ok(StreamSubscription { shared })
    }

    /// Does this session have at least one live subscriber? (Cheap
    /// check the stepper uses to skip encoding entirely.)
    pub fn wants_frames(&self, session: u64) -> bool {
        self.sessions
            .get(&session)
            .is_some_and(|s| s.subscribers.iter().any(|c| !c.is_closed()))
    }

    /// Encode the embedding at `iter` (if it changed) and fan the frame
    /// out to this session's subscribers. Call after each sweep — and
    /// once on subscribe, so paused sessions still deliver a first
    /// keyframe.
    pub fn broadcast(&mut self, session: u64, iter: u64, y: &Matrix, structure_version: u64) {
        let queue_frames = self.cfg.queue_frames.max(1);
        let Some(hub) = self.sessions.get_mut(&session) else { return };
        hub.subscribers.retain(|c| !c.is_closed());
        if hub.subscribers.is_empty() {
            self.sessions.remove(&session);
            return;
        }
        let encode_clock = self.obs.enabled().then(PhaseClock::start);
        let Some(bytes) = hub.encoder.encode(iter, y, structure_version) else { return };
        if let Some(clock) = encode_clock {
            self.obs.record_frame(clock.elapsed_ns() / 1_000, bytes.len() as u64);
        }
        let keyframe = bytes.get(5).is_some_and(|f| f & super::codec::FLAG_KEYFRAME != 0);
        let frame = Arc::new(bytes);
        let mut any_lagged = false;
        for sub in &hub.subscribers {
            let out = sub.push(&frame, keyframe, queue_frames);
            self.frames_dropped += out.dropped;
            if out.enqueued {
                self.frames_sent += 1;
                self.obs.record_queue_depth(out.depth);
            }
            any_lagged |= out.lagged;
        }
        if any_lagged {
            // Bounded resync: the very next frame is a keyframe for
            // everyone, so the lagged client recovers in one sweep and
            // all clients keep seeing one shared byte sequence.
            hub.encoder.force_keyframe();
        }
    }

    /// Force the next [`FrameHub::broadcast`] for `session` to emit a
    /// keyframe even if nothing moved since the last frame. Used on
    /// graceful shutdown so every subscriber's final frame is a
    /// self-contained snapshot they can persist or hand to a decoder
    /// that missed earlier deltas.
    pub fn force_keyframe(&mut self, session: u64) {
        if let Some(hub) = self.sessions.get_mut(&session) {
            hub.encoder.force_keyframe();
        }
    }

    /// Tear down a session's streams (session deleted): wake every
    /// subscriber with `Closed`.
    pub fn drop_session(&mut self, session: u64) {
        if let Some(hub) = self.sessions.remove(&session) {
            for sub in &hub.subscribers {
                sub.close();
            }
        }
    }

    /// Tear down everything (server shutdown).
    pub fn drop_all(&mut self) {
        let ids: Vec<u64> = self.sessions.keys().copied().collect();
        for id in ids {
            self.drop_session(id);
        }
    }

    fn prune(&mut self) {
        self.sessions.retain(|_, hub| {
            hub.subscribers.retain(|c| !c.is_closed());
            !hub.subscribers.is_empty()
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::codec::{decode, FrameDecoder};
    use super::*;
    use std::time::Duration;

    fn matrix(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.row_mut(r)[c] = f(r, c);
            }
        }
        m
    }

    fn small_cfg() -> StreamConfig {
        StreamConfig { max_per_session: 2, max_global: 3, queue_frames: 2, keyframe_every: 10 }
    }

    fn small_hub() -> FrameHub {
        FrameHub::new(small_cfg(), Arc::new(Obs::new(false)))
    }

    #[test]
    fn admission_control_enforces_caps() {
        let mut hub = small_hub();
        let _a = hub.subscribe(1).unwrap();
        let _b = hub.subscribe(1).unwrap();
        assert_eq!(hub.subscribe(1).unwrap_err(), SubscribeError::SessionFull);
        let _c = hub.subscribe(2).unwrap();
        assert_eq!(hub.subscribe(3).unwrap_err(), SubscribeError::GlobalFull);
        // Dropping a subscription frees its slot at the next subscribe.
        drop(_c);
        assert!(hub.subscribe(3).is_ok());
    }

    #[test]
    fn two_subscribers_see_identical_sequences() {
        let mut hub = small_hub();
        let mut y = matrix(30, 2, |r, c| (r * 2 + c) as f32);
        let mut a = hub.subscribe(7).unwrap();
        let mut b = hub.subscribe(7).unwrap();
        for it in 0..4u64 {
            y.row_mut((it as usize) % 30)[0] += 4.0;
            hub.broadcast(7, it, &y, 0);
            let fa = match a.next(Duration::from_millis(100)) {
                NextFrame::Frame(f) => f,
                _ => panic!("a expected frame at iter {it}"),
            };
            let fb = match b.next(Duration::from_millis(100)) {
                NextFrame::Frame(f) => f,
                _ => panic!("b expected frame at iter {it}"),
            };
            assert_eq!(*fa, *fb, "subscribers diverged at iter {it}");
        }
    }

    #[test]
    fn overflow_drops_then_resyncs_with_keyframe() {
        let mut hub = small_hub();
        let mut y = matrix(30, 2, |r, c| (r * 2 + c) as f32);
        let mut slow = hub.subscribe(9).unwrap();
        // Never read: queue (bound 2) overflows on the third frame.
        for it in 0..6u64 {
            for r in 0..30 {
                y.row_mut(r)[0] += 1.5;
            }
            hub.broadcast(9, it, &y, 0);
        }
        assert!(hub.frames_dropped() > 0, "stalled client must lose frames");
        // Drain what's left: the first frame out must be a keyframe and
        // the whole remainder must decode cleanly from it.
        let mut dec = FrameDecoder::new();
        let mut first = true;
        loop {
            match slow.next(Duration::from_millis(50)) {
                NextFrame::Frame(f) => {
                    let frame = decode(&f).unwrap();
                    if first {
                        assert!(frame.keyframe, "resync must start at a keyframe");
                        first = false;
                    }
                    dec.apply(&frame).unwrap();
                }
                NextFrame::Idle | NextFrame::Closed => break,
            }
        }
        assert!(dec.ready(), "slow client decoded a resynced stream");
    }

    #[test]
    fn drop_session_closes_subscribers() {
        let mut hub = small_hub();
        let mut sub = hub.subscribe(4).unwrap();
        hub.drop_session(4);
        assert!(matches!(sub.next(Duration::from_millis(10)), NextFrame::Closed));
        assert_eq!(hub.total_subscribers(), 0);
    }

    #[test]
    fn broadcast_without_subscribers_is_cheap_noop() {
        let mut hub = small_hub();
        let y = matrix(5, 2, |r, c| (r + c) as f32);
        assert!(!hub.wants_frames(1));
        hub.broadcast(1, 0, &y, 0);
        assert_eq!(hub.frames_sent(), 0);
    }
}
