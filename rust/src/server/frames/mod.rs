//! Streaming frame subsystem: compact binary embedding frames fanned
//! out to many concurrent viewers.
//!
//! Polling `GET /sessions/:id/embedding` re-encodes the full embedding
//! as JSON on every request — workable for one client, hopeless for
//! many. This module replaces polling with push:
//!
//! * [`codec`] — a zero-dependency binary codec. **Keyframes**
//!   quantize every LD coordinate to `u16` on a per-frame bounding
//!   grid (~4 bytes/point in 2-D, any `d`); **delta frames** ship only
//!   the points that moved by at least one grid cell. See
//!   `docs/wire-format.md` for the byte-level spec.
//! * [`hub`] — the broadcast side. Frames are encoded once per session
//!   per sweep and shared (`Arc`) across subscribers; each subscriber
//!   has a bounded queue with drop-oldest-then-resync-keyframe
//!   backpressure so a slow client can never stall the stepper, plus
//!   admission control (per-session and global subscriber caps).
//!
//! The stepper owns the [`hub::FrameHub`]; HTTP workers hold
//! [`hub::StreamSubscription`]s and turn them into chunked HTTP/1.1
//! responses (`GET /sessions/:id/stream`).

pub mod codec;
pub mod hub;

pub use codec::{decode, Frame, FrameDecoder, FrameEncoder};
pub use hub::{FrameHub, NextFrame, StreamConfig, StreamSubscription, SubscribeError};
