//! A small JSON value type with an encoder and a recursive-descent
//! parser — `serde_json` is not available offline, and the service's
//! payloads (session specs, commands, embedding frames) only need the
//! core of RFC 8259: objects, arrays, strings with escapes (including
//! `\uXXXX` and surrogate pairs), f64 numbers, booleans and null.
//!
//! Numbers are stored as `f64` (like JavaScript); integral values
//! encode without a fractional part so ids round-trip as integers.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt;

/// Maximum nesting depth the parser accepts (stack-overflow guard).
const MAX_DEPTH: usize = 128;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs (keys sort on encode).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Member lookup on objects; `None` on other variants.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Numbers that are exactly non-negative integers (up to 2⁵³, the
    /// largest contiguously-representable f64 integer — u64 fields
    /// like `seed` must accept more than u32::MAX).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9.007_199_254_740_992e15 => {
                Some(*v as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialise to a compact JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_number(*v, out),
            Json::Str(s) => write_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_number(v: f64, out: &mut String) {
    if !v.is_finite() {
        // JSON has no NaN/Inf; null is the least-surprising encoding.
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn eat_literal(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH}");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') | Some(b'f') => {
                if self.eat_literal("true") {
                    Ok(Json::Bool(true))
                } else if self.eat_literal("false") {
                    Ok(Json::Bool(false))
                } else {
                    bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(b'n') => {
                if self.eat_literal("null") {
                    Ok(Json::Null)
                } else {
                    bail!("bad literal at byte {}", self.pos)
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected {:?} at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let v: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number {text:?} at byte {start}"))?;
        // JSON has no NaN/Inf; literals that overflow f64 (e.g. 1e999)
        // would otherwise smuggle an Inf into payloads that every
        // consumer assumes finite.
        if !v.is_finite() {
            bail!("number {text:?} overflows f64 at byte {start}");
        }
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        c => bail!("unknown escape \\{:?}", c as char),
                    }
                }
                Some(b) => {
                    // Consume one UTF-8 character. The input came from a
                    // &str, so the leading byte gives the exact length —
                    // decode just that slice (O(1) per char; decoding
                    // from the whole remaining tail would be O(n²)).
                    let len = match b {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&self.bytes[self.pos..self.pos + len])?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape {hex:?}"))?;
        self.pos += 4;
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        let code = if (0xD800..0xDC00).contains(&hi) {
            // Surrogate pair: a \uDC00..\uDFFF unit must follow.
            if !self.eat_literal("\\u") {
                bail!("lone high surrogate \\u{hi:04x}");
            }
            let lo = self.hex4()?;
            if !(0xDC00..0xE000).contains(&lo) {
                bail!("bad low surrogate \\u{lo:04x}");
            }
            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
        } else {
            hi
        };
        char::from_u32(code).ok_or_else(|| anyhow::anyhow!("invalid code point {code:#x}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(text: &str) -> String {
        parse(text).unwrap().encode()
    }

    #[test]
    fn scalars_parse_and_encode() {
        assert_eq!(roundtrip("null"), "null");
        assert_eq!(roundtrip("true"), "true");
        assert_eq!(roundtrip("false"), "false");
        assert_eq!(roundtrip("42"), "42");
        assert_eq!(roundtrip("-3.5"), "-3.5");
        assert_eq!(roundtrip("1e3"), "1000");
        assert_eq!(roundtrip("\"hi\""), "\"hi\"");
    }

    #[test]
    fn containers_roundtrip() {
        assert_eq!(roundtrip("[1, 2, [3]]"), "[1,2,[3]]");
        assert_eq!(roundtrip("{}"), "{}");
        assert_eq!(roundtrip("[]"), "[]");
        assert_eq!(
            roundtrip("{\"b\": [1, true], \"a\": {\"x\": null}}"),
            "{\"a\":{\"x\":null},\"b\":[1,true]}"
        );
    }

    #[test]
    fn string_escapes() {
        assert_eq!(parse("\"a\\n\\t\\\"b\\\\\"").unwrap(), Json::Str("a\n\t\"b\\".into()));
        assert_eq!(parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        // Surrogate pair → U+1D11E (musical G clef).
        assert_eq!(parse("\"\\ud834\\udd1e\"").unwrap(), Json::Str("\u{1d11e}".into()));
        // Control characters encode as \u00XX.
        assert_eq!(Json::Str("\u{0001}".into()).encode(), "\"\\u0001\"");
        assert_eq!(roundtrip("\"caf\u{00e9}\""), "\"café\"");
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "nul", "1 2", "\"abc",
            "\"\\u12\"", "\"\\ud834\"", "\"\\q\"", "[1] extra", "--1",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_is_bounded() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(50) + &"]".repeat(50);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn accessors() {
        let v = parse("{\"n\": 3, \"s\": \"x\", \"b\": true, \"a\": [1]}").unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        // u64-sized fields (e.g. seeds) must pass through intact.
        assert_eq!(Json::Num(5.0e9).as_usize(), Some(5_000_000_000));
        assert_eq!(Json::Num(1.0e16).as_usize(), None, "beyond exact f64 integers");
    }

    #[test]
    fn non_finite_numbers_encode_as_null() {
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
        assert_eq!(Json::Num(f64::INFINITY).encode(), "null");
    }

    #[test]
    fn nan_and_inf_are_rejected_on_parse() {
        for bad in ["NaN", "nan", "Infinity", "-Infinity", "inf", "1e999", "-1e999", "[1e400]"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
        // The largest finite f64 still parses.
        assert!(parse("1.7976931348623157e308").is_ok());
    }

    // --- property tests (util::proptest harness) ------------------------

    use crate::util::proptest as pt;
    use crate::util::Rng;

    fn gen_string(rng: &mut Rng) -> String {
        let len = rng.below(10);
        (0..len)
            .map(|_| loop {
                // Mix ASCII, control characters, BMP and astral planes.
                let code = match rng.below(4) {
                    0 => rng.below(0x80) as u32,
                    1 => rng.below(0x20) as u32,
                    2 => rng.below(0x1_0000) as u32,
                    _ => 0x1_0000 + rng.below(0x2_0000) as u32,
                };
                if let Some(c) = char::from_u32(code) {
                    break c;
                }
            })
            .collect()
    }

    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        let pick = if depth >= 4 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => match rng.below(4) {
                0 => Json::Num(rng.below(2_000_000) as f64 - 1_000_000.0),
                1 => Json::Num((rng.f64() - 0.5) * 1e9),
                2 => Json::Num(rng.gauss_ms(0.0, 1e-4)),
                // Integral beyond u32 but inside the exact-i64 window.
                _ => Json::Num((rng.below(1 << 52)) as f64),
            },
            3 => Json::Str(gen_string(rng)),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5)).map(|_| (gen_string(rng), gen_value(rng, depth + 1))).collect(),
            ),
        }
    }

    #[test]
    fn property_encode_decode_round_trip() {
        pt::check("json-roundtrip", 128, |rng, _| {
            let v = gen_value(rng, 0);
            let text = v.encode();
            let back = parse(&text).map_err(|e| format!("decode of {text:?} failed: {e}"))?;
            crate::prop_assert!(back == v, "round-trip mismatch: {v:?} -> {text} -> {back:?}");
            // Encoding is a fixed point: encode(decode(encode(v))) == encode(v).
            crate::prop_assert!(back.encode() == text, "re-encode differs for {text}");
            Ok(())
        });
    }

    /// Every char written as `\uXXXX` (surrogate pairs for astral
    /// planes) must decode back to the same Rust string.
    fn escape_all(s: &str) -> String {
        let mut out = String::from("\"");
        for c in s.chars() {
            let code = c as u32;
            if code < 0x1_0000 {
                out.push_str(&format!("\\u{code:04x}"));
            } else {
                let v = code - 0x1_0000;
                out.push_str(&format!(
                    "\\u{:04x}\\u{:04x}",
                    0xD800 + (v >> 10),
                    0xDC00 + (v & 0x3FF)
                ));
            }
        }
        out.push('"');
        out
    }

    #[test]
    fn property_unicode_escapes_decode() {
        pt::check("json-unicode-escapes", 96, |rng, _| {
            let s = gen_string(rng);
            let escaped = escape_all(&s);
            let parsed = parse(&escaped).map_err(|e| format!("{escaped}: {e}"))?;
            crate::prop_assert!(
                parsed == Json::Str(s.clone()),
                "escape round-trip mismatch for {s:?} via {escaped}"
            );
            Ok(())
        });
    }

    #[test]
    fn property_nesting_below_limit_parses() {
        pt::check("json-depth", 24, |rng, _| {
            let d = rng.range_usize(1, 100);
            let text = "[".repeat(d) + &"]".repeat(d);
            crate::prop_assert!(parse(&text).is_ok(), "depth {d} rejected");
            let deep = "[".repeat(d + 150) + &"]".repeat(d + 150);
            crate::prop_assert!(parse(&deep).is_err(), "depth {} accepted", d + 150);
            Ok(())
        });
    }

    #[test]
    fn obj_builder() {
        let v = Json::obj(vec![("id", 7usize.into()), ("name", "s".into())]);
        assert_eq!(v.encode(), "{\"id\":7,\"name\":\"s\"}");
    }
}
