//! A minimal HTTP/1.1 server on `std::net` — request parsing, routing
//! glue, keep-alive connection handling, and an accept loop that runs
//! one connection handler per [`WorkerPool`] slot.
//!
//! Scope: exactly what the embedding service needs. `Content-Length`
//! bodies (no chunked *request* bodies), a bounded header section,
//! percent-decoded query strings, and keep-alive by default (HTTP/1.1
//! semantics; `Connection: close` honoured). The listener runs in
//! non-blocking mode and workers poll it with a short sleep, so
//! shutdown is a plain atomic flag — no self-connect tricks, no
//! platform-specific socket teardown.
//!
//! Responses come in two shapes ([`Reply`]): ordinary
//! `Content-Length`-framed [`Response`]s, and **streams** — a handler
//! returns a [`ChunkSource`] and the connection switches to chunked
//! transfer encoding, forwarding frames until the source closes. A
//! streaming connection pins its worker for the stream's lifetime and
//! always ends with `Connection: close`.

use crate::runtime::WorkerPool;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Largest accepted request body (inline datasets can be sizeable).
pub const MAX_BODY_BYTES: usize = 64 * 1024 * 1024;
/// Largest accepted request line / header line.
const MAX_LINE_BYTES: usize = 16 * 1024;
/// Maximum number of headers per request.
const MAX_HEADERS: usize = 64;
/// Accept-loop poll interval while idle (the listener is non-blocking).
const IDLE_POLL: Duration = Duration::from_millis(10);
/// Per-read socket timeout: bounds how long a worker sits in a blocking
/// read on an idle keep-alive connection before re-checking shutdown.
const READ_TIMEOUT: Duration = Duration::from_millis(250);
/// Overall deadline for receiving one request (line, headers or body —
/// individual reads may hit [`READ_TIMEOUT`] and retry; a slow but
/// live client is fine, a trickling one is bounded).
const BODY_DEADLINE: Duration = Duration::from_secs(60);
/// How long a keep-alive connection may sit idle between requests
/// before the worker closes it and returns to the accept loop —
/// without this, `threads` idle clients would pin every worker.
const IDLE_CONN_TIMEOUT: Duration = Duration::from_secs(30);
/// Write timeout while streaming chunks: a client that stops reading
/// stalls its own stream (and gets torn down), never the producer.
const STREAM_WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Write timeout for ordinary `Content-Length`-framed responses: a
/// client that stops reading mid-response errors the send out here
/// instead of pinning the worker for a full [`BODY_DEADLINE`].
const PLAIN_WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// How long a [`ChunkSource`] blocks per wait before the worker
/// re-checks server shutdown.
const STREAM_POLL: Duration = Duration::from_millis(250);

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Upper-case method (`GET`, `POST`, `DELETE`, ...).
    pub method: String,
    /// Decoded path without the query string (e.g. `/sessions/3/stats`).
    pub path: String,
    /// Percent-decoded query parameters.
    pub query: BTreeMap<String, String>,
    /// Headers with lower-cased names.
    pub headers: BTreeMap<String, String>,
    /// Raw body bytes (`Content-Length` framed).
    pub body: Vec<u8>,
    /// Whether the client asked to close the connection after this
    /// exchange (`Connection: close`, or HTTP/1.0 without keep-alive).
    pub close: bool,
}

impl Request {
    /// The body as UTF-8 text.
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }

    /// An optional non-negative integer query parameter.
    pub fn query_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.query.get(key) {
            None => Ok(None),
            Some(raw) => {
                let v = raw
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("query {key}={raw:?} is not an integer"))?;
                Ok(Some(v))
            }
        }
    }
}

/// An HTTP response ready for serialisation.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers beyond the framing set (e.g. `ETag`). Names must
    /// be valid header names; values must be single-line.
    pub headers: Vec<(String, String)>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, value: &super::json::Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: value.encode().into_bytes(),
            headers: Vec::new(),
        }
    }

    /// A plain-text response (e.g. Prometheus metrics).
    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into_bytes(),
            headers: Vec::new(),
        }
    }

    /// A bodyless response (e.g. `304 Not Modified`).
    pub fn empty(status: u16) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: Vec::new(),
            headers: Vec::new(),
        }
    }

    /// Attach an extra header (builder-style).
    pub fn header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.headers.push((name.to_string(), value.into()));
        self
    }
}

/// One step of a streamed response, as yielded by [`ChunkSource::next`].
pub enum NextChunk {
    /// Bytes to forward as one chunk.
    Data(std::sync::Arc<Vec<u8>>),
    /// Nothing yet — the worker re-checks shutdown and waits again.
    Idle,
    /// Stream over; send the terminating chunk and close.
    Closed,
}

/// A pull-based byte stream driven by the connection worker. `Send`
/// because the handler creates it on a worker thread that then owns it
/// for the stream's lifetime.
pub trait ChunkSource: Send {
    /// Block up to `timeout` for the next chunk.
    fn next(&mut self, timeout: Duration) -> NextChunk;
}

/// Header section of a streamed response.
pub struct StreamStart {
    pub status: u16,
    pub content_type: &'static str,
    pub source: Box<dyn ChunkSource>,
}

/// What a [`Handler`] returns: a normal framed response or a chunked
/// stream that takes over the connection.
pub enum Reply {
    Full(Response),
    Stream(StreamStart),
}

impl From<Response> for Reply {
    fn from(resp: Response) -> Reply {
        Reply::Full(resp)
    }
}

/// Reason phrase for the status codes this service emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        304 => "Not Modified",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Per-worker request handler. One instance lives on each accept-loop
/// slot (handlers are `Send`, not `Sync` — each worker owns its own,
/// so cheap per-worker state like channel senders needs no locking).
pub trait Handler: Send {
    fn handle(&mut self, req: &Request) -> Reply;

    /// Called after [`Handler::handle`] returns with the response
    /// status and the handler wall time in microseconds (for a
    /// stream, the time to *start* it — the connection takeover that
    /// follows is client-paced). Default: ignore.
    fn observe(&mut self, _req: &Request, _status: u16, _micros: u64) {}
}

/// Run the accept loop until `shutdown` is set: one connection-handler
/// per [`WorkerPool`] slot (`handlers.len()` slots), all accepting from
/// the same non-blocking listener — the kernel load-balances accepts.
/// Blocks the caller until every worker has exited.
pub fn serve<H: Handler>(listener: &TcpListener, shutdown: &AtomicBool, handlers: Vec<H>) {
    let pool = WorkerPool::new(handlers.len());
    let tasks: Vec<_> = handlers
        .into_iter()
        .map(|mut h| move || worker_loop(listener, shutdown, &mut h))
        .collect();
    pool.run_tasks(tasks);
}

fn worker_loop<H: Handler>(listener: &TcpListener, shutdown: &AtomicBool, handler: &mut H) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Connection errors only tear down that connection.
                let _ = handle_connection(stream, shutdown, handler);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(IDLE_POLL),
            Err(_) => std::thread::sleep(IDLE_POLL),
        }
    }
}

/// Serve one (possibly keep-alive) connection to completion.
fn handle_connection<H: Handler>(
    stream: TcpStream,
    shutdown: &AtomicBool,
    handler: &mut H,
) -> Result<()> {
    // The accepted socket may inherit the listener's non-blocking mode.
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    // A client that stops reading must not pin this worker: a stalled
    // send errors out after the deadline and the connection closes.
    stream.set_write_timeout(Some(PLAIN_WRITE_TIMEOUT))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut idle_since = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        // Wait for the next request without consuming anything, so an
        // idle tick (timeout) can loop back and re-check shutdown.
        match reader.fill_buf() {
            Ok(buf) if buf.is_empty() => break, // clean EOF
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if idle_since.elapsed() >= IDLE_CONN_TIMEOUT {
                    break; // free the worker slot for other clients
                }
                continue;
            }
            Err(_) => break,
        }
        let req = match read_request(&mut reader, Some(shutdown)) {
            Ok(r) => r,
            Err(e) => {
                // 408 for a client that blew the read deadline, 400
                // for a malformed request; either way the connection
                // is desynchronised, so close it.
                let body = super::json::Json::obj(vec![("error", format!("{e}").into())]);
                let _ = write_response(&mut writer, &Response::json(e.status(), &body), true);
                break;
            }
        };
        let clock = crate::util::timer::PhaseClock::start();
        let reply = handler.handle(&req);
        handler.observe(
            &req,
            match &reply {
                Reply::Full(resp) => resp.status,
                Reply::Stream(start) => start.status,
            },
            clock.elapsed_ns() / 1_000,
        );
        match reply {
            Reply::Full(resp) => {
                let close = req.close || shutdown.load(Ordering::SeqCst);
                write_response(&mut writer, &resp, close)?;
                if close {
                    break;
                }
            }
            Reply::Stream(start) => {
                // The stream takes over the connection: chunked framing,
                // Connection: close, and the worker is pinned until the
                // source closes, the client goes away or shutdown.
                let _ = stream_response(&mut writer, start, shutdown);
                break;
            }
        }
        idle_since = Instant::now();
    }
    Ok(())
}

/// Drive a chunked-transfer response: write the header section, then
/// pull chunks from the source until it closes (or the client / server
/// goes away). Dropping the source on exit is what unsubscribes it
/// from its producer.
fn stream_response(
    w: &mut TcpStream,
    mut start: StreamStart,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    w.set_write_timeout(Some(STREAM_WRITE_TIMEOUT))?;
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        start.status,
        reason(start.status),
        start.content_type,
    );
    w.write_all(head.as_bytes())?;
    w.flush()?;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        match start.source.next(STREAM_POLL) {
            NextChunk::Data(bytes) => {
                if bytes.is_empty() {
                    // An empty chunk would read as the terminator.
                    continue;
                }
                write!(w, "{:x}\r\n", bytes.len())?;
                w.write_all(&bytes)?;
                w.write_all(b"\r\n")?;
                w.flush()?;
            }
            NextChunk::Idle => continue,
            NextChunk::Closed => break,
        }
    }
    // Best-effort terminator; the connection closes either way.
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// Why reading a request failed — picks the response status: a client
/// that blew the read deadline gets `408 Request Timeout`; everything
/// else (malformed framing, oversized body, mid-request EOF) `400`.
///
/// A typed error rather than `anyhow` because the connection handler
/// must branch on the cause and the vendored shim has no downcast.
#[derive(Debug)]
pub enum RequestError {
    /// The shared [`BODY_DEADLINE`] elapsed before the request arrived.
    Timeout(String),
    /// The request was malformed, oversized, or cut short.
    Bad(String),
}

impl RequestError {
    fn bad(msg: impl Into<String>) -> RequestError {
        RequestError::Bad(msg.into())
    }

    /// The HTTP status this failure is answered with.
    pub fn status(&self) -> u16 {
        match self {
            RequestError::Timeout(_) => 408,
            RequestError::Bad(_) => 400,
        }
    }
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Timeout(msg) | RequestError::Bad(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for RequestError {}

/// Read one request (request line, headers, `Content-Length` body) from
/// a buffered stream positioned at a request boundary. One
/// [`BODY_DEADLINE`] covers the whole request, so a trickling client
/// cannot stretch it per-line; setting `cancel` (the server's shutdown
/// flag) aborts mid-request so shutdown never waits out the deadline.
pub fn read_request<R: BufRead>(
    r: &mut R,
    cancel: Option<&AtomicBool>,
) -> Result<Request, RequestError> {
    read_request_deadline(r, Instant::now() + BODY_DEADLINE, cancel)
}

/// [`read_request`] with an explicit deadline (tests inject an
/// already-elapsed one to exercise the timeout path).
fn read_request_deadline<R: BufRead>(
    r: &mut R,
    deadline: Instant,
    cancel: Option<&AtomicBool>,
) -> Result<Request, RequestError> {
    let line = read_line(r, deadline, cancel)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| RequestError::bad("empty request line"))?
        .to_ascii_uppercase();
    let target = parts.next().ok_or_else(|| RequestError::bad("request line has no target"))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    let http10 = version.eq_ignore_ascii_case("HTTP/1.0");
    let (path, query) = split_target(target);

    let mut headers = BTreeMap::new();
    loop {
        let line = read_line(r, deadline, cancel)?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(RequestError::bad("too many headers"));
        }
        let (name, value) =
            line.split_once(':').ok_or_else(|| RequestError::bad("malformed header line"))?;
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }

    if let Some(te) = headers.get("transfer-encoding") {
        // Parsing a chunked body as empty would desync the keep-alive
        // stream (chunk framing read as the next request line) — refuse.
        return Err(RequestError::bad(format!(
            "Transfer-Encoding {te:?} unsupported (use Content-Length)"
        )));
    }
    let len = match headers.get("content-length") {
        None => 0,
        Some(v) => v.parse::<usize>().map_err(|_| RequestError::bad("bad Content-Length"))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(RequestError::bad(format!(
            "body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte limit"
        )));
    }
    let body = read_body(r, len, deadline, cancel)?;

    let conn = headers.get("connection").map(|s| s.to_ascii_lowercase()).unwrap_or_default();
    let close = conn.contains("close") || (http10 && !conn.contains("keep-alive"));
    Ok(Request { method, path, query, headers, body, close })
}

/// Read exactly `len` body bytes, retrying reads that hit the short
/// socket [`READ_TIMEOUT`] (a large upload legitimately spans many
/// reads) under the request's shared `deadline`.
fn read_body<R: BufRead>(
    r: &mut R,
    len: usize,
    deadline: Instant,
    cancel: Option<&AtomicBool>,
) -> Result<Vec<u8>, RequestError> {
    let mut body = vec![0u8; len];
    let mut filled = 0usize;
    while filled < len {
        match r.read(&mut body[filled..]) {
            Ok(0) => {
                return Err(RequestError::bad(format!(
                    "connection closed mid-body ({filled}/{len} bytes)"
                )))
            }
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if cancelled(cancel) {
                    return Err(RequestError::bad("server shutting down"));
                }
                if Instant::now() >= deadline {
                    return Err(RequestError::Timeout(format!(
                        "timed out reading request body ({filled}/{len} bytes)"
                    )));
                }
            }
            Err(e) => return Err(RequestError::bad(format!("read request body: {e}"))),
        }
    }
    Ok(body)
}

fn cancelled(cancel: Option<&AtomicBool>) -> bool {
    cancel.is_some_and(|c| c.load(Ordering::SeqCst))
}

/// Read one CRLF- (or LF-) terminated line, bounded by
/// [`MAX_LINE_BYTES`]. Reads that hit the short socket
/// [`READ_TIMEOUT`] mid-line retry under the request's shared
/// `deadline` (already-read bytes stay accumulated in `buf`),
/// mirroring [`read_body`] — a header split across slow packets must
/// not 400.
fn read_line<R: BufRead>(
    r: &mut R,
    deadline: Instant,
    cancel: Option<&AtomicBool>,
) -> Result<String, RequestError> {
    let mut buf = Vec::new();
    loop {
        let remaining = MAX_LINE_BYTES.saturating_sub(buf.len());
        if remaining == 0 {
            return Err(RequestError::bad(format!("header line exceeds {MAX_LINE_BYTES} bytes")));
        }
        match r.by_ref().take(remaining as u64).read_until(b'\n', &mut buf) {
            Ok(0) => return Err(RequestError::bad("connection closed mid-request")),
            Ok(_) => {
                if buf.last() == Some(&b'\n') {
                    break;
                }
                // Hit the length cap or EOF mid-line; loop to find out
                // (cap → remaining == 0 bails, EOF → Ok(0) bails).
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if cancelled(cancel) {
                    return Err(RequestError::bad("server shutting down"));
                }
                if Instant::now() >= deadline {
                    return Err(RequestError::Timeout(
                        "timed out reading request line/headers".to_string(),
                    ));
                }
            }
            Err(e) => return Err(RequestError::bad(format!("read line: {e}"))),
        }
    }
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| RequestError::bad("header line is not UTF-8"))
}

/// Split a request target into its decoded path and query map.
fn split_target(target: &str) -> (String, BTreeMap<String, String>) {
    let (path, qs) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for pair in qs.split('&').filter(|p| !p.is_empty()) {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        query.insert(percent_decode(k, true), percent_decode(v, true));
    }
    // RFC 3986: '+' is only space-encoded in form-style query data,
    // never in the path — a literal '+' in a path must survive.
    (percent_decode(path, false), query)
}

/// Decode `%XX` escapes (and, for query components, `+`-as-space);
/// malformed escapes pass through literally.
fn percent_decode(s: &str, plus_as_space: bool) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' if plus_as_space => {
                out.push(b' ');
                i += 1;
            }
            b'%' => match (hex_val(bytes.get(i + 1)), hex_val(bytes.get(i + 2))) {
                (Some(h), Some(l)) => {
                    out.push(h * 16 + l);
                    i += 3;
                }
                _ => {
                    out.push(b'%');
                    i += 1;
                }
            },
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn hex_val(b: Option<&u8>) -> Option<u8> {
    match b? {
        c @ b'0'..=b'9' => Some(c - b'0'),
        c @ b'a'..=b'f' => Some(c - b'a' + 10),
        c @ b'A'..=b'F' => Some(c - b'A' + 10),
        _ => None,
    }
}

/// Serialise a response; `close` selects the `Connection` header.
pub fn write_response(w: &mut impl Write, resp: &Response, close: bool) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if close { "close" } else { "keep-alive" },
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(&resp.body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse_req(raw: &str) -> Request {
        read_request(&mut Cursor::new(raw.as_bytes()), None).unwrap()
    }

    #[test]
    fn parses_get_with_query() {
        let req = parse_req("GET /sessions/3/embedding?iter=120&x=a%20b HTTP/1.1\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/sessions/3/embedding");
        assert_eq!(req.query.get("iter").unwrap(), "120");
        assert_eq!(req.query.get("x").unwrap(), "a b");
        assert_eq!(req.query_usize("iter").unwrap(), Some(120));
        assert_eq!(req.query_usize("missing").unwrap(), None);
        assert!(req.query_usize("x").is_err());
        assert!(!req.close, "HTTP/1.1 defaults to keep-alive");
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_with_body_and_headers() {
        let raw = "POST /sessions HTTP/1.1\r\nContent-Type: application/json\r\n\
                   Content-Length: 14\r\nConnection: close\r\n\r\n{\"rows\":[[1]]}";
        let req = read_request(&mut Cursor::new(raw.as_bytes()), None).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.headers.get("content-type").unwrap(), "application/json");
        assert_eq!(req.body_str().unwrap(), "{\"rows\":[[1]]}");
        assert!(req.close);
    }

    #[test]
    fn http10_defaults_to_close() {
        let req = parse_req("GET / HTTP/1.0\r\n\r\n");
        assert!(req.close);
        let req = parse_req("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n");
        assert!(!req.close);
    }

    #[test]
    fn rejects_malformed_requests() {
        for raw in [
            "",
            "\r\n\r\n",
            "GET\r\n\r\n",
            "GET / HTTP/1.1\r\nno-colon-here\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: nan\r\n\r\n",
            "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort",
        ] {
            assert!(
                read_request(&mut Cursor::new(raw.as_bytes()), None).is_err(),
                "should reject {raw:?}"
            );
        }
    }

    #[test]
    fn rejects_oversized_body_declaration() {
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(read_request(&mut Cursor::new(raw.as_bytes()), None).is_err());
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%2Fb+c", true), "a/b c");
        assert_eq!(percent_decode("no-escapes", true), "no-escapes");
        assert_eq!(percent_decode("bad%zz", true), "bad%zz");
        assert_eq!(percent_decode("%41%42", true), "AB");
        assert_eq!(percent_decode("trail%4", true), "trail%4");
        // '+' survives in path position, decodes only in queries.
        assert_eq!(percent_decode("a+b", false), "a+b");
        let req = parse_req("GET /a+b?q=c+d HTTP/1.1\r\n\r\n");
        assert_eq!(req.path, "/a+b");
        assert_eq!(req.query.get("q").unwrap(), "c d");
    }

    #[test]
    fn rejects_transfer_encoding() {
        let raw = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        assert!(read_request(&mut Cursor::new(raw.as_bytes()), None).is_err());
    }

    #[test]
    fn response_serialises_with_framing() {
        let resp = Response::text(200, "hello".into());
        let mut out = Vec::new();
        write_response(&mut out, &resp, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 5\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\nhello"));

        let mut out = Vec::new();
        write_response(&mut out, &Response::text(404, "x".into()), true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.contains("Connection: close\r\n"));
    }

    #[test]
    fn extra_headers_and_304_serialise() {
        let resp = Response::empty(304).header("ETag", "\"abc\"");
        let mut out = Vec::new();
        write_response(&mut out, &resp, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 304 Not Modified\r\n"), "{text}");
        assert!(text.contains("ETag: \"abc\"\r\n"), "{text}");
        assert!(text.contains("Content-Length: 0\r\n"), "{text}");
    }

    /// A reader that behaves like a socket whose peer went silent:
    /// every read hits the socket timeout.
    struct StalledReader;

    impl Read for StalledReader {
        fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(ErrorKind::TimedOut, "stalled"))
        }
    }

    impl BufRead for StalledReader {
        fn fill_buf(&mut self) -> std::io::Result<&[u8]> {
            Err(std::io::Error::new(ErrorKind::TimedOut, "stalled"))
        }
        fn consume(&mut self, _: usize) {}
    }

    #[test]
    fn stalled_request_line_reports_timeout_as_408() {
        let err = read_request_deadline(&mut StalledReader, Instant::now(), None).unwrap_err();
        assert!(matches!(err, RequestError::Timeout(_)), "{err:?}");
        assert_eq!(err.status(), 408);
    }

    #[test]
    fn stalled_request_body_reports_timeout_as_408() {
        // Headers arrive, then the client stops 7 bytes short of its
        // declared Content-Length.
        let raw = "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        let mut r = Cursor::new(raw.as_bytes().to_vec()).chain(StalledReader);
        let deadline = Instant::now() + Duration::from_millis(20);
        let err = read_request_deadline(&mut r, deadline, None).unwrap_err();
        assert!(matches!(err, RequestError::Timeout(_)), "{err:?}");
        assert!(err.to_string().contains("3/10"), "{err}");
    }

    #[test]
    fn malformed_requests_report_400() {
        let err = read_request(&mut Cursor::new(b"GET\r\n\r\n".as_slice()), None).unwrap_err();
        assert!(matches!(err, RequestError::Bad(_)), "{err:?}");
        assert_eq!(err.status(), 400);
    }

    #[test]
    fn multiple_requests_parse_from_one_stream() {
        let raw = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes());
        assert_eq!(read_request(&mut cur, None).unwrap().path, "/a");
        assert_eq!(read_request(&mut cur, None).unwrap().path, "/b");
        assert!(read_request(&mut cur, None).is_err(), "EOF after the second");
    }
}
