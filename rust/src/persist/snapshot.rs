//! Versioned snapshot codec and atomic on-disk publish.
//!
//! A snapshot is the complete durable image of one session: engine
//! state (embedding, velocities, twin neighbour tables, affinities,
//! sequential RNG, EWMAs, config, iteration counters), the optional
//! PCA pre-reduction basis, session bookkeeping, and the WAL sequence
//! number the image is consistent with. Restoring a snapshot and
//! replaying the WAL tail reproduces the exact bitwise trajectory the
//! session would have taken uninterrupted (see `docs/persistence.md`).
//!
//! # Wire format (version 1, all integers little-endian)
//!
//! ```text
//! header   := magic "FSNP" | version u8 | reserved u8×3
//! body     := section×9 (fixed order, all mandatory)
//! section  := tag u8 | payload_len u64 | payload | crc32(payload) u32
//! ```
//!
//! Sections, in order: META (0x01), CONFIG (0x02), X (0x03), Y (0x04),
//! VEL (0x05), KNN (0x06), AFF (0x07), RNG (0x08), EXTRAS (0x09).
//! Every section carries its own IEEE CRC32, so a flipped bit anywhere
//! in a payload is detected before any value is trusted. [`decode`] is
//! strict: wrong magic, unknown version, out-of-order or missing
//! sections, CRC mismatches, truncation, trailing bytes, enum bytes
//! outside their domain, and cross-section inconsistencies (matrix
//! dims vs config, table sizes vs N) are all hard errors — a snapshot
//! either restores exactly or not at all.
//!
//! # Atomic publish
//!
//! [`save_atomic`] writes `<path>.tmp`, fsyncs, renames over `<path>`,
//! then fsyncs the directory (best-effort). A crash at any instant
//! leaves either the old complete snapshot or the new complete
//! snapshot — never a torn one. The write and rename steps carry
//! [`failpoint`](super::failpoint) hooks (`snapshot.write`,
//! `snapshot.rename`) so tests can prove exactly that.

use std::fs;
use std::io::{self, Write};
use std::path::Path;

use crate::config::{Backend, EmbedConfig, Init};
use crate::data::Matrix;
use crate::engine::funcsne::EngineState;
use crate::engine::{EngineStats, PhaseMicros};
use crate::hd::Affinities;
use crate::knn::iterative::{CandidateRoutes, IterativeKnn};
use crate::knn::NeighborTable;
use crate::linalg::Pca;
use crate::metrics::probe::QualityReport;

use super::codec::{crc32, put_bool, put_f32, put_f64, put_u32, put_u64, put_usize, Reader};
use super::failpoint::{self, FailAction};

/// File magic: "FUnc-SNE SNaPshot".
pub const MAGIC: [u8; 4] = *b"FSNP";
/// Current codec version. Loaders reject anything newer.
pub const VERSION: u8 = 1;

const TAG_META: u8 = 0x01;
const TAG_CONFIG: u8 = 0x02;
const TAG_X: u8 = 0x03;
const TAG_Y: u8 = 0x04;
const TAG_VEL: u8 = 0x05;
const TAG_KNN: u8 = 0x06;
const TAG_AFF: u8 = 0x07;
const TAG_RNG: u8 = 0x08;
const TAG_EXTRAS: u8 = 0x09;

/// Everything a [`crate::session::Session`] needs to come back to
/// life: the engine image plus session-level bookkeeping. The compute
/// backend, worker pool, probe ground-truth rows and scratch buffers
/// are *not* stored — they are rebuilt deterministically from the
/// config and data on restore.
pub struct SessionState {
    pub engine: EngineState,
    /// Ingest-time PCA basis (sessions whose input was pre-reduced).
    pub pca: Option<Pca>,
    pub paused: bool,
    pub snapshot_stride: u64,
    pub snapshot_capacity: u64,
    pub commands_applied: u64,
    pub commands_rejected: u64,
    /// Highest WAL sequence number already folded into this image;
    /// replay skips records with `seq <= wal_seq`.
    pub wal_seq: u64,
}

// ------------------------------------------------------------- encode

/// Serialize a session image. Encoding is infallible: every reachable
/// in-memory state has a representation.
pub fn encode(st: &SessionState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.extend_from_slice(&[0u8; 3]);
    section(&mut out, TAG_META, &encode_meta(st));
    section(&mut out, TAG_CONFIG, &encode_config(&st.engine.cfg));
    section(&mut out, TAG_X, &encode_matrix(&st.engine.x));
    section(&mut out, TAG_Y, &encode_matrix(&st.engine.y));
    section(&mut out, TAG_VEL, &encode_matrix(&st.engine.vel));
    section(&mut out, TAG_KNN, &encode_knn(&st.engine.knn));
    section(&mut out, TAG_AFF, &encode_aff(&st.engine.aff));
    section(&mut out, TAG_RNG, &encode_rng(&st.engine));
    section(&mut out, TAG_EXTRAS, &encode_extras(st));
    out
}

fn section(out: &mut Vec<u8>, tag: u8, payload: &[u8]) {
    out.push(tag);
    put_u64(out, payload.len() as u64);
    out.extend_from_slice(payload);
    put_u32(out, crc32(payload));
}

fn encode_meta(st: &SessionState) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, st.engine.iter);
    put_u64(&mut p, st.engine.structure_version);
    put_u64(&mut p, st.wal_seq);
    put_bool(&mut p, st.paused);
    put_u64(&mut p, st.snapshot_stride);
    put_u64(&mut p, st.snapshot_capacity);
    put_u64(&mut p, st.commands_applied);
    put_u64(&mut p, st.commands_rejected);
    let s = &st.engine.stats;
    put_usize(&mut p, s.iters);
    put_usize(&mut p, s.hd_refines);
    put_usize(&mut p, s.ld_refines);
    put_usize(&mut p, s.recalibrated_points);
    put_usize(&mut p, s.implosions);
    put_usize(&mut p, s.hd_new_last);
    put_f64(&mut p, s.refine_ewma);
    put_f64(&mut p, s.mean_w);
    put_f64(&mut p, s.covered_avg);
    match &s.quality {
        None => put_bool(&mut p, false),
        Some(q) => {
            put_bool(&mut p, true);
            put_usize(&mut p, q.iter);
            put_usize(&mut p, q.anchors);
            put_usize(&mut p, q.k);
            put_f64(&mut p, q.knn_recall);
            put_f64(&mut p, q.trustworthiness);
            put_f64(&mut p, q.continuity);
            put_f64(&mut p, q.knn_recall_hd);
        }
    }
    p
}

fn encode_config(cfg: &EmbedConfig) -> Vec<u8> {
    let mut p = Vec::new();
    put_usize(&mut p, cfg.ld_dim);
    put_f64(&mut p, cfg.alpha);
    put_f64(&mut p, cfg.perplexity);
    put_usize(&mut p, cfg.k_hd);
    put_usize(&mut p, cfg.k_ld);
    put_usize(&mut p, cfg.n_neg);
    put_f64(&mut p, cfg.lr);
    put_f64(&mut p, cfg.momentum);
    put_f64(&mut p, cfg.attraction);
    put_f64(&mut p, cfg.repulsion);
    put_f64(&mut p, cfg.early_exag);
    put_usize(&mut p, cfg.early_exag_iters);
    put_usize(&mut p, cfg.n_iters);
    put_f64(&mut p, cfg.refine_base_prob);
    put_f64(&mut p, cfg.refine_ewma_beta);
    put_usize(&mut p, cfg.n_candidates);
    put_usize(&mut p, cfg.jumpstart_iters);
    put_f64(&mut p, cfg.implosion_radius);
    put_f64(&mut p, cfg.implosion_factor);
    p.push(match cfg.init {
        Init::Random => 0,
        Init::Pca => 1,
    });
    p.push(match cfg.backend {
        Backend::Native => 0,
        Backend::Simd => 1,
        Backend::Pjrt => 2,
    });
    put_u64(&mut p, cfg.seed);
    put_usize(&mut p, cfg.recalibrate_every);
    put_usize(&mut p, cfg.threads);
    put_usize(&mut p, cfg.probe_every);
    put_usize(&mut p, cfg.probe_anchors);
    p
}

fn encode_matrix(m: &Matrix) -> Vec<u8> {
    let mut p = Vec::new();
    put_matrix(&mut p, m);
    p
}

fn put_matrix(p: &mut Vec<u8>, m: &Matrix) {
    put_usize(p, m.n());
    put_usize(p, m.d());
    for &v in m.data() {
        put_f32(p, v);
    }
}

fn get_matrix(r: &mut Reader<'_>) -> Result<Matrix, String> {
    let n = r.get_usize()?;
    let d = r.get_usize()?;
    let len = n
        .checked_mul(d)
        .ok_or_else(|| format!("{}: matrix dims {n}x{d} overflow", r.what()))?;
    let data = r.get_f32s(len)?;
    Matrix::from_vec(data, n, d).map_err(|e| format!("{}: {e}", r.what()))
}

fn put_table(p: &mut Vec<u8>, t: &NeighborTable) {
    let (n, k, dists, idxs, lens) = t.raw_parts();
    put_usize(p, n);
    put_usize(p, k);
    for &l in lens {
        put_u32(p, l);
    }
    for &d in dists {
        put_f32(p, d);
    }
    for &i in idxs {
        put_u32(p, i);
    }
}

fn get_table(r: &mut Reader<'_>) -> Result<NeighborTable, String> {
    let n = r.get_usize()?;
    let k = r.get_usize()?;
    let slots = n
        .checked_mul(k)
        .ok_or_else(|| format!("{}: table dims {n}x{k} overflow", r.what()))?;
    let lens = r.get_u32s(n)?;
    let dists = r.get_f32s(slots)?;
    let idxs = r.get_u32s(slots)?;
    NeighborTable::from_raw_parts(n, k, dists, idxs, lens)
        .map_err(|e| format!("{}: {e}", r.what()))
}

fn encode_knn(knn: &IterativeKnn) -> Vec<u8> {
    let mut p = Vec::new();
    put_table(&mut p, &knn.hd);
    put_table(&mut p, &knn.ld);
    put_usize(&mut p, knn.hd_dirty.len());
    for &dirty in &knn.hd_dirty {
        put_bool(&mut p, dirty);
    }
    p
}

fn encode_aff(aff: &Affinities) -> Vec<u8> {
    let mut p = Vec::new();
    let n = aff.beta.len();
    put_usize(&mut p, n);
    put_usize(&mut p, aff.k());
    for &v in aff.p_all() {
        put_f32(&mut p, v);
    }
    for &v in &aff.beta {
        put_f32(&mut p, v);
    }
    for &v in &aff.achieved {
        put_f32(&mut p, v);
    }
    p
}

fn encode_rng(e: &EngineState) -> Vec<u8> {
    let mut p = Vec::new();
    let (s, spare) = e.rng;
    for word in s {
        put_u64(&mut p, word);
    }
    match spare {
        None => put_bool(&mut p, false),
        Some(bits) => {
            put_bool(&mut p, true);
            put_u64(&mut p, bits);
        }
    }
    for (beta, value, initialised) in [e.refine_ewma, e.w_ewma] {
        put_f64(&mut p, beta);
        put_f64(&mut p, value);
        put_bool(&mut p, initialised);
    }
    put_f64(&mut p, e.covered_avg);
    p
}

fn encode_extras(st: &SessionState) -> Vec<u8> {
    let mut p = Vec::new();
    let r = st.engine.routes;
    p.push((r.same_space as u8) | ((r.cross_space as u8) << 1) | ((r.random as u8) << 2));
    match &st.engine.jumpstart_target {
        None => put_bool(&mut p, false),
        Some(m) => {
            put_bool(&mut p, true);
            put_matrix(&mut p, m);
        }
    }
    match &st.engine.probe_anchors {
        None => put_bool(&mut p, false),
        Some(ids) => {
            put_bool(&mut p, true);
            put_usize(&mut p, ids.len());
            for &id in ids {
                put_u32(&mut p, id);
            }
        }
    }
    match &st.pca {
        None => put_bool(&mut p, false),
        Some(pca) => {
            put_bool(&mut p, true);
            put_matrix(&mut p, &pca.components);
            put_usize(&mut p, pca.means.len());
            for &v in &pca.means {
                put_f32(&mut p, v);
            }
            put_usize(&mut p, pca.explained.len());
            for &v in &pca.explained {
                put_f64(&mut p, v);
            }
        }
    }
    p
}

// ------------------------------------------------------------- decode

/// Deserialize and fully validate a snapshot. Any corruption — bit
/// flips (CRC), truncation, format drift, or internally inconsistent
/// state — is an error; a partially trusted restore is worse than a
/// clean failure.
pub fn decode(bytes: &[u8]) -> Result<SessionState, String> {
    if bytes.len() < 8 {
        return Err("snapshot shorter than its 8-byte header".into());
    }
    if bytes[0..4] != MAGIC {
        return Err("bad snapshot magic (not an FSNP file)".into());
    }
    if bytes[4] != VERSION {
        return Err(format!("unsupported snapshot version {} (expected {VERSION})", bytes[4]));
    }
    let mut pos = 8usize;
    let meta = read_section(bytes, &mut pos, TAG_META, "META")?;
    let config = read_section(bytes, &mut pos, TAG_CONFIG, "CONFIG")?;
    let xb = read_section(bytes, &mut pos, TAG_X, "X")?;
    let yb = read_section(bytes, &mut pos, TAG_Y, "Y")?;
    let velb = read_section(bytes, &mut pos, TAG_VEL, "VEL")?;
    let knnb = read_section(bytes, &mut pos, TAG_KNN, "KNN")?;
    let affb = read_section(bytes, &mut pos, TAG_AFF, "AFF")?;
    let rngb = read_section(bytes, &mut pos, TAG_RNG, "RNG")?;
    let extras = read_section(bytes, &mut pos, TAG_EXTRAS, "EXTRAS")?;
    if pos != bytes.len() {
        return Err(format!("{} trailing bytes after final section", bytes.len() - pos));
    }

    let cfg = decode_config(config)?;
    cfg.validate().map_err(|e| format!("CONFIG: {e}"))?;

    let mut r = Reader::new(xb, "X");
    let x = get_matrix(&mut r)?;
    r.finish()?;
    let mut r = Reader::new(yb, "Y");
    let y = get_matrix(&mut r)?;
    r.finish()?;
    let mut r = Reader::new(velb, "VEL");
    let vel = get_matrix(&mut r)?;
    r.finish()?;

    let n = x.n();
    if n < 4 {
        return Err(format!("X: {n} points is below the 4-point minimum"));
    }
    if y.n() != n || vel.n() != n {
        return Err(format!("Y/VEL row counts ({}, {}) disagree with X ({n})", y.n(), vel.n()));
    }
    if y.d() != cfg.ld_dim || vel.d() != cfg.ld_dim {
        return Err(format!(
            "Y/VEL widths ({}, {}) disagree with ld_dim {}",
            y.d(),
            vel.d(),
            cfg.ld_dim
        ));
    }

    let knn = decode_knn(knnb, n)?;
    let aff = decode_aff(affb, n, knn.hd.k())?;
    let (rng, refine_ewma, w_ewma, covered_avg) = decode_rng(rngb)?;
    let (meta_out, stats) = decode_meta(meta)?;
    let (routes, jumpstart_target, probe_anchors, pca) = decode_extras(extras, n, &cfg, &x)?;

    Ok(SessionState {
        engine: EngineState {
            cfg,
            x,
            y,
            vel,
            knn,
            aff,
            rng,
            refine_ewma,
            w_ewma,
            covered_avg,
            iter: meta_out.iter,
            structure_version: meta_out.structure_version,
            stats,
            routes,
            jumpstart_target,
            probe_anchors,
        },
        pca,
        paused: meta_out.paused,
        snapshot_stride: meta_out.snapshot_stride,
        snapshot_capacity: meta_out.snapshot_capacity,
        commands_applied: meta_out.commands_applied,
        commands_rejected: meta_out.commands_rejected,
        wal_seq: meta_out.wal_seq,
    })
}

fn read_section<'a>(
    bytes: &'a [u8],
    pos: &mut usize,
    tag: u8,
    what: &'static str,
) -> Result<&'a [u8], String> {
    if bytes.len() - *pos < 9 {
        return Err(format!("truncated before {what} section header"));
    }
    let found = bytes[*pos];
    if found != tag {
        return Err(format!("expected {what} section (tag 0x{tag:02x}), found tag 0x{found:02x}"));
    }
    let mut lb = [0u8; 8];
    lb.copy_from_slice(&bytes[*pos + 1..*pos + 9]);
    let len = usize::try_from(u64::from_le_bytes(lb))
        .map_err(|_| format!("{what} section length overflows usize"))?;
    let start = *pos + 9;
    let end = start
        .checked_add(len)
        .filter(|&e| e + 4 <= bytes.len())
        .ok_or_else(|| format!("{what} section truncated"))?;
    let payload = &bytes[start..end];
    let mut cb = [0u8; 4];
    cb.copy_from_slice(&bytes[end..end + 4]);
    if crc32(payload) != u32::from_le_bytes(cb) {
        return Err(format!("{what} section CRC mismatch"));
    }
    *pos = end + 4;
    Ok(payload)
}

struct MetaOut {
    iter: u64,
    structure_version: u64,
    wal_seq: u64,
    paused: bool,
    snapshot_stride: u64,
    snapshot_capacity: u64,
    commands_applied: u64,
    commands_rejected: u64,
}

fn decode_meta(payload: &[u8]) -> Result<(MetaOut, EngineStats), String> {
    let mut r = Reader::new(payload, "META");
    let meta = MetaOut {
        iter: r.get_u64()?,
        structure_version: r.get_u64()?,
        wal_seq: r.get_u64()?,
        paused: r.get_bool()?,
        snapshot_stride: r.get_u64()?,
        snapshot_capacity: r.get_u64()?,
        commands_applied: r.get_u64()?,
        commands_rejected: r.get_u64()?,
    };
    let mut stats = EngineStats {
        iters: r.get_usize()?,
        hd_refines: r.get_usize()?,
        ld_refines: r.get_usize()?,
        recalibrated_points: r.get_usize()?,
        implosions: r.get_usize()?,
        hd_new_last: r.get_usize()?,
        refine_ewma: r.get_f64()?,
        mean_w: r.get_f64()?,
        covered_avg: r.get_f64()?,
        // Wall-clock telemetry restarts from zero on restore; it never
        // feeds back into the computation.
        phase_micros: PhaseMicros::default(),
        quality: None,
    };
    if r.get_bool()? {
        stats.quality = Some(QualityReport {
            iter: r.get_usize()?,
            anchors: r.get_usize()?,
            k: r.get_usize()?,
            knn_recall: r.get_f64()?,
            trustworthiness: r.get_f64()?,
            continuity: r.get_f64()?,
            knn_recall_hd: r.get_f64()?,
        });
    }
    r.finish()?;
    Ok((meta, stats))
}

fn decode_config(payload: &[u8]) -> Result<EmbedConfig, String> {
    let mut r = Reader::new(payload, "CONFIG");
    let cfg = EmbedConfig {
        ld_dim: r.get_usize()?,
        alpha: r.get_f64()?,
        perplexity: r.get_f64()?,
        k_hd: r.get_usize()?,
        k_ld: r.get_usize()?,
        n_neg: r.get_usize()?,
        lr: r.get_f64()?,
        momentum: r.get_f64()?,
        attraction: r.get_f64()?,
        repulsion: r.get_f64()?,
        early_exag: r.get_f64()?,
        early_exag_iters: r.get_usize()?,
        n_iters: r.get_usize()?,
        refine_base_prob: r.get_f64()?,
        refine_ewma_beta: r.get_f64()?,
        n_candidates: r.get_usize()?,
        jumpstart_iters: r.get_usize()?,
        implosion_radius: r.get_f64()?,
        implosion_factor: r.get_f64()?,
        init: match r.get_u8()? {
            0 => Init::Random,
            1 => Init::Pca,
            v => return Err(format!("CONFIG: invalid init byte {v}")),
        },
        backend: match r.get_u8()? {
            0 => Backend::Native,
            1 => Backend::Simd,
            2 => Backend::Pjrt,
            v => return Err(format!("CONFIG: invalid backend byte {v}")),
        },
        seed: r.get_u64()?,
        recalibrate_every: r.get_usize()?,
        threads: r.get_usize()?,
        probe_every: r.get_usize()?,
        probe_anchors: r.get_usize()?,
    };
    r.finish()?;
    Ok(cfg)
}

fn decode_knn(payload: &[u8], n: usize) -> Result<IterativeKnn, String> {
    let mut r = Reader::new(payload, "KNN");
    let hd = get_table(&mut r)?;
    let ld = get_table(&mut r)?;
    let dirty_len = r.get_usize()?;
    let mut hd_dirty = Vec::with_capacity(dirty_len.min(payload.len()));
    for _ in 0..dirty_len {
        hd_dirty.push(r.get_bool()?);
    }
    r.finish()?;
    if hd.n() != n || ld.n() != n || hd_dirty.len() != n {
        return Err(format!(
            "KNN: table sizes (hd {}, ld {}, dirty {}) disagree with N={n}",
            hd.n(),
            ld.n(),
            hd_dirty.len()
        ));
    }
    Ok(IterativeKnn { hd, ld, hd_dirty })
}

fn decode_aff(payload: &[u8], n: usize, k_hd: usize) -> Result<Affinities, String> {
    let mut r = Reader::new(payload, "AFF");
    let an = r.get_usize()?;
    let ak = r.get_usize()?;
    if an != n {
        return Err(format!("AFF: row count {an} disagrees with N={n}"));
    }
    if ak != k_hd {
        return Err(format!("AFF: k={ak} disagrees with the HD table's k={k_hd}"));
    }
    let slots = an
        .checked_mul(ak)
        .ok_or_else(|| "AFF: dims overflow".to_string())?;
    let p = r.get_f32s(slots)?;
    let beta = r.get_f32s(an)?;
    let achieved = r.get_f32s(an)?;
    r.finish()?;
    Affinities::from_raw(ak, p, beta, achieved).map_err(|e| format!("AFF: {e}"))
}

type RngOut = (([u64; 4], Option<u64>), (f64, f64, bool), (f64, f64, bool), f64);

fn decode_rng(payload: &[u8]) -> Result<RngOut, String> {
    let mut r = Reader::new(payload, "RNG");
    let s = [r.get_u64()?, r.get_u64()?, r.get_u64()?, r.get_u64()?];
    let spare = if r.get_bool()? { Some(r.get_u64()?) } else { None };
    let mut ewmas = [(0f64, 0f64, false); 2];
    for e in &mut ewmas {
        *e = (r.get_f64()?, r.get_f64()?, r.get_bool()?);
    }
    let covered_avg = r.get_f64()?;
    r.finish()?;
    Ok(((s, spare), ewmas[0], ewmas[1], covered_avg))
}

type ExtrasOut = (CandidateRoutes, Option<Matrix>, Option<Vec<u32>>, Option<Pca>);

fn decode_extras(
    payload: &[u8],
    n: usize,
    cfg: &EmbedConfig,
    x: &Matrix,
) -> Result<ExtrasOut, String> {
    let mut r = Reader::new(payload, "EXTRAS");
    let bits = r.get_u8()?;
    if bits & !0b111 != 0 {
        return Err(format!("EXTRAS: invalid route bits 0b{bits:b}"));
    }
    let routes = CandidateRoutes {
        same_space: bits & 0b001 != 0,
        cross_space: bits & 0b010 != 0,
        random: bits & 0b100 != 0,
    };
    if !(routes.same_space || routes.cross_space || routes.random) {
        return Err("EXTRAS: no candidate route enabled".into());
    }
    let jumpstart_target = if r.get_bool()? {
        let m = get_matrix(&mut r)?;
        if m.n() != n || m.d() != cfg.ld_dim {
            return Err(format!(
                "EXTRAS: jumpstart target {}x{} disagrees with {n}x{}",
                m.n(),
                m.d(),
                cfg.ld_dim
            ));
        }
        Some(m)
    } else {
        None
    };
    let probe_anchors = if r.get_bool()? {
        let count = r.get_usize()?;
        let ids = r.get_u32s(count)?;
        if ids.iter().any(|&id| id as usize >= n) {
            return Err(format!("EXTRAS: probe anchor out of range (N={n})"));
        }
        Some(ids)
    } else {
        None
    };
    let pca = if r.get_bool()? {
        let components = get_matrix(&mut r)?;
        let mc = r.get_usize()?;
        let means = r.get_f32s(mc)?;
        let ec = r.get_usize()?;
        let explained = {
            let bytes = ec
                .checked_mul(8)
                .ok_or_else(|| "EXTRAS: explained length overflow".to_string())?;
            r.need(bytes)?;
            let mut out = Vec::with_capacity(ec);
            for _ in 0..ec {
                out.push(r.get_f64()?);
            }
            out
        };
        if means.len() != components.d() {
            return Err(format!(
                "EXTRAS: PCA means length {} disagrees with component width {}",
                means.len(),
                components.d()
            ));
        }
        if components.n() != x.d() {
            return Err(format!(
                "EXTRAS: PCA output dim {} disagrees with the stored data width {}",
                components.n(),
                x.d()
            ));
        }
        Some(Pca { components, means, explained })
    } else {
        None
    };
    r.finish()?;
    Ok((routes, jumpstart_target, probe_anchors, pca))
}

// ----------------------------------------------------------- file I/O

/// Write `bytes` to `path` atomically: temp file, fsync, rename,
/// best-effort directory fsync. Returns the byte count written. On a
/// non-crash failure the temp file is removed; a simulated crash
/// (failpoint) leaves whatever a real crash would.
pub fn save_atomic(path: &Path, bytes: &[u8]) -> io::Result<u64> {
    let tmp = tmp_path(path);
    let res = publish(path, &tmp, bytes);
    if let Err(e) = &res {
        if !failpoint::is_crash(e) {
            let _ = fs::remove_file(&tmp);
        }
    }
    res
}

/// The sibling temp file a snapshot is staged in before the rename.
pub fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    std::path::PathBuf::from(os)
}

fn publish(path: &Path, tmp: &Path, bytes: &[u8]) -> io::Result<u64> {
    match failpoint::hit("snapshot.write") {
        Some(FailAction::Error) => return Err(failpoint::io_error("snapshot.write")),
        Some(FailAction::Torn) => {
            // Model a power cut mid-write: half the image reaches the
            // temp file, then the operation dies.
            let mut f = fs::File::create(tmp)?;
            f.write_all(&bytes[..bytes.len() / 2])?;
            let _ = f.sync_all();
            return Err(failpoint::io_error("snapshot.write[torn]"));
        }
        Some(FailAction::Crash) => return Err(failpoint::crash_error("snapshot.write")),
        None => {}
    }
    let mut f = fs::File::create(tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    match failpoint::hit("snapshot.rename") {
        Some(FailAction::Crash) => return Err(failpoint::crash_error("snapshot.rename")),
        Some(_) => return Err(failpoint::io_error("snapshot.rename")),
        None => {}
    }
    fs::rename(tmp, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(bytes.len() as u64)
}

/// Read and decode the snapshot at `path`.
pub fn load(path: &Path) -> Result<SessionState, String> {
    let bytes = fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    decode(&bytes)
}
