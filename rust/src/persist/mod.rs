//! Durable sessions: snapshots, write-ahead command logs, and
//! bitwise-exact crash recovery.
//!
//! The engine's counter-based RNG streams make a session's future a
//! pure function of (state, seed, iteration) — so durability reduces
//! to two artifacts per session, kept in the server's `--state-dir`:
//!
//! * `session-<id>.snap` — a complete point-in-time image
//!   ([`snapshot`]), atomically published via temp-file + rename;
//! * `session-<id>.wal` — the commands drained since that image
//!   ([`wal`]), each fsynced *before* it is applied.
//!
//! Restore ([`restore_session`]) loads the snapshot, then re-drives
//! the session through the logged command drains at their recorded
//! iterations. Because stepping is deterministic and command
//! validation is pure, the recovered trajectory is bitwise-identical
//! to the uninterrupted one — the property the crash-recovery tests
//! assert at multiple thread counts, under [`failpoint`]-injected I/O
//! errors, torn writes and simulated crashes.
//!
//! Formats, CRC coverage and the atomic-publish protocol are
//! documented byte-by-byte in `docs/persistence.md`.

mod codec;
pub mod failpoint;
pub mod snapshot;
pub mod wal;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::session::Session;

/// The durable artifacts of one session.
pub struct SessionPaths {
    pub snap: PathBuf,
    pub wal: PathBuf,
}

/// On-disk layout: `<dir>/session-<id>.snap` + `<dir>/session-<id>.wal`.
pub fn session_paths(dir: &Path, id: u64) -> SessionPaths {
    SessionPaths {
        snap: dir.join(format!("session-{id}.snap")),
        wal: dir.join(format!("session-{id}.wal")),
    }
}

/// Checkpoint a session: export, encode, publish atomically, then
/// truncate the WAL (its records are folded into the image; sequence
/// numbering continues). Returns the snapshot size in bytes.
///
/// On failure the session is untouched except for its WAL health flag
/// — it keeps stepping, and a later checkpoint can heal it. A crash
/// between the snapshot rename and the WAL truncation is harmless:
/// replay skips records at or below the image's sequence floor.
pub fn checkpoint_session(session: &mut Session, paths: &SessionPaths) -> Result<u64> {
    let st = session.export_state();
    let bytes = snapshot::encode(&st);
    snapshot::save_atomic(&paths.snap, &bytes)
        .map_err(|e| anyhow!("publish {}: {e}", paths.snap.display()))?;
    match wal::WalWriter::create(&paths.wal, session.wal_next_seq()) {
        Ok(w) => session.set_wal(Some(w)),
        Err(e) => {
            let msg = format!("could not recreate {}: {e}", paths.wal.display());
            session.mark_wal_broken(msg.clone());
            bail!("snapshot published but {msg}");
        }
    }
    Ok(bytes.len() as u64)
}

/// A session brought back from disk.
pub struct Restored {
    pub session: Session,
    /// WAL records replayed on top of the snapshot.
    pub replayed: usize,
    /// Torn-tail report from the WAL scan, if the log did not end
    /// cleanly (the valid prefix was still replayed).
    pub wal_warning: Option<String>,
}

/// Restore one session: load + validate the snapshot, replay the WAL
/// tail at its recorded drain iterations, then reattach a compacted
/// log for future appends.
pub fn restore_session(paths: &SessionPaths, artifact_dir: &Path) -> Result<Restored> {
    let st = snapshot::load(&paths.snap).map_err(|e| anyhow!("{e}"))?;
    let floor = st.wal_seq;
    let mut session = Session::from_state(st, artifact_dir)?;
    let rd = wal::read(&paths.wal).map_err(|e| anyhow!("{}: {e}", paths.wal.display()))?;

    // Replay the tail: group contiguous records by drain iteration and
    // re-drive the session through the same boundaries. Draining a
    // batch in one step is equivalent to the live run's possibly
    // multiple drains at that iteration — no engine step separated
    // them, and per-command validation sees the same state in the same
    // order.
    let tail: Vec<&wal::WalRecord> = rd.records.iter().filter(|r| r.seq > floor).collect();
    let mut i = 0usize;
    while i < tail.len() {
        let target = tail[i].iter;
        if target < session.iterations() as u64 {
            bail!(
                "WAL record {} drains at iteration {target}, behind the session ({}): \
                 log and snapshot disagree",
                tail[i].seq,
                session.iterations()
            );
        }
        while (session.iterations() as u64) < target {
            if !session.step()? {
                bail!(
                    "WAL replay stalled: session paused at iteration {} but the log \
                     continues at {target}",
                    session.iterations()
                );
            }
        }
        while i < tail.len() && tail[i].iter == target {
            session.enqueue(tail[i].cmd.clone());
            i += 1;
        }
        // Drain the batch exactly at `target` (and take the step that
        // followed it live, unless the batch left the session paused).
        session.step()?;
    }
    let replayed = tail.len();
    let last_seq = rd.records.last().map(|r| r.seq).unwrap_or(0).max(floor);
    session.set_wal_seq(last_seq);

    // Reattach a writer over the valid prefix only, so any torn tail
    // is excised before new records land behind it.
    let w = wal::WalWriter::rewrite(&paths.wal, &rd.records, last_seq + 1)
        .map_err(|e| anyhow!("reattach {}: {e}", paths.wal.display()))?;
    session.set_wal(Some(w));
    Ok(Restored { session, replayed, wal_warning: rd.warning })
}

/// A state file the boot scan could not restore. The file is left in
/// place for post-mortem inspection; the server reports and continues.
pub struct SkippedState {
    pub path: PathBuf,
    pub reason: String,
}

/// Everything a boot scan recovered (sessions in ascending id order)
/// and everything it had to skip: corrupt or unreadable snapshots,
/// and orphaned WALs with no snapshot beside them.
pub struct BootRestore {
    pub sessions: Vec<(u64, Restored)>,
    pub skipped: Vec<SkippedState>,
}

/// Restore every session under `state_dir`. Never fails the boot: a
/// corrupt or orphaned state file is skipped and reported, and the
/// remaining sessions come up normally.
pub fn restore_all(state_dir: &Path, artifact_dir: &Path) -> BootRestore {
    let mut out = BootRestore { sessions: Vec::new(), skipped: Vec::new() };
    let entries = match fs::read_dir(state_dir) {
        Ok(e) => e,
        Err(_) => return out,
    };
    let mut snap_ids = Vec::new();
    let mut wal_ids = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(id) = parse_state_name(name, ".snap") {
            snap_ids.push(id);
        } else if let Some(id) = parse_state_name(name, ".wal") {
            wal_ids.push(id);
        }
    }
    snap_ids.sort_unstable();
    for &id in &snap_ids {
        let paths = session_paths(state_dir, id);
        match restore_session(&paths, artifact_dir) {
            Ok(r) => out.sessions.push((id, r)),
            Err(e) => {
                out.skipped.push(SkippedState { path: paths.snap, reason: e.to_string() })
            }
        }
    }
    wal_ids.sort_unstable();
    for id in wal_ids {
        if snap_ids.binary_search(&id).is_err() {
            out.skipped.push(SkippedState {
                path: session_paths(state_dir, id).wal,
                reason: "orphaned WAL with no snapshot beside it".to_string(),
            });
        }
    }
    out
}

fn parse_state_name(name: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix("session-")?.strip_suffix(suffix)?.parse().ok()
}

/// Remove a session's durable files (and any temp debris) — the
/// `DELETE /sessions/:id` and session-replacement paths. Missing files
/// are fine; other I/O errors surface.
pub fn remove_session_files(paths: &SessionPaths) -> io::Result<()> {
    for p in [
        &paths.snap,
        &paths.wal,
        &snapshot::tmp_path(&paths.snap),
        &snapshot::tmp_path(&paths.wal),
    ] {
        match fs::remove_file(p) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::NotFound => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}
