//! Per-session write-ahead command log.
//!
//! Every command a session drains is appended here — with the
//! iteration it was drained at — *before* the session applies it.
//! Because the engine's trajectory is a pure function of (state, seed,
//! iteration) and command validation is deterministic, replaying the
//! log against the matching snapshot reproduces the interrupted run
//! bit for bit: accepted commands are re-accepted, rejected ones
//! re-rejected, in the same order at the same iterations.
//!
//! # Wire format (version 1, all integers little-endian)
//!
//! ```text
//! header  := magic "FWAL" | version u8 | reserved u8×3
//! record  := payload_len u32 | crc32(payload) u32 | payload
//! payload := seq u64 | iter u64 | tag u8 | body
//! ```
//!
//! Command tags 0–10 mirror [`Command`]'s variants in declaration
//! order. Sequence numbers are per-session, monotone, and never reused
//! — a snapshot records the last sequence folded into it, and replay
//! skips everything at or below that mark, so a crash between a
//! snapshot's rename and the log truncation that follows it is
//! harmless.
//!
//! # Torn tails
//!
//! Reads have *valid-prefix* semantics: the first record whose header
//! is short, whose payload is truncated, whose CRC disagrees, or whose
//! sequence number is not strictly increasing ends the log. Everything
//! before it is trusted (each record was fsynced before the command it
//! describes was applied); everything after it is reported, not
//! replayed. The append path carries a `wal.append` failpoint that can
//! simulate exactly these torn tails.

use std::fs;
use std::io::{self, Write};
use std::path::Path;

use crate::data::Matrix;
use crate::knn::iterative::CandidateRoutes;
use crate::session::Command;

use super::codec::{crc32, put_f32, put_f64, put_u32, put_u64, put_usize, Reader};
use super::failpoint::{self, FailAction};

/// File magic: "FUnc-SNE Write-Ahead Log".
pub const MAGIC: [u8; 4] = *b"FWAL";
/// Current log version. Readers reject anything newer.
pub const VERSION: u8 = 1;

const HEADER_LEN: usize = 8;
/// Record header: payload length u32 + payload CRC u32.
const RECORD_HEADER_LEN: usize = 8;

/// One logged command.
#[derive(Clone, Debug)]
pub struct WalRecord {
    /// Per-session monotone sequence number (starts at 1).
    pub seq: u64,
    /// Engine iteration the command was drained at.
    pub iter: u64,
    pub cmd: Command,
}

/// Result of scanning a log file: the valid prefix, plus a description
/// of the torn tail if the scan stopped early.
pub struct WalRead {
    pub records: Vec<WalRecord>,
    pub warning: Option<String>,
}

/// Append handle for one session's log. Every append is fsynced before
/// it returns — the caller only applies a command once its record is
/// durable.
pub struct WalWriter {
    file: fs::File,
    next_seq: u64,
}

impl WalWriter {
    /// Create (or truncate to) an empty log whose next record will be
    /// `next_seq`. Used at session creation (`next_seq = 1`) and after
    /// every successful snapshot publish (sequence numbering
    /// continues; the old records are folded into the snapshot).
    pub fn create(path: &Path, next_seq: u64) -> io::Result<WalWriter> {
        let mut file =
            fs::OpenOptions::new().write(true).create(true).truncate(true).open(path)?;
        let mut header = Vec::with_capacity(HEADER_LEN);
        header.extend_from_slice(&MAGIC);
        header.push(VERSION);
        header.extend_from_slice(&[0u8; 3]);
        file.write_all(&header)?;
        file.sync_all()?;
        Ok(WalWriter { file, next_seq: next_seq.max(1) })
    }

    /// Atomically rewrite the log to contain exactly `records` (the
    /// valid prefix a restore trusted), then reopen it for appending.
    /// This discards any torn tail so later appends never land behind
    /// garbage that would mask them from the next scan.
    pub fn rewrite(path: &Path, records: &[WalRecord], next_seq: u64) -> io::Result<WalWriter> {
        let tmp = super::snapshot::tmp_path(path);
        {
            let mut bytes = Vec::new();
            bytes.extend_from_slice(&MAGIC);
            bytes.push(VERSION);
            bytes.extend_from_slice(&[0u8; 3]);
            for rec in records {
                bytes.extend_from_slice(&encode_record(rec));
            }
            let mut f = fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)?;
        let file = fs::OpenOptions::new().append(true).open(path)?;
        let floor = records.last().map(|r| r.seq + 1).unwrap_or(1);
        Ok(WalWriter { file, next_seq: next_seq.max(floor) })
    }

    /// The sequence number the next append will use.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Durably append one command, returning its sequence number. On
    /// error nothing was logged (or only a torn fragment was) and the
    /// caller must NOT apply the command — an applied-but-unlogged
    /// command would diverge from what a restore replays.
    pub fn append(&mut self, iter: u64, cmd: &Command) -> io::Result<u64> {
        let seq = self.next_seq;
        let rec = encode_record(&WalRecord { seq, iter, cmd: cmd.clone() });
        match failpoint::hit("wal.append") {
            Some(FailAction::Error) => return Err(failpoint::io_error("wal.append")),
            Some(FailAction::Torn) => {
                // Write a fragment and die: the scan must stop here.
                self.file.write_all(&rec[..rec.len() / 2])?;
                let _ = self.file.sync_all();
                return Err(failpoint::io_error("wal.append[torn]"));
            }
            Some(FailAction::Crash) => return Err(failpoint::crash_error("wal.append")),
            None => {}
        }
        self.file.write_all(&rec)?;
        self.file.sync_all()?;
        self.next_seq = seq + 1;
        Ok(seq)
    }
}

fn encode_record(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    put_u64(&mut payload, rec.seq);
    put_u64(&mut payload, rec.iter);
    encode_command(&mut payload, &rec.cmd);
    let mut out = Vec::with_capacity(RECORD_HEADER_LEN + payload.len());
    put_u32(&mut out, payload.len() as u32);
    put_u32(&mut out, crc32(&payload));
    out.extend_from_slice(&payload);
    out
}

fn encode_command(out: &mut Vec<u8>, cmd: &Command) {
    match cmd {
        Command::SetAlpha(v) => {
            out.push(0);
            put_f64(out, *v);
        }
        Command::SetPerplexity(v) => {
            out.push(1);
            put_f64(out, *v);
        }
        Command::SetAttraction(v) => {
            out.push(2);
            put_f64(out, *v);
        }
        Command::SetRepulsion(v) => {
            out.push(3);
            put_f64(out, *v);
        }
        Command::SetRoutes(r) => {
            out.push(4);
            out.push((r.same_space as u8) | ((r.cross_space as u8) << 1) | ((r.random as u8) << 2));
        }
        Command::InsertPoints(m) => {
            out.push(5);
            put_usize(out, m.n());
            put_usize(out, m.d());
            for &v in m.data() {
                put_f32(out, v);
            }
        }
        Command::RemovePoint(i) => {
            out.push(6);
            put_usize(out, *i);
        }
        Command::MovePoint(i, row) => {
            out.push(7);
            put_usize(out, *i);
            put_usize(out, row.len());
            for &v in row {
                put_f32(out, v);
            }
        }
        Command::Implode => out.push(8),
        Command::Pause => out.push(9),
        Command::Resume => out.push(10),
    }
}

fn decode_command(r: &mut Reader<'_>) -> Result<Command, String> {
    let cmd = match r.get_u8()? {
        0 => Command::SetAlpha(r.get_f64()?),
        1 => Command::SetPerplexity(r.get_f64()?),
        2 => Command::SetAttraction(r.get_f64()?),
        3 => Command::SetRepulsion(r.get_f64()?),
        4 => {
            let bits = r.get_u8()?;
            if bits & !0b111 != 0 {
                return Err(format!("invalid route bits 0b{bits:b}"));
            }
            Command::SetRoutes(CandidateRoutes {
                same_space: bits & 0b001 != 0,
                cross_space: bits & 0b010 != 0,
                random: bits & 0b100 != 0,
            })
        }
        5 => {
            let n = r.get_usize()?;
            let d = r.get_usize()?;
            let len = n.checked_mul(d).ok_or_else(|| "matrix dims overflow".to_string())?;
            let data = r.get_f32s(len)?;
            Command::InsertPoints(Matrix::from_vec(data, n, d).map_err(|e| e.to_string())?)
        }
        6 => Command::RemovePoint(r.get_usize()?),
        7 => {
            let i = r.get_usize()?;
            let len = r.get_usize()?;
            Command::MovePoint(i, r.get_f32s(len)?)
        }
        8 => Command::Implode,
        9 => Command::Pause,
        10 => Command::Resume,
        t => return Err(format!("unknown command tag {t}")),
    };
    Ok(cmd)
}

/// Scan the log at `path` with valid-prefix semantics. A missing file
/// is an empty log; a file that is not a WAL at all (bad magic or a
/// future version) is a hard error.
pub fn read(path: &Path) -> Result<WalRead, String> {
    let bytes = match fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            return Ok(WalRead { records: Vec::new(), warning: None })
        }
        Err(e) => return Err(format!("read {}: {e}", path.display())),
    };
    if bytes.len() < HEADER_LEN {
        // A crash during creation can leave a short header; there can
        // be no durable records in such a file.
        return Ok(WalRead {
            records: Vec::new(),
            warning: Some(format!("log header truncated ({} bytes)", bytes.len())),
        });
    }
    if bytes[0..4] != MAGIC {
        return Err("bad WAL magic (not an FWAL file)".into());
    }
    if bytes[4] != VERSION {
        return Err(format!("unsupported WAL version {} (expected {VERSION})", bytes[4]));
    }
    let mut records = Vec::new();
    let mut warning = None;
    let mut pos = HEADER_LEN;
    let mut last_seq = 0u64;
    while pos < bytes.len() {
        let Some((record, end)) = scan_record(&bytes, pos, last_seq, &mut warning) else {
            break;
        };
        last_seq = record.seq;
        records.push(record);
        pos = end;
    }
    Ok(WalRead { records, warning })
}

/// Decode the record starting at `pos`, or set `warning` and return
/// `None` where the valid prefix ends.
fn scan_record(
    bytes: &[u8],
    pos: usize,
    last_seq: u64,
    warning: &mut Option<String>,
) -> Option<(WalRecord, usize)> {
    let nrec = |msg: String| -> Option<(WalRecord, usize)> {
        *warning = Some(msg);
        None
    };
    if bytes.len() - pos < RECORD_HEADER_LEN {
        return nrec(format!("torn record header at byte {pos}"));
    }
    let mut b4 = [0u8; 4];
    b4.copy_from_slice(&bytes[pos..pos + 4]);
    let len = u32::from_le_bytes(b4) as usize;
    b4.copy_from_slice(&bytes[pos + 4..pos + 8]);
    let stored_crc = u32::from_le_bytes(b4);
    let start = pos + RECORD_HEADER_LEN;
    let end = match start.checked_add(len) {
        Some(e) if e <= bytes.len() => e,
        _ => return nrec(format!("torn record payload at byte {pos}")),
    };
    let payload = &bytes[start..end];
    if crc32(payload) != stored_crc {
        return nrec(format!("record CRC mismatch at byte {pos}"));
    }
    let mut r = Reader::new(payload, "WAL record");
    let parsed: Result<WalRecord, String> = (|| {
        let seq = r.get_u64()?;
        let iter = r.get_u64()?;
        let cmd = decode_command(&mut r)?;
        Ok(WalRecord { seq, iter, cmd })
    })();
    let record = match parsed {
        Ok(rec) => rec,
        Err(e) => return nrec(format!("undecodable record at byte {pos}: {e}")),
    };
    if let Err(e) = r.finish() {
        return nrec(format!("undecodable record at byte {pos}: {e}"));
    }
    if record.seq <= last_seq {
        return nrec(format!(
            "non-monotone sequence {} after {} at byte {pos}",
            record.seq, last_seq
        ));
    }
    Some((record, end))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("funcsne_wal_test_{}_{name}", std::process::id()));
        p
    }

    fn sample_commands() -> Vec<Command> {
        vec![
            Command::SetAlpha(1.5),
            Command::SetRoutes(CandidateRoutes { same_space: true, cross_space: false, random: true }),
            Command::InsertPoints(Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2).unwrap()),
            Command::MovePoint(3, vec![0.5, -0.5]),
            Command::RemovePoint(7),
            Command::Implode,
            Command::Pause,
            Command::Resume,
            Command::SetPerplexity(12.0),
            Command::SetAttraction(0.7),
            Command::SetRepulsion(1.3),
        ]
    }

    #[test]
    fn wal_round_trips_every_command_variant() {
        let path = tmp("wal_roundtrip.wal");
        let mut w = WalWriter::create(&path, 1).unwrap();
        for (i, cmd) in sample_commands().iter().enumerate() {
            let seq = w.append(10 + i as u64, cmd).unwrap();
            assert_eq!(seq, i as u64 + 1);
        }
        let rd = read(&path).unwrap();
        assert!(rd.warning.is_none());
        assert_eq!(rd.records.len(), sample_commands().len());
        for (i, (rec, cmd)) in rd.records.iter().zip(sample_commands()).enumerate() {
            assert_eq!(rec.seq, i as u64 + 1);
            assert_eq!(rec.iter, 10 + i as u64);
            assert_eq!(format!("{:?}", rec.cmd), format!("{cmd:?}"));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_yields_valid_prefix() {
        let path = tmp("wal_torn.wal");
        let mut w = WalWriter::create(&path, 1).unwrap();
        for cmd in sample_commands().iter().take(4) {
            w.append(1, cmd).unwrap();
        }
        drop(w);
        let full = std::fs::read(&path).unwrap();
        // Chop the file mid-way through the final record.
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let rd = read(&path).unwrap();
        assert_eq!(rd.records.len(), 3);
        assert!(rd.warning.is_some(), "torn tail must be reported");

        // Corrupt a payload byte of the third record: prefix shrinks to 2.
        let mut corrupt = full.clone();
        let third_start = {
            // Walk two records forward from the header.
            let mut pos = 8usize;
            for _ in 0..2 {
                let mut b4 = [0u8; 4];
                b4.copy_from_slice(&corrupt[pos..pos + 4]);
                pos += 8 + u32::from_le_bytes(b4) as usize;
            }
            pos
        };
        corrupt[third_start + 9] ^= 0xFF;
        std::fs::write(&path, &corrupt).unwrap();
        let rd = read(&path).unwrap();
        assert_eq!(rd.records.len(), 2);
        assert!(rd.warning.unwrap().contains("CRC"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_log_is_empty_but_foreign_files_are_rejected() {
        let path = tmp("wal_missing.wal");
        let _ = std::fs::remove_file(&path);
        let rd = read(&path).unwrap();
        assert!(rd.records.is_empty() && rd.warning.is_none());

        std::fs::write(&path, b"not a wal at all").unwrap();
        assert!(read(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rewrite_discards_tail_and_continues_sequencing() {
        let path = tmp("wal_rewrite.wal");
        let mut w = WalWriter::create(&path, 1).unwrap();
        for cmd in sample_commands().iter().take(3) {
            w.append(2, cmd).unwrap();
        }
        drop(w);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let rd = read(&path).unwrap();
        assert_eq!(rd.records.len(), 2);

        let mut w = WalWriter::rewrite(&path, &rd.records, 3).unwrap();
        let seq = w.append(5, &Command::Implode).unwrap();
        assert_eq!(seq, 3);
        drop(w);
        let rd = read(&path).unwrap();
        assert!(rd.warning.is_none());
        assert_eq!(rd.records.len(), 3);
        assert_eq!(rd.records[2].seq, 3);
        let _ = std::fs::remove_file(&path);
    }
}
