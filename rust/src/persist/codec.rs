//! Little-endian primitives shared by the snapshot and WAL codecs:
//! byte putters, a strict bounds-checked [`Reader`], and the IEEE
//! CRC32 both formats frame their payloads with.

/// IEEE CRC32 lookup table, built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// IEEE CRC32 (the zlib/PNG polynomial), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(v as u8);
}

/// Strict sequential reader over one framed payload. Every getter is
/// bounds-checked; bulk getters verify the remaining length *before*
/// allocating, so corrupt length prefixes cannot balloon memory.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Frame name, for error attribution.
    what: &'static str,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8], what: &'static str) -> Reader<'a> {
        Reader { buf, pos: 0, what }
    }

    pub fn what(&self) -> &'static str {
        self.what
    }

    pub fn need(&self, n: usize) -> Result<(), String> {
        if self.buf.len() - self.pos < n {
            return Err(format!("{} truncated", self.what));
        }
        Ok(())
    }

    pub fn get_u8(&mut self) -> Result<u8, String> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    pub fn get_bool(&mut self) -> Result<bool, String> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("{}: invalid bool byte {v}", self.what)),
        }
    }

    pub fn get_u32(&mut self) -> Result<u32, String> {
        self.need(4)?;
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        self.pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    pub fn get_u64(&mut self) -> Result<u64, String> {
        self.need(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    pub fn get_usize(&mut self) -> Result<usize, String> {
        let v = self.get_u64()?;
        usize::try_from(v).map_err(|_| format!("{}: value {v} overflows usize", self.what))
    }

    pub fn get_f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.get_u32()?.to_le_bytes()))
    }

    pub fn get_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.get_u64()?.to_le_bytes()))
    }

    /// Read `n` f32 values (length-checked before allocating).
    pub fn get_f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| format!("{}: f32 array length overflow", self.what))?;
        self.need(bytes)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f32()?);
        }
        Ok(out)
    }

    /// Read `n` u32 values (length-checked before allocating).
    pub fn get_u32s(&mut self, n: usize) -> Result<Vec<u32>, String> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| format!("{}: u32 array length overflow", self.what))?;
        self.need(bytes)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u32()?);
        }
        Ok(out)
    }

    /// Every payload byte must be consumed — leftovers mean the writer
    /// and reader disagree about the format.
    pub fn finish(self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!(
                "{}: {} unread trailing bytes",
                self.what,
                self.buf.len() - self.pos
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard IEEE CRC32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn reader_round_trips_and_rejects_overruns() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 42);
        put_f64(&mut buf, -0.5);
        put_bool(&mut buf, true);
        put_f32(&mut buf, 1.25);
        let mut r = Reader::new(&buf, "test");
        assert_eq!(r.get_u64().unwrap(), 42);
        assert_eq!(r.get_f64().unwrap(), -0.5);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_f32().unwrap(), 1.25);
        assert!(r.get_u8().is_err());
        r.finish().unwrap();

        let mut r = Reader::new(&buf, "test");
        let _ = r.get_u64().unwrap();
        assert!(r.finish().is_err());

        let mut r = Reader::new(&[2u8], "test");
        assert!(r.get_bool().is_err());
    }
}
