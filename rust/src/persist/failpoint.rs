//! Fault injection for the durability layer.
//!
//! A *failpoint* is a named hook compiled into the persistence I/O
//! paths (`snapshot.write`, `snapshot.rename`, `wal.append`). When a
//! hook is armed it forces a failure — an injected I/O error, a torn
//! (short) write, or a simulated crash that abandons the operation
//! without cleanup — so the recovery test suite and CI smoke jobs can
//! exercise every corruption mode the codecs claim to survive.
//!
//! Unarmed, the whole facility costs one relaxed atomic load per hook:
//! there is no registry lookup, no lock, no allocation. Hooks are
//! armed either programmatically ([`arm`]) from tests or from the
//! `FUNCSNE_FAILPOINTS` environment variable (parsed once, on the
//! first [`init_from_env`] call):
//!
//! ```text
//! FUNCSNE_FAILPOINTS="snapshot.rename=crash;wal.append=torn:2"
//! ```
//!
//! Each entry is `name=action` with an optional `:count` suffix
//! limiting how many times it fires before auto-disarming. Actions are
//! `error`, `torn` and `crash`.

use std::collections::BTreeMap;
use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use crate::runtime::sync::DebugMutex;

/// What an armed failpoint does when hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailAction {
    /// Fail the operation with an injected `io::Error`.
    Error,
    /// Write only a prefix of the payload, then fail — models a power
    /// cut or full disk mid-write.
    Torn,
    /// Abandon the operation exactly where a crash would: no error
    /// cleanup runs, temp-file debris stays on disk.
    Crash,
}

struct Entry {
    action: FailAction,
    /// Remaining firings; `None` means unlimited.
    remaining: Option<u32>,
}

/// Fast-path flag: `false` whenever the registry is empty, so unarmed
/// hooks never touch the lock.
static ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static DebugMutex<BTreeMap<String, Entry>> {
    static REGISTRY: OnceLock<DebugMutex<BTreeMap<String, Entry>>> = OnceLock::new();
    REGISTRY.get_or_init(|| DebugMutex::new("persist.failpoints", BTreeMap::new()))
}

/// Arm failpoint `name`. `count` limits how many times it fires
/// (`Some(0)` is ignored); `None` fires until [`disarm`]ed.
pub fn arm(name: &str, action: FailAction, count: Option<u32>) {
    if count == Some(0) {
        return;
    }
    let mut reg = registry().lock();
    reg.insert(name.to_string(), Entry { action, remaining: count });
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm failpoint `name` (no-op when not armed).
pub fn disarm(name: &str) {
    let mut reg = registry().lock();
    reg.remove(name);
    if reg.is_empty() {
        ARMED.store(false, Ordering::Relaxed);
    }
}

/// Disarm every failpoint.
pub fn clear() {
    let mut reg = registry().lock();
    reg.clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Consult failpoint `name`. Returns the action to simulate, or `None`
/// (the overwhelmingly common case — one relaxed load, no lock).
pub fn hit(name: &str) -> Option<FailAction> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut reg = registry().lock();
    let (action, exhausted) = match reg.get_mut(name) {
        None => return None,
        Some(e) => {
            let exhausted = match e.remaining.as_mut() {
                Some(r) => {
                    *r = r.saturating_sub(1);
                    *r == 0
                }
                None => false,
            };
            (e.action, exhausted)
        }
    };
    if exhausted {
        reg.remove(name);
        if reg.is_empty() {
            ARMED.store(false, Ordering::Relaxed);
        }
    }
    Some(action)
}

/// Parse `FUNCSNE_FAILPOINTS` once per process. Safe to call from
/// every entry point that performs durable I/O; only the first call
/// reads the environment. Invalid entries are reported to stderr and
/// skipped — a typo in a fault-injection variable must never take the
/// service down.
pub fn init_from_env() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        if let Ok(spec) = std::env::var("FUNCSNE_FAILPOINTS") {
            if let Err(e) = arm_from_spec(&spec) {
                eprintln!("funcsne: ignoring invalid FUNCSNE_FAILPOINTS: {e}");
            }
        }
    });
}

/// Arm failpoints from a spec string (`name=action[:count]`, entries
/// separated by `;`). Valid entries before an invalid one stay armed.
pub fn arm_from_spec(spec: &str) -> Result<(), String> {
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, rhs) = part
            .split_once('=')
            .ok_or_else(|| format!("`{part}`: expected name=action[:count]"))?;
        let (action_str, count) = match rhs.split_once(':') {
            Some((a, c)) => {
                let n = c
                    .parse::<u32>()
                    .map_err(|_| format!("`{part}`: count `{c}` is not a u32"))?;
                (a, Some(n))
            }
            None => (rhs, None),
        };
        let action = match action_str.trim() {
            "error" => FailAction::Error,
            "torn" => FailAction::Torn,
            "crash" => FailAction::Crash,
            other => {
                return Err(format!(
                    "`{part}`: unknown action `{other}` (expected error, torn or crash)"
                ))
            }
        };
        arm(name.trim(), action, count);
    }
    Ok(())
}

/// Prefix of injected (non-crash) I/O errors, so logs and tests can
/// tell injected failures from real ones.
pub const INJECTED_PREFIX: &str = "failpoint:";

/// Prefix of simulated-crash errors. Callers must propagate these
/// without running any cleanup, so on-disk state is exactly what a
/// real crash at that instant would leave.
pub const CRASH_PREFIX: &str = "failpoint-crash:";

/// An injected I/O error attributed to `name`.
pub fn io_error(name: &str) -> io::Error {
    io::Error::new(io::ErrorKind::Other, format!("{INJECTED_PREFIX} injected I/O error at `{name}`"))
}

/// A simulated crash at `name`.
pub fn crash_error(name: &str) -> io::Error {
    io::Error::new(io::ErrorKind::Other, format!("{CRASH_PREFIX} simulated crash at `{name}`"))
}

/// Is `e` a simulated crash (as opposed to an injected or real error)?
pub fn is_crash(e: &io::Error) -> bool {
    e.to_string().starts_with(CRASH_PREFIX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sync::DebugMutex;

    /// Failpoint state is process-global; serialize tests touching it.
    static GUARD: OnceLock<DebugMutex<()>> = OnceLock::new();

    fn serial() -> crate::runtime::sync::DebugMutexGuard<'static, ()> {
        GUARD.get_or_init(|| DebugMutex::new("persist.failpoint_tests", ())).lock()
    }

    #[test]
    fn unarmed_hooks_fire_nothing() {
        let _g = serial();
        clear();
        assert_eq!(hit("snapshot.write"), None);
    }

    #[test]
    fn counted_failpoint_auto_disarms() {
        let _g = serial();
        clear();
        arm("wal.append", FailAction::Torn, Some(2));
        assert_eq!(hit("wal.append"), Some(FailAction::Torn));
        assert_eq!(hit("wal.append"), Some(FailAction::Torn));
        assert_eq!(hit("wal.append"), None);
        assert!(!ARMED.load(Ordering::Relaxed));
    }

    #[test]
    fn spec_parsing_arms_and_rejects() {
        let _g = serial();
        clear();
        arm_from_spec("snapshot.rename=crash; wal.append=error:1").unwrap();
        assert_eq!(hit("snapshot.rename"), Some(FailAction::Crash));
        assert_eq!(hit("snapshot.rename"), Some(FailAction::Crash));
        assert_eq!(hit("wal.append"), Some(FailAction::Error));
        assert_eq!(hit("wal.append"), None);
        clear();

        assert!(arm_from_spec("nonsense").is_err());
        assert!(arm_from_spec("a=explode").is_err());
        assert!(arm_from_spec("a=torn:many").is_err());
        arm_from_spec("a=torn:0").unwrap();
        assert_eq!(hit("a"), None);
        clear();
    }

    #[test]
    fn crash_errors_are_distinguishable() {
        assert!(is_crash(&crash_error("x")));
        assert!(!is_crash(&io_error("x")));
    }
}
