//! Table 1 — quality of the repulsive-field approximation by range, for
//! the three strategies: negative sampling only (UMAP-style), modelling
//! the whole space (BH/FIt-SNE-style), and the proposed LD-neighbour +
//! negative-sampling hybrid.
//!
//! The paper states Table 1 qualitatively; here it is *measured*: on a
//! live embedding we compute the exact per-point repulsion restricted to
//! close-range pairs (the K_LD nearest in LD), medium-range pairs and
//! far pairs, then compare each strategy's estimate of those components
//! against the exact value (relative error, averaged over points).
//! "correct" ⇒ low error, "poor/none" ⇒ high.

use super::common::{self, Scale};
use crate::baselines::bhtsne::QuadTree;
use crate::data::datasets;
use crate::engine::FuncSne;
use crate::knn::brute::brute_knn;
use crate::ld::kernel::kernel_pair;
use crate::util::Rng;
use anyhow::Result;

/// Relative L2 error between an estimated and exact force component.
fn rel_err(est: &[f32], exact: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (e, x) in est.iter().zip(exact) {
        num += ((e - x) as f64).powi(2);
        den += (*x as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(600, 2000);
    let alpha = 1.0f32;
    let ds = datasets::blobs(n, 16, 6, 1.0, 15.0, 10);
    // A live, partially-converged embedding (realistic field geometry).
    let mut cfg = common::figure_config(n, 2, alpha as f64);
    cfg.n_iters = scale.pick(250, 600);
    let engine: FuncSne = common::run_funcsne(ds.x.clone(), &cfg)?;
    let y = engine.embedding().clone();

    // Range partition per point: close = K nearest in LD, far = beyond
    // the median LD distance, medium = in between.
    let k_close = 16usize;
    let ld_knn = brute_knn(&y, k_close);
    let mut rng = Rng::new(3);

    // Exact per-range repulsion components.
    let d = 2usize;
    let mut exact_close = vec![0.0f32; n * d];
    let mut exact_med = vec![0.0f32; n * d];
    let mut exact_far = vec![0.0f32; n * d];
    // median LD distance estimate from sampling
    let mut samp = Vec::with_capacity(2048);
    for _ in 0..2048 {
        let (i, j) = (rng.below(n), rng.below(n));
        if i != j {
            samp.push(y.sqdist(i, j));
        }
    }
    samp.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med2 = samp[samp.len() / 2];
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let d2 = y.sqdist(i, j);
            let (w, g) = kernel_pair(d2, alpha);
            let close = ld_knn.contains(i, j as u32);
            let target = if close {
                &mut exact_close
            } else if d2 < med2 {
                &mut exact_med
            } else {
                &mut exact_far
            };
            for c in 0..d {
                target[i * d + c] += w * g * (y.row(i)[c] - y.row(j)[c]);
            }
        }
    }

    // --- Strategy estimates, per range ---------------------------------
    // (1) negative sampling only: m uniform samples, rescaled to N−1;
    //     its close/med/far components are whatever the samples hit.
    let m = 8usize;
    let mut ns_close = vec![0.0f32; n * d];
    let mut ns_med = vec![0.0f32; n * d];
    let mut ns_far = vec![0.0f32; n * d];
    for i in 0..n {
        let scale_f = (n - 1) as f32 / m as f32;
        for _ in 0..m {
            let mut j = rng.below(n - 1);
            if j >= i {
                j += 1;
            }
            let d2 = y.sqdist(i, j);
            let (w, g) = kernel_pair(d2, alpha);
            let close = ld_knn.contains(i, j as u32);
            let target = if close {
                &mut ns_close
            } else if d2 < med2 {
                &mut ns_med
            } else {
                &mut ns_far
            };
            for c in 0..d {
                target[i * d + c] += scale_f * w * g * (y.row(i)[c] - y.row(j)[c]);
            }
        }
    }
    // (2) whole-space modelling (Barnes-Hut θ=0.5): compute the BH force
    //     restricted per range is not separable, so evaluate its *total*
    //     vs exact total and report the same number for all ranges
    //     (BH is uniformly accurate by construction).
    let tree = QuadTree::build(&y);
    let mut bh_total = vec![0.0f32; n * d];
    let mut exact_total = vec![0.0f32; n * d];
    for i in 0..n {
        let (fx, fy, _) = tree.repulsion(y.row(i)[0], y.row(i)[1], 0.5, alpha);
        bh_total[i * d] = fx;
        bh_total[i * d + 1] = fy;
        for c in 0..d {
            exact_total[i * d + c] =
                exact_close[i * d + c] + exact_med[i * d + c] + exact_far[i * d + c];
        }
    }
    // (3) proposed: exact close range via LD-neighbour slots + negative
    //     sampling for the rest (medium unmodelled beyond samples).
    let mut pr_close = vec![0.0f32; n * d];
    for i in 0..n {
        for j in ld_knn.neighbors(i) {
            let d2 = y.sqdist(i, *j as usize);
            let (w, g) = kernel_pair(d2, alpha);
            for c in 0..d {
                pr_close[i * d + c] += w * g * (y.row(i)[c] - y.row(*j as usize)[c]);
            }
        }
    }
    // proposed med/far = negative-sampling estimates (same as (1)).
    let bh_err = rel_err(&bh_total, &exact_total);
    let rows = vec![
        vec![
            "Negative sampling only".into(),
            fmt_q(rel_err(&ns_close, &exact_close)),
            fmt_q(rel_err(&ns_med, &exact_med)),
            fmt_q(rel_err(&ns_far, &exact_far)),
        ],
        vec![
            "Modelling the whole space (BH)".into(),
            fmt_q(bh_err),
            fmt_q(bh_err),
            fmt_q(bh_err),
        ],
        vec![
            "Proposed (LD-KNN + neg sampling)".into(),
            fmt_q(rel_err(&pr_close, &exact_close)),
            fmt_q(rel_err(&ns_med, &exact_med)),
            fmt_q(rel_err(&ns_far, &exact_far)),
        ],
    ];
    let mut summary = String::from(
        "=== Table 1: repulsive-field relative error by range (lower = \"correct\") ===\n",
    );
    summary.push_str(&common::format_table(
        &["strategy", "close range", "medium range", "far away"],
        &rows,
    ));
    summary.push_str(
        "\npaper-shape check: neg-sampling poor at close range; BH uniformly good; proposed good at close+far.\n",
    );
    common::record_csv("table1_repulsion", &["strategy", "close", "medium", "far"], &rows)?;
    common::record("table1_repulsion", &summary)?;
    Ok(summary)
}

fn fmt_q(err: f64) -> String {
    let label = if err < 0.25 {
        "correct"
    } else if err < 0.8 {
        "mediocre"
    } else {
        "poor/none"
    };
    format!("{err:.2} ({label})")
}
