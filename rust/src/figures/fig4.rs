//! Figure 4 — the positive feedback loop: quality of the estimated HD
//! KNN sets over iterations, with a *fixed* embedding (no feedback)
//! vs an embedding updated by gradient descent, at LD dim 2 and 8.
//!
//! Paper claims to reproduce: the optimised-embedding curves rise faster
//! than the fixed-embedding curves, and the d=8 feedback is at least as
//! strong as d=2.

use super::common::{self, Scale};
use crate::data::datasets;
use crate::engine::FuncSne;
use crate::knn::brute::brute_knn;
use crate::ld::NativeBackend;
use crate::metrics::rnx::rnx_curve_vs_table;
use crate::util::plot::{line_chart, Series};
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(700, 3000);
    let k_eval = 32.min(n / 4); // paper uses K ≤ 256 at larger N
    let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 4);
    let truth = brute_knn(&ds.x, k_eval);
    let iters = scale.pick(120, 600);
    let stride = (iters / 12).max(1);

    let mut series = Vec::new();
    let mut csv: Vec<Vec<String>> = Vec::new();
    for &(d, feedback) in &[(2usize, true), (2, false), (8, true), (8, false)] {
        let mut cfg = common::figure_config(n, d, 1.0);
        cfg.jumpstart_iters = 0; // isolate the feedback effect
        cfg.n_iters = iters;
        let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
        let mut backend = NativeBackend::new();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for it in 0..iters {
            if feedback {
                engine.step(&mut backend)?;
            } else {
                // No feedback: refine the KNN sets but freeze the embedding.
                let y_frozen = engine.y.clone();
                engine.step(&mut backend)?;
                engine.y = y_frozen;
            }
            if it % stride == 0 || it + 1 == iters {
                let c = rnx_curve_vs_table(&truth, &engine.knn.hd, k_eval);
                xs.push(it as f64);
                ys.push(c.auc);
                csv.push(vec![
                    format!("d{d}_{}", if feedback { "feedback" } else { "fixed" }),
                    it.to_string(),
                    format!("{:.5}", c.auc),
                ]);
            }
        }
        series.push(Series::new(
            format!("d={d} {}", if feedback { "optimised" } else { "fixed" }),
            xs,
            ys,
        ));
    }
    let chart = line_chart(
        "Fig4: AUC of R_NX(K) of estimated HD-KNN vs iteration",
        &series,
        72,
        20,
        false,
    );
    // Shape check: final AUC with feedback ≥ without, for both dims.
    let finals: Vec<f64> = series.iter().map(|s| *s.ys.last().unwrap()).collect();
    let mut summary = String::from("=== Fig. 4: embedding→KNN feedback loop ===\n");
    summary.push_str(&chart);
    summary.push_str(&format!(
        "final AUC: d2 optimised {:.3} vs fixed {:.3} | d8 optimised {:.3} vs fixed {:.3}\n",
        finals[0], finals[1], finals[2], finals[3]
    ));
    summary.push_str("paper-shape check: optimised ≥ fixed at both dims (feedback helps).\n");
    common::record_csv("fig4_feedback", &["series", "iter", "auc"], &csv)?;
    common::record("fig4_feedback", &summary)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    #[test]
    fn feedback_beats_fixed_eventually() {
        // Shrunk version of the figure's claim, deterministic seeds.
        let out = super::run(super::Scale::Quick).unwrap();
        assert!(out.contains("final AUC"));
    }
}
