//! Figure 6 — R_NX(K) curves: FUnc-SNE vs UMAP-like vs the
//! whole-space-modelling baseline (BH t-SNE, substituting FIt-SNE) on
//! the rat-brain twin, Gaussian blobs, and the COIL-20 twin.
//!
//! Paper claims to reproduce: the proposed method is competitive with
//! the precise baseline across scales, while UMAP's *local* R_NX
//! (small K) is systematically weaker — the negative-sampling intrusion
//! artefact (its repulsion misses close range, Table 1).

use super::common::{self, Scale};
use crate::baselines::bhtsne::{bh_tsne, BhConfig};
use crate::baselines::umap_like::{umap_like, UmapConfig};
use crate::data::datasets;
use crate::metrics::rnx::rnx_curve;
use crate::util::plot::{line_chart, Series};
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(600, 3000);
    let k_max = (n / 6).clamp(20, 300);
    let mut summary = String::from("=== Fig. 6: R_NX(K), three methods × three datasets ===\n");
    let mut csv = Vec::new();
    let mut auc_rows = Vec::new();
    for (dname, ds) in [
        ("rat_brain", datasets::rat_brain_like(n, 50, 7)),
        ("blobs", datasets::blobs(n, 16, 8, 1.0, 18.0, 5)),
        ("coil", datasets::coil_like(20, (n / 20).max(8), 48, 6)),
    ] {
        let n = ds.n();
        let iters = scale.pick(400, 1000);
        let y_ours = {
            let mut cfg = common::figure_config(n, 2, 1.0);
            cfg.n_iters = iters;
            common::run_funcsne(ds.x.clone(), &cfg)?.y
        };
        let y_umap = umap_like(
            &ds.x,
            &UmapConfig { n_epochs: scale.pick(150, 400), ..UmapConfig::default() },
        );
        let y_bh = bh_tsne(
            &ds.x,
            &BhConfig {
                n_iters: scale.pick(250, 600),
                k: 3 * 15,
                perplexity: 15.0,
                ..BhConfig::default()
            },
        );
        let mut series = Vec::new();
        for (mname, y) in [("FUnc-SNE", &y_ours), ("UMAP-like", &y_umap), ("BH-tSNE (FIt-SNE stand-in)", &y_bh)] {
            let c = rnx_curve(&ds.x, y, k_max);
            for (&k, &r) in c.ks.iter().zip(&c.rnx) {
                csv.push(vec![
                    dname.to_string(),
                    mname.to_string(),
                    k.to_string(),
                    format!("{r:.5}"),
                ]);
            }
            auc_rows.push(vec![dname.to_string(), mname.to_string(), format!("{:.3}", c.auc)]);
            series.push(Series::new(
                mname,
                c.ks.iter().map(|&k| k as f64).collect(),
                c.rnx.clone(),
            ));
        }
        summary.push_str(&line_chart(
            &format!("Fig6 [{dname}]: R_NX(K), log K"),
            &series,
            72,
            18,
            true,
        ));
    }
    summary.push_str(&common::format_table(&["dataset", "method", "RNX AUC"], &auc_rows));
    summary.push_str(
        "\npaper-shape check: FUnc-SNE ≈ BH baseline; UMAP-like trails at small K (local intrusions).\n",
    );
    common::record_csv("fig6_quality", &["dataset", "method", "K", "rnx"], &csv)?;
    common::record("fig6_quality", &summary)?;
    Ok(summary)
}
