//! Figures 9/10 — hierarchical cluster-graph extraction by sweeping α
//! during a continual optimisation, on the MNIST twin (Fig. 9, LD dim 4)
//! and the rat-brain twin (Fig. 10, LD dim 6).
//!
//! Paper claims to reproduce: snapshots under progressively heavier
//! tails, clustered by DBSCAN and linked by overlap, form a meaningful
//! tree; for the rat-brain data the tree resembles the ground-truth
//! dendrogram — which we *have* (the generator plants it), so the
//! resemblance is scored quantitatively with `tree_agreement`.

use super::common::{self, Scale};
use crate::cluster::hierarchy::{alpha_sweep, tree_agreement, SweepConfig};
use crate::cluster::layout::{layout, render_ascii};
use crate::data::datasets;
use crate::engine::FuncSne;
use crate::ld::NativeBackend;
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let mut summary = String::from("=== Figs 9/10: α-sweep hierarchy graphs ===\n");
    let mut csv = Vec::new();

    // ---- Fig. 9: MNIST twin at LD dim 4 -------------------------------
    {
        let n = scale.pick(700, 3000);
        let ds = datasets::mnist_like(n, 32, 6);
        let mut cfg = common::figure_config(n, 4, 1.0);
        cfg.n_iters = 0;
        let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
        let mut backend = NativeBackend::new();
        let sweep = SweepConfig {
            alphas: vec![1.0, 0.6, 0.45],
            iters_per_level: scale.pick(250, 800),
            ..SweepConfig::default()
        };
        let graph = alpha_sweep(&mut engine, &mut backend, &sweep)?;
        let pos = layout(&graph, 250, 1);
        summary.push_str("--- Fig 9 (MNIST twin, LD dim 4) ---\n");
        summary.push_str(&render_ascii(&graph, &pos, 64, 18));
        let counts: Vec<usize> =
            (0..graph.levels).map(|l| graph.nodes_at(l).count()).collect();
        summary.push_str(&format!("clusters per level: {counts:?}\n"));
        csv.push(vec!["mnist".into(), format!("{counts:?}"), "".into()]);
    }

    // ---- Fig. 10: rat-brain twin at LD dim 6 + dendrogram score -------
    {
        let n = scale.pick(700, 3000);
        let ds = datasets::rat_brain_like(n, 50, 7);
        let planted = ds.hierarchy.clone().unwrap();
        let mut cfg = common::figure_config(n, 6, 1.0);
        cfg.n_iters = 0;
        let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
        let mut backend = NativeBackend::new();
        let sweep = SweepConfig {
            alphas: vec![1.0, 0.6, 0.45],
            iters_per_level: scale.pick(250, 800),
            ..SweepConfig::default()
        };
        let graph = alpha_sweep(&mut engine, &mut backend, &sweep)?;
        let pos = layout(&graph, 250, 2);
        summary.push_str("--- Fig 10 (rat-brain twin, LD dim 6) ---\n");
        summary.push_str(&render_ascii(&graph, &pos, 64, 18));
        let leaf_level = graph.levels - 1;
        let score = tree_agreement(&graph, leaf_level, &ds.labels, &planted);
        let counts: Vec<usize> =
            (0..graph.levels).map(|l| graph.nodes_at(l).count()).collect();
        summary.push_str(&format!(
            "clusters per level: {counts:?}\ndendrogram agreement vs planted taxonomy: {score:.3} (1 = perfect, 0.5 ≈ chance)\n"
        ));
        csv.push(vec!["rat_brain".into(), format!("{counts:?}"), format!("{score:.4}")]);
    }
    summary.push_str(
        "\npaper-shape check: deeper levels have ≥ clusters; rat-brain graph agrees with the planted dendrogram well above chance.\n",
    );
    common::record_csv("fig9_10_hierarchy", &["dataset", "clusters_per_level", "tree_agreement"], &csv)?;
    common::record("fig9_10_hierarchy", &summary)?;
    Ok(summary)
}
