//! Figure 7 — the iterative KNN finder vs nearest-neighbour descent on
//! four datasets, including the Overlapping / Disjointed blob pair.
//!
//! Paper claims to reproduce: (i) NN-descent is near-perfect on
//! overlapping blobs; (ii) on *disjointed* tight blobs its greedy
//! refinement gets trapped while the proposed finder escapes (higher
//! R_NX given enough iterations); (iii) with more iterations the
//! proposed finder closes any remaining gap.

use super::common::{self, Scale};
use crate::config::KnnConfig;
use crate::data::datasets;
use crate::engine::FuncSne;
use crate::knn::brute::brute_knn;
use crate::knn::nn_descent::nn_descent;
use crate::ld::NativeBackend;
use crate::metrics::rnx::rnx_curve_vs_table;
use crate::util::plot::{line_chart, Series};
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let mut summary = String::from("=== Fig. 7: proposed KNN finder vs NN-descent ===\n");
    let k = 16;
    let mut csv = Vec::new();
    let mut auc_rows = Vec::new();
    let datasets: Vec<(&str, datasets::Dataset)> = vec![
        ("blobs_overlapping", datasets::blobs_overlapping(scale.pick(600, 3000), 32, 1)),
        (
            "blobs_disjointed",
            datasets::blobs_disjointed(scale.pick(60, 1000), 30, 32, 2),
        ),
        ("mnist_twin", datasets::mnist_like(scale.pick(600, 3000), 64, 3)),
        ("coil_twin", datasets::coil_like(20, scale.pick(30, 120), 48, 4)),
    ];
    for (dname, ds) in datasets {
        let n = ds.n();
        let truth = brute_knn(&ds.x, k);
        // --- NN-descent (to convergence) -------------------------------
        let nnd = nn_descent(&ds.x, &KnnConfig { k, rho: 0.8, ..KnnConfig::default() });
        let c_nnd = rnx_curve_vs_table(&truth, &nnd.table, k);
        // --- proposed finder embedded in the engine, two budgets -------
        let mut curves = Vec::new();
        for &iters in &[scale.pick(60, 3000), scale.pick(180, 9000)] {
            let mut cfg = common::figure_config(n, 2, 1.0);
            cfg.k_hd = k;
            cfg.n_iters = iters;
            // Always refine in this experiment (isolate the finder).
            cfg.refine_base_prob = 1.0;
            let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
            let mut backend = NativeBackend::new();
            engine.run(iters, &mut backend)?;
            let c = rnx_curve_vs_table(&truth, &engine.knn.hd, k);
            curves.push((iters, c));
        }
        let mut series = vec![Series::new(
            "NN-descent (converged)",
            c_nnd.ks.iter().map(|&v| v as f64).collect(),
            c_nnd.rnx.clone(),
        )];
        auc_rows.push(vec![dname.to_string(), "nn_descent".into(), format!("{:.3}", c_nnd.auc)]);
        for (&k_, &r) in c_nnd.ks.iter().zip(&c_nnd.rnx) {
            csv.push(vec![dname.into(), "nn_descent".into(), k_.to_string(), format!("{r:.5}")]);
        }
        for (iters, c) in &curves {
            series.push(Series::new(
                format!("proposed @{iters} iters"),
                c.ks.iter().map(|&v| v as f64).collect(),
                c.rnx.clone(),
            ));
            auc_rows.push(vec![
                dname.to_string(),
                format!("proposed_{iters}"),
                format!("{:.3}", c.auc),
            ]);
            for (&k_, &r) in c.ks.iter().zip(&c.rnx) {
                csv.push(vec![
                    dname.into(),
                    format!("proposed_{iters}"),
                    k_.to_string(),
                    format!("{r:.5}"),
                ]);
            }
        }
        summary.push_str(&line_chart(
            &format!("Fig7 [{dname}]: R_NX(K) of estimated HD-KNN"),
            &series,
            72,
            16,
            true,
        ));
    }
    summary.push_str(&common::format_table(&["dataset", "finder", "RNX AUC"], &auc_rows));
    summary.push_str(
        "\npaper-shape check: NN-descent ~perfect on overlapping; proposed wins on disjointed; longer budget ⇒ better.\n",
    );
    common::record_csv("fig7_knn", &["dataset", "finder", "K", "rnx"], &csv)?;
    common::record("fig7_knn", &summary)?;
    Ok(summary)
}
