//! One driver per paper table / figure. Each driver regenerates the
//! figure's data (CSV + ASCII rendering in `results/`) and prints the
//! paper-style rows; the `rust/benches/*` targets are thin wrappers.
//!
//! Every driver takes a [`common::Scale`] so the same code serves quick
//! CI-sized runs (`cargo bench` defaults) and the full paper-sized runs
//! (`FUNCSNE_FULL=1 cargo bench`).

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9_10;
pub mod fig11;
pub mod table1;
pub mod table2;
