//! Table 2 — 1-NN classification on three representations of the
//! deep-feature twin: raw ambient features, PCA-reduced, and the
//! intermediate-dimensional NE (32-D).
//!
//! Paper claims to reproduce (on ImageNet/EVA: 47.3 / 45.9 / **76.2** %
//! one-shot top-1): the unsupervised NE concentrates classes so one-shot
//! 1-NN improves *dramatically* over raw and PCA representations, while
//! cross-validated accuracy changes little — i.e. the NE reorganises,
//! not memorises.

use super::common::{self, Scale};
use crate::coordinator::driver::maybe_pca_reduce;
use crate::data::datasets;
use crate::data::Matrix;
use crate::knn::brute::knn_of_query;
use crate::util::Rng;
use anyhow::Result;

/// One-shot 1-NN accuracy: reveal one random labelled point per class,
/// classify everything else; mean over `trials`.
pub fn one_shot_accuracy(
    x: &Matrix,
    labels: &[usize],
    trials: usize,
    top: usize,
    rng: &mut Rng,
) -> f64 {
    let n = x.n();
    let classes = labels.iter().copied().max().unwrap_or(0) + 1;
    let mut acc = 0.0f64;
    for _ in 0..trials {
        // pick one exemplar per class
        let mut exemplar = vec![usize::MAX; classes];
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            if exemplar[labels[i]] == usize::MAX {
                exemplar[labels[i]] = i;
            }
        }
        let exemplars: Vec<usize> = exemplar.iter().copied().filter(|&e| e != usize::MAX).collect();
        let ex_mat = x.take_rows(&exemplars);
        let mut correct = 0usize;
        let mut total = 0usize;
        for i in 0..n {
            if exemplar[labels[i]] == i {
                continue;
            }
            let hits = knn_of_query(&ex_mat, x.row(i), top.min(exemplars.len()), None);
            if hits
                .iter()
                .any(|&(e, _)| labels[exemplars[e as usize]] == labels[i])
            {
                correct += 1;
            }
            total += 1;
        }
        acc += correct as f64 / total.max(1) as f64;
    }
    acc / trials as f64
}

/// k-fold cross-validated 1-NN accuracy (train = other folds).
pub fn crossval_accuracy(x: &Matrix, labels: &[usize], folds: usize, rng: &mut Rng) -> f64 {
    let n = x.n();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut correct = 0usize;
    let mut total = 0usize;
    for f in 0..folds {
        let test: Vec<usize> =
            order.iter().copied().skip(f).step_by(folds).collect();
        let train: Vec<usize> = order
            .iter()
            .copied()
            .enumerate()
            .filter(|(t, _)| t % folds != f)
            .map(|(_, i)| i)
            .collect();
        let train_mat = x.take_rows(&train);
        for &i in &test {
            let hit = knn_of_query(&train_mat, x.row(i), 1, None);
            if let Some(&(e, _)) = hit.first() {
                if labels[train[e as usize]] == labels[i] {
                    correct += 1;
                }
            }
            total += 1;
        }
    }
    correct as f64 / total.max(1) as f64
}

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(1000, 6000);
    let classes = scale.pick(25, 100);
    let trials = scale.pick(10, 100);
    let ds = datasets::deep_features(n, classes, 256, 8);
    let mut rng = Rng::new(77);

    // Three representations mirroring 1280-EVA / 192-PCA / 32-NE.
    let raw = ds.x.clone();
    let pca48 = maybe_pca_reduce(ds.x.clone(), 48, 0);
    let ne32 = {
        let mut cfg = common::figure_config(n, 32, 1.0);
        cfg.n_iters = scale.pick(500, 1500);
        common::run_funcsne(pca48.clone(), &cfg)?.y
    };

    let mut rows = Vec::new();
    let reprs: Vec<(&str, &Matrix)> =
        vec![("256, raw", &raw), ("48, PCA", &pca48), ("32, NE", &ne32)];
    let mut cells: Vec<Vec<f64>> = Vec::new();
    for (name, x) in &reprs {
        let os1 = one_shot_accuracy(x, &ds.labels, trials, 1, &mut rng);
        let os5 = one_shot_accuracy(x, &ds.labels, trials, 5, &mut rng);
        let cv = crossval_accuracy(x, &ds.labels, 10, &mut rng);
        rows.push(vec![
            name.to_string(),
            format!("{:.1}%", os1 * 100.0),
            format!("{:.1}%", os5 * 100.0),
            format!("{:.1}%", cv * 100.0),
        ]);
        cells.push(vec![os1, os5, cv]);
    }
    let mut summary = String::from("=== Table 2: 1-NN accuracy across representations ===\n");
    summary.push_str(&common::format_table(
        &["representation", "one-shot (top-1)", "one-shot (top-5)", "crossval (top-1)"],
        &rows,
    ));
    summary.push_str(&format!(
        "\npaper reference (ImageNet/EVA): one-shot top-1 47.3 / 45.9 / 76.2; ours: {:.1} / {:.1} / {:.1}\n",
        cells[0][0] * 100.0,
        cells[1][0] * 100.0,
        cells[2][0] * 100.0
    ));
    summary.push_str(
        "paper-shape check: NE one-shot ≫ raw/PCA one-shot; crossval gap small across representations.\n",
    );
    common::record_csv(
        "table2_oneshot",
        &["repr", "oneshot_top1", "oneshot_top5", "crossval_top1"],
        &rows,
    )?;
    common::record("table2_oneshot", &summary)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn one_shot_perfect_on_separated_blobs() {
        let ds = datasets::blobs(120, 4, 3, 0.05, 30.0, 1);
        let mut rng = Rng::new(2);
        let acc = one_shot_accuracy(&ds.x, &ds.labels, 3, 1, &mut rng);
        assert!(acc > 0.95, "one-shot acc {acc}");
    }

    #[test]
    fn crossval_reasonable_on_blobs() {
        let ds = datasets::blobs(150, 4, 3, 0.3, 20.0, 2);
        let mut rng = Rng::new(3);
        let acc = crossval_accuracy(&ds.x, &ds.labels, 5, &mut rng);
        assert!(acc > 0.9, "crossval acc {acc}");
    }

    #[test]
    fn top5_at_least_top1() {
        let ds = datasets::deep_features(200, 10, 32, 4);
        let mut rng = Rng::new(4);
        let t1 = one_shot_accuracy(&ds.x, &ds.labels, 2, 1, &mut rng);
        let mut rng = Rng::new(4);
        let t5 = one_shot_accuracy(&ds.x, &ds.labels, 2, 5, &mut rng);
        assert!(t5 >= t1 - 1e-9, "top5 {t5} < top1 {t1}");
    }
}
