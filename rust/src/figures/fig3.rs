//! Figure 3 — MNIST-twin fragmentation under heavier LD tails, with the
//! inter-cluster direction histograms.
//!
//! Paper claims to reproduce: (a) lowering α fragments the digit
//! clusters into more, denser sub-clusters; (b) the fragmentation is
//! *data-driven*: along the HD direction connecting two LD sub-clusters
//! of the same digit, the point distribution shows a dip (two modes) —
//! the planted density dips of the `mnist_like` generator.

use super::common::{self, Scale};
use crate::cluster::dbscan::{auto_eps, dbscan};
use crate::data::datasets;
use crate::data::Matrix;
use crate::util::plot;
use anyhow::Result;

/// Histogram of points of two clusters along the HD axis between the
/// cluster means (the h(c_x, c_y) of the paper).
fn direction_histogram(
    x: &Matrix,
    members_a: &[u32],
    members_b: &[u32],
) -> (Vec<f64>, Vec<f64>) {
    let d = x.d();
    let mean_of = |ms: &[u32]| -> Vec<f32> {
        let mut m = vec![0.0f32; d];
        for &i in ms {
            for (c, v) in x.row(i as usize).iter().enumerate() {
                m[c] += v;
            }
        }
        for v in m.iter_mut() {
            *v /= ms.len().max(1) as f32;
        }
        m
    };
    let ma = mean_of(members_a);
    let mb = mean_of(members_b);
    let mut axis: Vec<f32> = ma.iter().zip(&mb).map(|(a, b)| a - b).collect();
    let norm = axis.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-9);
    for v in axis.iter_mut() {
        *v /= norm;
    }
    let project = |ms: &[u32]| -> Vec<f64> {
        ms.iter()
            .map(|&i| {
                x.row(i as usize)
                    .iter()
                    .zip(&axis)
                    .map(|(v, a)| (v * a) as f64)
                    .sum::<f64>()
            })
            .collect()
    };
    (project(members_a), project(members_b))
}

/// Bimodality check: compare the histogram mass at the midpoint valley
/// vs the two mode regions. > 1 means a dip exists.
fn dip_ratio(a: &[f64], b: &[f64]) -> f64 {
    let all: Vec<f64> = a.iter().chain(b).copied().collect();
    let lo = all.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = all.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let w = (hi - lo).max(1e-9);
    let bins = 12usize;
    let mut counts = vec![0usize; bins];
    for &v in &all {
        let b = (((v - lo) / w) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let mid = (counts[bins / 2 - 1] + counts[bins / 2] + counts[bins / 2 + 1]) as f64 / 3.0;
    let flank_a = counts[1..4].iter().sum::<usize>() as f64 / 3.0;
    let flank_b = counts[bins - 4..bins - 1].iter().sum::<usize>() as f64 / 3.0;
    (flank_a.min(flank_b)) / mid.max(0.5)
}

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(800, 4000);
    let ds = datasets::mnist_like(n, 32, 6);
    let digits = ds.coarse_labels.clone().unwrap();
    let mut summary = String::from("=== Fig. 3: MNIST-twin fragmentation vs α ===\n");
    let mut rows = Vec::new();
    let mut last_clusters: Option<(Matrix, Vec<Vec<u32>>)> = None;
    for &alpha in &[1.0, 0.6, 0.4] {
        let mut cfg = common::figure_config(n, 2, alpha);
        cfg.n_iters = scale.pick(500, 1200);
        // Heavier tails need stronger repulsion to stay readable (paper §3).
        if alpha < 1.0 {
            cfg.repulsion = 1.5;
        }
        let engine = common::run_funcsne(ds.x.clone(), &cfg)?;
        let y = engine.embedding();
        let eps = auto_eps(y, 4, 0.75);
        let res = dbscan(y, eps, 5);
        summary.push_str(&plot::scatter_2d(
            &format!("Fig3a [α={alpha}] (labels = digit class)"),
            y.data(),
            &digits,
            n,
            72,
            18,
        ));
        rows.push(vec![format!("{alpha}"), format!("{}", res.n_clusters)]);
        // Collect clusters of the heaviest-tail run for the histogram.
        let mut clusters: Vec<Vec<u32>> = vec![Vec::new(); res.n_clusters];
        for (i, &l) in res.labels.iter().enumerate() {
            if l >= 0 {
                clusters[l as usize].push(i as u32);
            }
        }
        last_clusters = Some((y.clone(), clusters));
    }
    summary.push_str(&common::format_table(&["alpha", "clusters found (DBSCAN)"], &rows));

    // --- 3b/3c: histogram along the axis between two same-digit clusters.
    if let Some((_, clusters)) = &last_clusters {
        // Find two clusters dominated by the same digit.
        // BTreeMaps: both the majority-digit tie-break and the digit
        // iteration below must not depend on hash order, or the figure
        // picks different cluster pairs run to run.
        let digit_of = |members: &Vec<u32>| -> usize {
            let mut counts = std::collections::BTreeMap::new();
            for &i in members {
                *counts.entry(digits[i as usize]).or_insert(0usize) += 1;
            }
            counts.into_iter().max_by_key(|&(_, c)| c).map(|(d, _)| d).unwrap_or(0)
        };
        let mut by_digit = std::collections::BTreeMap::<usize, Vec<usize>>::new();
        for (c, m) in clusters.iter().enumerate() {
            if m.len() >= 15 {
                by_digit.entry(digit_of(m)).or_default().push(c);
            }
        }
        let mut found = false;
        for (digit, cs) in by_digit {
            if cs.len() >= 2 {
                let (pa, pb) = direction_histogram(&ds.x, &clusters[cs[0]], &clusters[cs[1]]);
                let ratio = dip_ratio(&pa, &pb);
                summary.push_str(&plot::histogram(
                    &format!(
                        "Fig3b h(c_x,c_y) for digit {digit}: projection onto (X̄_cx − X̄_cy), dip ratio {ratio:.2}"
                    ),
                    &pa,
                    &pb,
                    12,
                ));
                summary.push_str(&format!(
                    "dip ratio {ratio:.2} (> 1 ⇒ the LD split tracks a real HD density dip)\n"
                ));
                found = true;
                break;
            }
        }
        if !found {
            summary.push_str("(no same-digit cluster pair large enough for the histogram at this scale)\n");
        }
    }
    summary.push_str("\npaper-shape check: cluster count increases as α decreases; same-digit splits show a dip.\n");
    common::record_csv(
        "fig3_alpha",
        &["alpha", "n_clusters"],
        &rows,
    )?;
    common::record("fig3_alpha_mnist", &summary)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    #[test]
    fn dip_ratio_detects_bimodal() {
        let a: Vec<f64> = (0..50).map(|i| -2.0 + 0.01 * i as f64).collect();
        let b: Vec<f64> = (0..50).map(|i| 2.0 + 0.01 * i as f64).collect();
        assert!(super::dip_ratio(&a, &b) > 1.5);
        // Unimodal: no dip.
        let c: Vec<f64> = (0..100).map(|i| (i as f64 - 50.0) * 0.01).collect();
        assert!(super::dip_ratio(&c[..50].to_vec(), &c[50..].to_vec()) < 1.5);
    }
}
