//! Figure 8 — wall-clock vs dataset size: the proposed method (default
//! refinement policy vs always-refine), NN-descent, UMAP-like, on
//! blobs(N, 32).
//!
//! Paper claims to reproduce: the proposed method scales *linearly* in
//! N; the default probabilistic-refinement policy is faster than
//! refining HD neighbours at every iteration. (Absolute times differ —
//! the paper's method ran on a laptop GPU; ours is single-core CPU.)

use super::common::{self, Scale};
use crate::baselines::umap_like::{umap_like, UmapConfig};
use crate::config::KnnConfig;
use crate::data::datasets;
use crate::engine::FuncSne;
use crate::knn::nn_descent::nn_descent;
use crate::ld::NativeBackend;
use crate::util::plot::{line_chart, Series};
use crate::util::Stopwatch;
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let sizes: Vec<usize> = match scale {
        Scale::Quick => vec![1000, 2000, 4000, 8000],
        Scale::Full => vec![20_000, 60_000, 100_000, 180_000, 260_000, 340_000],
    };
    let iters = scale.pick(300, 3000);
    let mut summary = String::from("=== Fig. 8: wall-clock vs N on blobs(N, 32) ===\n");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut s_default = Vec::new();
    let mut s_always = Vec::new();
    let mut s_nnd = Vec::new();
    let mut s_umap = Vec::new();
    for &n in &sizes {
        let ds = datasets::blobs(n, 32, 10, 1.0, 20.0, 9);
        // proposed, default policy
        let t_default = {
            let mut cfg = common::figure_config(n, 2, 1.0);
            cfg.n_iters = iters;
            let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
            let mut backend = NativeBackend::new();
            let sw = Stopwatch::new();
            engine.run(iters, &mut backend)?;
            sw.elapsed_s()
        };
        // proposed, always refine
        let t_always = {
            let mut cfg = common::figure_config(n, 2, 1.0);
            cfg.n_iters = iters;
            cfg.refine_base_prob = 1.0;
            let mut engine = FuncSne::new(ds.x.clone(), cfg)?;
            let mut backend = NativeBackend::new();
            let sw = Stopwatch::new();
            engine.run(iters, &mut backend)?;
            sw.elapsed_s()
        };
        // NN-descent alone (the KNN-phase baseline)
        let t_nnd = {
            let sw = Stopwatch::new();
            let _ = nn_descent(&ds.x, &KnnConfig { k: 32, ..KnnConfig::default() });
            sw.elapsed_s()
        };
        // UMAP-like, scaled iteration count like the paper (1000 epochs full)
        let t_umap = {
            let sw = Stopwatch::new();
            let _ = umap_like(
                &ds.x,
                &UmapConfig {
                    n_epochs: scale.pick(100, 1000),
                    exact_knn_below: 0, // always NN-descent, like real UMAP
                    ..UmapConfig::default()
                },
            );
            sw.elapsed_s()
        };
        s_default.push((n as f64, t_default));
        s_always.push((n as f64, t_always));
        s_nnd.push((n as f64, t_nnd));
        s_umap.push((n as f64, t_umap));
        rows.push(vec![
            n.to_string(),
            format!("{t_default:.2}"),
            format!("{t_always:.2}"),
            format!("{t_nnd:.2}"),
            format!("{t_umap:.2}"),
        ]);
        csv.push(vec![
            n.to_string(),
            format!("{t_default:.4}"),
            format!("{t_always:.4}"),
            format!("{t_nnd:.4}"),
            format!("{t_umap:.4}"),
        ]);
    }
    let mk = |name: &str, pts: &[(f64, f64)]| {
        Series::new(
            name,
            pts.iter().map(|p| p.0).collect(),
            pts.iter().map(|p| p.1).collect(),
        )
    };
    summary.push_str(&line_chart(
        &format!("Fig8: seconds for {iters} iterations vs N"),
        &[
            mk("proposed (default)", &s_default),
            mk("proposed (always refine)", &s_always),
            mk("NN-descent", &s_nnd),
            mk("UMAP-like", &s_umap),
        ],
        72,
        18,
        false,
    ));
    summary.push_str(&common::format_table(
        &["N", "proposed default (s)", "proposed always (s)", "NN-descent (s)", "UMAP-like (s)"],
        &rows,
    ));
    // Linearity check: time per point should be ~constant.
    let tpp_first = s_default[0].1 / s_default[0].0;
    let tpp_last = s_default.last().unwrap().1 / s_default.last().unwrap().0;
    summary.push_str(&format!(
        "\nlinearity: default policy time/point {:.2} µs at N={} vs {:.2} µs at N={} (ratio {:.2}; ≈1 ⇒ O(N))\n",
        tpp_first * 1e6,
        sizes[0],
        tpp_last * 1e6,
        sizes.last().unwrap(),
        tpp_last / tpp_first
    ));
    summary.push_str("paper-shape check: proposed scales linearly; default ≤ always-refine.\n");
    common::record_csv(
        "fig8_speed",
        &["n", "proposed_default_s", "proposed_always_s", "nn_descent_s", "umap_like_s"],
        &csv,
    )?;
    common::record("fig8_speed", &summary)?;
    Ok(summary)
}
