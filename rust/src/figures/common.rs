//! Shared plumbing for the figure drivers.

use crate::config::EmbedConfig;
use crate::coordinator::driver::default_artifact_dir;
use crate::data::Matrix;
use crate::engine::FuncSne;
use crate::ld::NativeBackend;
use crate::util::io;
use anyhow::Result;
use std::path::PathBuf;

/// Run scale: quick (CI / default `cargo bench`) vs full (paper-sized).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    /// From the FUNCSNE_FULL environment variable.
    pub fn from_env() -> Scale {
        if std::env::var("FUNCSNE_FULL").map(|v| v == "1").unwrap_or(false) {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    /// Pick a size by scale.
    pub fn pick(self, quick: usize, full: usize) -> usize {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
}

/// Where figure outputs land.
pub fn results_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}

/// Write an ASCII figure + echo it to stdout.
pub fn record(name: &str, text: &str) -> Result<()> {
    println!("{text}");
    io::write_text(&results_dir().join(format!("{name}.txt")), text)?;
    Ok(())
}

/// Write a CSV for external re-plotting.
pub fn record_csv(name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    io::write_csv(&results_dir().join(format!("{name}.csv")), header, rows)
}

/// Run FUnc-SNE natively with the given config; returns the embedding.
pub fn run_funcsne(x: Matrix, cfg: &EmbedConfig) -> Result<FuncSne> {
    let mut backend = NativeBackend::new();
    let mut engine = FuncSne::new(x, cfg.clone())?;
    engine.run(cfg.n_iters, &mut backend)?;
    Ok(engine)
}

/// A sensibly-tuned engine config for figure-sized runs.
pub fn figure_config(n: usize, ld_dim: usize, alpha: f64) -> EmbedConfig {
    let k_hd = 32.min(n.saturating_sub(1)).max(4);
    EmbedConfig {
        ld_dim,
        alpha,
        perplexity: (k_hd as f64 / 3.0).max(5.0),
        k_hd,
        k_ld: 16.min(n.saturating_sub(1)).max(2),
        n_neg: 8,
        n_iters: 800,
        early_exag_iters: 150,
        jumpstart_iters: 80,
        ..EmbedConfig::default()
    }
}

/// Default artifact dir re-export for benches.
pub fn artifacts() -> PathBuf {
    default_artifact_dir()
}

/// Format a table with aligned columns.
pub fn format_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (c, cell) in row.iter().enumerate().take(cols) {
            widths[c] = widths[c].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::from("| ");
        for (c, cell) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$} | ", cell, w = widths[c]));
        }
        s.push('\n');
        s
    };
    out.push_str(&line(
        &header.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push_str(&format!(
        "|{}|\n",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    ));
    for row in rows {
        out.push_str(&line(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_pick() {
        assert_eq!(Scale::Quick.pick(1, 2), 1);
        assert_eq!(Scale::Full.pick(1, 2), 2);
    }

    #[test]
    fn format_table_aligns() {
        let t = format_table(
            &["name", "auc"],
            &[
                vec!["funcsne".into(), "0.71".into()],
                vec!["umap".into(), "0.55".into()],
            ],
        );
        assert!(t.contains("funcsne"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn figure_config_valid_for_small_n() {
        figure_config(10, 2, 1.0).validate().unwrap();
        figure_config(5000, 8, 0.5).validate().unwrap();
    }
}
