//! Figure 11 — 2-D PCA views of the deep-feature twin: raw features vs
//! the 32-dimensional NE of them.
//!
//! Paper claims to reproduce: after the 32-D NE, a *linear* PCA view
//! shows tighter, less diffuse class groups than the raw representation
//! (plus the spectral-like spike artefact). We quantify "tighter" as the
//! within-class / total variance ratio in the 2-D PCA view.

use super::common::{self, Scale};
use crate::coordinator::driver::maybe_pca_reduce;
use crate::data::datasets;
use crate::data::Matrix;
use crate::linalg::Pca;
use crate::util::plot;
use anyhow::Result;

/// Within-class variance fraction of a 2-D view (lower = tighter).
fn within_class_fraction(y: &Matrix, labels: &[usize]) -> f64 {
    let n = y.n();
    let classes = labels.iter().copied().max().unwrap_or(0) + 1;
    let d = y.d();
    let mut means = vec![vec![0.0f64; d]; classes];
    let mut counts = vec![0usize; classes];
    for i in 0..n {
        counts[labels[i]] += 1;
        for c in 0..d {
            means[labels[i]][c] += y.row(i)[c] as f64;
        }
    }
    for k in 0..classes {
        for c in 0..d {
            means[k][c] /= counts[k].max(1) as f64;
        }
    }
    let mut grand = vec![0.0f64; d];
    for i in 0..n {
        for c in 0..d {
            grand[c] += y.row(i)[c] as f64;
        }
    }
    for g in grand.iter_mut() {
        *g /= n as f64;
    }
    let (mut within, mut total) = (0.0f64, 0.0f64);
    for i in 0..n {
        for c in 0..d {
            let v = y.row(i)[c] as f64;
            within += (v - means[labels[i]][c]).powi(2);
            total += (v - grand[c]).powi(2);
        }
    }
    within / total.max(1e-12)
}

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(800, 4000);
    let classes = scale.pick(20, 100);
    let ds = datasets::deep_features(n, classes, 256, 8);
    let mut summary = String::from("=== Fig. 11: PCA views, raw vs 32-D NE ===\n");

    // Raw pipeline: 256 → 2 (PCA view).
    let view_raw = Pca::fit_transform(&ds.x, 2, 0);
    // NE pipeline: 256 → 48 PCs → 32-D NE → 2 (PCA view), mirroring the
    // paper's 1280 → 192 PCs → 32 NE → 2.
    let reduced = maybe_pca_reduce(ds.x.clone(), 48, 0);
    let mut cfg = common::figure_config(n, 32, 1.0);
    cfg.n_iters = scale.pick(400, 1200);
    let y32 = common::run_funcsne(reduced, &cfg)?.y;
    let view_ne = Pca::fit_transform(&y32, 2, 0);

    summary.push_str(&plot::scatter_2d(
        "Fig11-left: raw features → PCA (labels = class % 62)",
        view_raw.data(),
        &ds.labels,
        n,
        72,
        18,
    ));
    summary.push_str(&plot::scatter_2d(
        "Fig11-right: 48 PCs → 32-D NE → PCA",
        view_ne.data(),
        &ds.labels,
        n,
        72,
        18,
    ));
    let f_raw = within_class_fraction(&view_raw, &ds.labels);
    let f_ne = within_class_fraction(&view_ne, &ds.labels);
    summary.push_str(&format!(
        "within-class variance fraction (lower = tighter): raw {f_raw:.3} vs NE {f_ne:.3}\n"
    ));
    summary.push_str("paper-shape check: the NE view is tighter (NE fraction < raw fraction).\n");
    common::record_csv(
        "fig11_pca_view",
        &["pipeline", "within_class_fraction"],
        &[
            vec!["raw_pca".into(), format!("{f_raw:.5}")],
            vec!["ne32_pca".into(), format!("{f_ne:.5}")],
        ],
    )?;
    common::record("fig11_pca_view", &summary)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::Rng;

    #[test]
    fn within_class_fraction_bounds() {
        let mut rng = Rng::new(1);
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, 60, 2, 1.0), 60, 2).unwrap();
        let labels: Vec<usize> = (0..60).map(|i| i % 3).collect();
        let f = within_class_fraction(&y, &labels);
        assert!((0.0..=1.0 + 1e-9).contains(&f));
        // Perfectly separated classes → near 0.
        let mut ysep = Matrix::zeros(60, 2);
        for i in 0..60 {
            ysep.row_mut(i)[0] = (i % 3) as f32 * 100.0 + rng.f32() * 0.01;
        }
        assert!(within_class_fraction(&ysep, &labels) < 0.01);
    }
}
