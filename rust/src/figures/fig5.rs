//! Figure 5 — the hyperparameter grid: attraction/repulsion ratio × LD
//! tail heaviness on the two single-cell twins.
//!
//! Paper claims to reproduce: lowering α fragments both datasets more
//! and more; raising repulsion counteracts the visual collapse of the
//! dense heavy-tail clusters (cluster diameter grows with repulsion).

use super::common::{self, Scale};
use crate::cluster::dbscan::{auto_eps, dbscan};
use crate::data::datasets;
use crate::util::plot;
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let mut summary = String::from("=== Fig. 5: A/R ratio × α grid, single-cell twins ===\n");
    let mut csv = Vec::new();
    for (dname, ds) in [
        ("rat_brain", datasets::rat_brain_like(scale.pick(500, 2000), 50, 7)),
        ("tabula", datasets::tabula_like(scale.pick(500, 3000), 50, 8)),
    ] {
        let n = ds.n();
        let coarse = ds.coarse_labels.clone().unwrap();
        let mut rows = Vec::new();
        for &alpha in &[1.0, 0.5] {
            for &ar in &[0.5, 1.0, 2.0] {
                let mut cfg = common::figure_config(n, 2, alpha);
                cfg.n_iters = scale.pick(350, 1000);
                cfg.repulsion = ar;
                let engine = common::run_funcsne(ds.x.clone(), &cfg)?;
                let y = engine.embedding();
                let eps = auto_eps(y, 4, 0.75);
                let res = dbscan(y, eps, 5);
                // Mean cluster "diameter" relative to embedding extent —
                // the collapse metric the A/R ratio controls.
                let rms_all = (y.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
                    / y.data().len() as f64)
                    .sqrt();
                let mut intra = 0.0f64;
                let mut count = 0usize;
                for i in (0..n).step_by(7) {
                    for j in (i + 1..n).step_by(11) {
                        if res.labels[i] >= 0 && res.labels[i] == res.labels[j] {
                            intra += (y.sqdist(i, j) as f64).sqrt();
                            count += 1;
                        }
                    }
                }
                let collapse = if count > 0 { intra / count as f64 / rms_all.max(1e-9) } else { 0.0 };
                if alpha == 0.5 && (ar - 2.0).abs() < 1e-9 {
                    summary.push_str(&plot::scatter_2d(
                        &format!("Fig5 [{dname}] α={alpha} A/R={ar} (labels = subtype)"),
                        y.data(),
                        &coarse,
                        n,
                        72,
                        16,
                    ));
                }
                rows.push(vec![
                    format!("{alpha}"),
                    format!("{ar}"),
                    format!("{}", res.n_clusters),
                    format!("{collapse:.3}"),
                ]);
                csv.push(vec![
                    dname.to_string(),
                    format!("{alpha}"),
                    format!("{ar}"),
                    format!("{}", res.n_clusters),
                    format!("{collapse:.5}"),
                ]);
            }
        }
        summary.push_str(&format!("--- {dname} ---\n"));
        summary.push_str(&common::format_table(
            &["alpha", "A/R (repulsion)", "clusters", "intra-dist / extent"],
            &rows,
        ));
    }
    summary.push_str(
        "\npaper-shape check: clusters increase as α drops; intra/extent grows with repulsion (collapse counteracted).\n",
    );
    common::record_csv(
        "fig5_ar_grid",
        &["dataset", "alpha", "repulsion", "n_clusters", "collapse"],
        &csv,
    )?;
    common::record("fig5_ar_grid", &summary)?;
    Ok(summary)
}
