//! Figure 1 — the S-curve study: how method (PCA vs t-SNE-family),
//! perplexity, sample size, and unbalanced sampling change the 2-D
//! embedding; quality shown as pointwise distance correlation (global)
//! and ⌈0.05N⌉-neighbourhood preservation (local).
//!
//! Paper claims to reproduce: PCA preserves global shape but intrudes
//! locally; NE preserves local structure at the cost of global; changing
//! perplexity / sample size / sampling balance visibly changes NE output.

use super::common::{self, Scale};
use crate::data::datasets;
use crate::linalg::Pca;
use crate::metrics::pointwise::{pointwise_distance_correlation, pointwise_knn_preservation};
use crate::util::plot;
use crate::util::stats::mean;
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(600, 2000);
    let mut summary = String::from("=== Fig. 1: S-curve, method × hyperparameter × sampling ===\n");
    let mut rows = Vec::new();
    let mut csv = Vec::new();

    // Panels: (label, dataset variant, method)
    let variants: Vec<(String, datasets::Dataset, Panel)> = vec![
        ("PCA".into(), datasets::scurve(n, 0.02, false, 1), Panel::Pca),
        ("tSNE perp=10".into(), datasets::scurve(n, 0.02, false, 1), Panel::Ne { perplexity: 10.0 }),
        ("tSNE perp=40".into(), datasets::scurve(n, 0.02, false, 1), Panel::Ne { perplexity: 40.0 }),
        (format!("tSNE n={}", n / 3), datasets::scurve(n / 3, 0.02, false, 1), Panel::Ne { perplexity: 30.0 }),
        ("tSNE unbalanced".into(), datasets::scurve(n, 0.02, true, 2), Panel::Ne { perplexity: 30.0 }),
    ];

    for (label, ds, panel) in variants {
        let y = match panel {
            Panel::Pca => Pca::fit_transform(&ds.x, 2, 0),
            Panel::Ne { perplexity } => {
                let mut cfg = common::figure_config(ds.n(), 2, 1.0);
                cfg.perplexity = perplexity.min(ds.n() as f64 / 4.0);
                cfg.k_hd = cfg.k_hd.max((cfg.perplexity as usize) + 2).min(ds.n() - 1);
                cfg.n_iters = 600;
                common::run_funcsne(ds.x.clone(), &cfg)?.y
            }
        };
        let corr = pointwise_distance_correlation(&ds.x, &y);
        let pres = pointwise_knn_preservation(&ds.x, &y, 0.05);
        let scatter = plot::scatter_2d(
            &format!("Fig1 [{label}] (labels = S-curve halves)"),
            y.data(),
            &ds.labels,
            ds.n(),
            72,
            20,
        );
        summary.push_str(&scatter);
        rows.push(vec![
            label.clone(),
            format!("{:.3}", mean(&corr)),
            format!("{:.3}", mean(&pres)),
        ]);
        csv.push(vec![label, format!("{}", ds.n()), format!("{:.5}", mean(&corr)), format!("{:.5}", mean(&pres))]);
    }
    let table = common::format_table(
        &["panel", "mean dist-corr (global)", "mean 5%NN preservation (local)"],
        &rows,
    );
    summary.push_str(&table);
    summary.push_str(
        "\npaper-shape check: PCA should lead the global column; NE panels should lead the local column.\n",
    );
    common::record_csv("fig1_scurve", &["panel", "n", "dist_corr", "knn_preservation"], &csv)?;
    common::record("fig1_scurve", &summary)?;
    Ok(summary)
}

enum Panel {
    Pca,
    Ne { perplexity: f64 },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_holds_at_tiny_scale() {
        let out = run(Scale::Quick).unwrap();
        assert!(out.contains("PCA"));
        assert!(out.contains("unbalanced"));
    }
}
