//! Figure 2 — method panorama on the rat-brain twin: PCA, MDS, t-SNE
//! (our engine, α=1), UMAP-like, side by side.
//!
//! Paper claims to reproduce: PCA/MDS keep the global cell-type split
//! (non-neurons far from neurons), NE methods discard the largest scale
//! but reveal the finer cluster hierarchy.

use super::common::{self, Scale};
use crate::baselines::umap_like::{umap_like, UmapConfig};
use crate::data::datasets;
use crate::linalg::{mds, Pca};
use crate::metrics::pointwise::pointwise_distance_correlation;
use crate::metrics::rnx_auc;
use crate::util::plot;
use crate::util::stats::mean;
use anyhow::Result;

pub fn run(scale: Scale) -> Result<String> {
    let n = scale.pick(500, 2000);
    let ds = datasets::rat_brain_like(n, 50, 7);
    let coarse = ds.coarse_labels.clone().unwrap();
    let mut summary = String::from("=== Fig. 2: rat-brain twin, four methods ===\n");
    let mut rows = Vec::new();

    let mds_n = n.min(400); // MDS is O(N²); subsample like the paper's qualitative use
    let methods: Vec<(&str, crate::data::Matrix, usize)> = vec![
        ("PCA", Pca::fit_transform(&ds.x, 2, 0), n),
        ("MDS", mds::smacof(&ds.x.take_rows(&(0..mds_n).collect::<Vec<_>>()), 2, 60, 1), mds_n),
        ("FUnc-SNE (α=1)", {
            let cfg = common::figure_config(n, 2, 1.0);
            common::run_funcsne(ds.x.clone(), &cfg)?.y
        }, n),
        ("UMAP-like", umap_like(&ds.x, &UmapConfig { n_epochs: scale.pick(120, 300), ..UmapConfig::default() }), n),
    ];

    for (name, y, used) in methods {
        let x_used = if used == n {
            ds.x.clone()
        } else {
            ds.x.take_rows(&(0..used).collect::<Vec<_>>())
        };
        let labels: Vec<usize> = coarse[..used].to_vec();
        let global = mean(&pointwise_distance_correlation(&x_used, &y));
        let auc = rnx_auc(&x_used, &y, 50.min(used - 2));
        summary.push_str(&plot::scatter_2d(
            &format!("Fig2 [{name}] (labels = root cell type)"),
            y.data(),
            &labels,
            used,
            72,
            18,
        ));
        rows.push(vec![name.to_string(), format!("{global:.3}"), format!("{auc:.3}")]);
    }
    let table = common::format_table(&["method", "global (dist-corr)", "local (RNX AUC)"], &rows);
    summary.push_str(&table);
    summary.push_str(
        "\npaper-shape check: PCA/MDS lead the global column, NE methods lead the local column.\n",
    );
    common::record_csv(
        "fig2_methods",
        &["method", "global", "local_auc"],
        &rows.iter().map(|r| r.clone()).collect::<Vec<_>>(),
    )?;
    common::record("fig2_methods", &summary)?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig2_runs_quick() {
        let out = super::run(super::Scale::Quick).unwrap();
        assert!(out.contains("PCA"));
        assert!(out.contains("UMAP-like"));
    }
}
