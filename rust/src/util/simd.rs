//! Portable fixed-width f32 lane arithmetic for the SIMD backend.
//!
//! `F32x8` is an array-of-8-lanes value type with elementwise
//! arithmetic written as straight-line per-lane loops — the shape LLVM
//! auto-vectorizes to a pair of SSE registers or one AVX register on
//! x86-64 and to NEON pairs on aarch64, with a well-defined scalar
//! fallback everywhere else. No intrinsics, no `unsafe`, no feature
//! detection: the portability contract of the crate is preserved and
//! the numeric results are identical on every target because each lane
//! is an ordinary IEEE-754 f32 operation.
//!
//! Determinism contract (see docs/determinism.md):
//!
//! * Elementwise ops (`add`/`sub`/`mul`/`div`) are per-lane scalar
//!   f32 ops — bitwise reproducible by construction.
//! * Horizontal folds never use `.sum()`/`.fold()`; [`F32x8::hsum`]
//!   reduces in one **fixed** association,
//!   `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, so a lane total is a
//!   pure function of the lane values and never of shard count,
//!   thread count, or iteration order.
//! * There is deliberately no fused multiply-add: `mul_add` contracts
//!   rounding steps and would make results target-dependent.
//!
//! This module is inside the deterministic lint scope (`funcsne lint`
//! rule 6 applies here), so an accidental f32 `.sum()` creeping into a
//! fold is a CI failure, not a review hope.

/// Number of lanes in one [`F32x8`].
pub const LANES: usize = 8;

/// Eight f32 lanes with elementwise arithmetic and a fixed-order
/// horizontal sum.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct F32x8(pub [f32; LANES]);

impl F32x8 {
    /// All lanes zero.
    pub const ZERO: F32x8 = F32x8([0.0; LANES]);

    /// Broadcast one value to all lanes.
    #[inline(always)]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Load the first 8 values of `src` (`src.len() >= 8`).
    #[inline(always)]
    pub fn load(src: &[f32]) -> F32x8 {
        let mut out = [0.0f32; LANES];
        out.copy_from_slice(&src[..LANES]);
        F32x8(out)
    }

    /// Store the lanes into the first 8 slots of `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        dst[..LANES].copy_from_slice(&self.0);
    }

    /// Elementwise `self + rhs`.
    #[inline(always)]
    pub fn add(self, rhs: F32x8) -> F32x8 {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] + rhs.0[l];
        }
        F32x8(out)
    }

    /// Elementwise `self - rhs`.
    #[inline(always)]
    pub fn sub(self, rhs: F32x8) -> F32x8 {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] - rhs.0[l];
        }
        F32x8(out)
    }

    /// Elementwise `self * rhs`. Kept separate from `add` on purpose:
    /// `a.mul(b).add(c)` is two rounding steps, exactly like the
    /// scalar kernels — never a contracted fma.
    #[inline(always)]
    pub fn mul(self, rhs: F32x8) -> F32x8 {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] * rhs.0[l];
        }
        F32x8(out)
    }

    /// Elementwise `self / rhs`.
    #[inline(always)]
    pub fn div(self, rhs: F32x8) -> F32x8 {
        let mut out = [0.0f32; LANES];
        for l in 0..LANES {
            out[l] = self.0[l] / rhs.0[l];
        }
        F32x8(out)
    }

    /// Horizontal sum in a single fixed association:
    /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
    ///
    /// This is the only reduction the SIMD kernels use for f32 lane
    /// totals; because the association is explicit, the result is a
    /// deterministic function of the lane values alone. It is *not*
    /// the left-to-right order a scalar loop would use, which is why
    /// SIMD-vs-native comparisons are approximate, not bitwise.
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let a = self.0;
        ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe() -> (F32x8, F32x8) {
        let a = F32x8([1.5, -2.25, 3.0e-3, 4.0e4, -5.5, 0.0625, 7.75, -8.125]);
        let b = F32x8([0.5, 2.0, -1.25e-3, 3.5e2, 5.0, -0.5, 1.5, 2.5]);
        (a, b)
    }

    #[test]
    fn elementwise_ops_match_scalar_bitwise() {
        let (a, b) = probe();
        for l in 0..LANES {
            assert_eq!(a.add(b).0[l].to_bits(), (a.0[l] + b.0[l]).to_bits());
            assert_eq!(a.sub(b).0[l].to_bits(), (a.0[l] - b.0[l]).to_bits());
            assert_eq!(a.mul(b).0[l].to_bits(), (a.0[l] * b.0[l]).to_bits());
            assert_eq!(a.div(b).0[l].to_bits(), (a.0[l] / b.0[l]).to_bits());
        }
    }

    #[test]
    fn hsum_uses_the_documented_fixed_association() {
        let (a, _) = probe();
        let v = a.0;
        let expect = ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]));
        assert_eq!(a.hsum().to_bits(), expect.to_bits());
    }

    #[test]
    fn load_store_round_trip() {
        let src = [9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 99.0];
        let v = F32x8::load(&src);
        let mut dst = [0.0f32; 9];
        dst[8] = -1.0;
        v.store(&mut dst);
        assert_eq!(&dst[..8], &src[..8]);
        assert_eq!(dst[8], -1.0, "store must only touch the first 8 slots");
    }

    #[test]
    fn splat_and_zero() {
        assert_eq!(F32x8::splat(3.5).0, [3.5; LANES]);
        assert_eq!(F32x8::ZERO.0, [0.0; LANES]);
    }
}
