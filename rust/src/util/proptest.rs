//! A miniature property-testing harness.
//!
//! `proptest` is unavailable offline; this module gives the tests the
//! part that matters most for this codebase: run a property over many
//! seeded random cases and, on failure, report the *seed and case index*
//! so the failure replays deterministically (`Rng::new` is platform
//! stable). No shrinking — cases are kept small instead.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` over `cases` random cases. The property receives a fresh,
/// per-case RNG and the case index; it returns `Err(msg)` to fail.
///
/// Panics with seed + case index on the first failing case.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng, usize) -> Result<(), String>) {
    let base_seed: u64 = 0xF00D_0000_0000_0000
        ^ name.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generate a random length-`n` f32 vector with entries in [-scale, scale].
pub fn vec_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

/// Generate a random row-major (n, d) matrix of Gaussians.
pub fn gauss_mat(rng: &mut Rng, n: usize, d: usize, std: f64) -> Vec<f32> {
    (0..n * d).map(|_| rng.gauss_ms(0.0, std) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("always-true", 16, |rng, _| {
            let v = rng.f64();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("out of range {v}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        check("always-false", 4, |_, _| Err("nope".into()));
    }

    #[test]
    fn generators_have_right_shapes() {
        let mut rng = Rng::new(1);
        assert_eq!(vec_f32(&mut rng, 7, 2.0).len(), 7);
        assert_eq!(gauss_mat(&mut rng, 3, 5, 1.0).len(), 15);
        assert!(vec_f32(&mut rng, 100, 0.5).iter().all(|v| v.abs() <= 0.5));
    }
}
