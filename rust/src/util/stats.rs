//! Small statistics helpers shared by metrics and figure drivers.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for < 2 elements.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation of two equal-length slices; 0 if degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Spearman rank correlation.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

/// Average ranks (ties get the mean of their rank range).
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Exponentially-smoothed scalar (the paper's E[N_new/N] tracker).
#[derive(Debug, Clone)]
pub struct Ewma {
    value: f64,
    beta: f64,
    initialised: bool,
}

impl Ewma {
    /// `beta` is the retention factor (e.g. 0.9 keeps 90% of history).
    pub fn new(beta: f64) -> Self {
        Ewma { value: 0.0, beta, initialised: false }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        if !self.initialised {
            self.value = x;
            self.initialised = true;
        } else {
            self.value = self.beta * self.value + (1.0 - self.beta) * x;
        }
        self.value
    }

    pub fn get(&self) -> f64 {
        self.value
    }

    /// The full tracker state `(beta, value, initialised)`, for
    /// serialization.
    pub fn state(&self) -> (f64, f64, bool) {
        (self.beta, self.value, self.initialised)
    }

    /// Rebuild a tracker from [`Ewma::state`] output.
    pub fn from_state(beta: f64, value: f64, initialised: bool) -> Ewma {
        Ewma { value, beta, initialised }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[1.0, 1.0, 1.0])).abs() < 1e-12);
        assert!((std_dev(&[0.0, 2.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let ys = vec![2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg: Vec<f64> = ys.iter().map(|v| -v).collect();
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let xs = vec![1.0, 5.0, 2.0, 9.0];
        let ys: Vec<f64> = xs.iter().map(|v: &f64| v.exp()).collect();
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_handle_ties() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn ewma_tracks() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        assert_eq!(e.update(0.0), 5.0);
        assert_eq!(e.get(), 5.0);
    }
}
