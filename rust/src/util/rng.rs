//! Deterministic pseudo-random number generation.
//!
//! Two generators with different contracts:
//!
//! * [`Rng`] — a *sequential* generator (SplitMix64 seeding into
//!   xoshiro256**) for setup-time work (dataset synthesis, embedding
//!   init, table seeding) and single-threaded mutators. Its stream is
//!   consumed in call order, so it can never be shared across shards
//!   without serialising them.
//! * [`StreamRng`] — a *counter-based* generator:
//!   [`StreamRng::at`]`(seed, iter, point, lane)` derives an independent
//!   stream from its coordinates alone, statelessly. Draw `t` of stream
//!   `(seed, iter, point, lane)` is one pure function of those five
//!   numbers — no shared cursor, no consumption order. This is what
//!   lets the per-iteration hot passes (LD/HD candidate generation,
//!   negative sampling) shard across worker threads while staying
//!   **bitwise thread-count-invariant**: every shard partition computes
//!   the identical stream for every point.
//!
//! Both also back the distribution helpers the embedding engine and the
//! synthetic dataset generators need: uniforms, bounded integers,
//! Gaussians (Box–Muller with caching), shuffles and subset sampling.
//!
//! Determinism matters here: every experiment driver takes an explicit
//! seed so that paper figures regenerate bit-identically, and the
//! `StreamRng` constants below are pinned by unit tests — changing them
//! re-pins every golden trajectory in the repo.

/// xoshiro256** pseudo-random generator.
///
/// Not cryptographic; chosen for speed (4×u64 state, ~1 ns/draw) and
/// quality sufficient for Monte-Carlo style sampling in the engine's
/// negative-sampling hot loop.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// SplitMix64's finalizer: a bijective 64-bit mixer.
#[inline(always)]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stream-lane identifiers: each per-point random consumer in one
/// engine iteration draws from its own lane so the streams never
/// overlap (LD candidate generation, HD candidate generation, negative
/// sampling, and iteration-level decisions).
pub mod lane {
    /// LD-table candidate generation.
    pub const LD: u64 = 0;
    /// HD-table candidate generation.
    pub const HD: u64 = 1;
    /// Negative-sample drawing.
    pub const NEG: u64 = 2;
    /// Per-iteration engine decisions (e.g. the HD-refinement skip).
    pub const STEP: u64 = 3;
}

/// The minimal uniform-draw surface shared by [`Rng`] and [`StreamRng`]
/// so the candidate-generation code is generic over its random source.
///
/// `below` is the same Lemire multiply-shift rejection as
/// [`Rng::below`]; both implementations consume identical raw draws for
/// identical bounds, so swapping sources never changes *how much* of a
/// stream a call consumes for a given outcome sequence.
pub trait RandomSource {
    /// Next raw 64 bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in [0, 1).
    #[inline(always)]
    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias; `n` must be > 0.
    #[inline(always)]
    fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline(always)]
    fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Counter-based stream generator (the CBRNG of Salmon et al.'s
/// "Parallel random numbers: as easy as 1, 2, 3", in splitmix64
/// clothing): the state is a pure hash of `(seed, iter, point, lane)`
/// and successive draws walk a splitmix64 sequence from it.
///
/// Properties the sharded hot passes rely on:
///
/// * **Stateless derivation** — `at` is a pure function; no generator
///   object is threaded through the iteration, so there is no serial
///   cursor forcing an execution order.
/// * **Order independence** — stream `(s, i, p, l)` is identical no
///   matter which thread materialises it, when, or how many siblings
///   exist: shard partitions cannot change a single draw.
/// * **Per-coordinate distinctness** — each coordinate is folded in by
///   XOR with a distinct odd-constant multiple followed by a bijective
///   mix, so two calls differing in any one coordinate start from
///   different states (multiplication by an odd constant and `mix64`
///   are both bijections on u64).
///
/// The constants are pinned by `stream_rng_pinned_constants`; changing
/// any of them re-pins every golden trajectory in the repo.
#[derive(Clone, Copy, Debug)]
pub struct StreamRng {
    state: u64,
}

impl StreamRng {
    /// The stream for `point` in `lane` at iteration `iter` under
    /// `seed`. Cheap enough to call once per point per pass (4 mixes).
    #[inline(always)]
    pub fn at(seed: u64, iter: u64, point: u64, lane: u64) -> StreamRng {
        let mut h = seed ^ 0x5851_F42D_4C95_7F2D;
        h = mix64(h ^ iter.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = mix64(h ^ point.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        h = mix64(h ^ lane.wrapping_mul(0x1656_67B1_9E37_79F9));
        StreamRng { state: h }
    }
}

impl RandomSource for StreamRng {
    #[inline(always)]
    fn next_u64(&mut self) -> u64 {
        splitmix64(&mut self.state)
    }
}

impl RandomSource for Rng {
    #[inline(always)]
    fn next_u64(&mut self) -> u64 {
        self.step()
    }
}

impl Rng {
    /// Create a generator from a 64-bit seed. Identical seeds give
    /// identical streams on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-subsystem streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// The full generator state, for serialization: the xoshiro256**
    /// words plus the cached Box–Muller spare as raw IEEE-754 bits
    /// (`None` when no spare is cached). Restoring via
    /// [`Rng::from_state`] resumes the stream mid-sequence exactly.
    pub fn state(&self) -> ([u64; 4], Option<u64>) {
        (self.s, self.gauss_spare.map(f64::to_bits))
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], gauss_spare_bits: Option<u64>) -> Rng {
        Rng { s, gauss_spare: gauss_spare_bits.map(f64::from_bits) }
    }

    /// Next raw 64 bits.
    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        self.step()
    }

    /// The xoshiro256** state transition (shared by the inherent
    /// methods and the [`RandomSource`] impl).
    #[inline(always)]
    fn step(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1). (Delegates to the [`RandomSource`] default so
    /// the draw logic exists exactly once.)
    #[inline(always)]
    pub fn f64(&mut self) -> f64 {
        RandomSource::f64(self)
    }

    /// Uniform in [0, 1) as f32.
    #[inline(always)]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline(always)]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias; `n` must be > 0. (Delegates to the
    /// [`RandomSource`] default — one implementation, so inherent and
    /// generic call sites can never fork their draw streams.)
    #[inline(always)]
    pub fn below(&mut self, n: usize) -> usize {
        RandomSource::below(self, n)
    }

    /// Uniform integer in [lo, hi).
    #[inline(always)]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller, with spare caching.
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // Avoid u == 0.
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with given mean / std-dev.
    #[inline]
    pub fn gauss_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n). O(k) expected via
    /// Floyd's algorithm for small k, falls back to shuffle for large k.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Floyd's: guarantees distinctness with O(k) draws.
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        RandomSource::chance(self, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            m += g;
            v += g * g;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(10usize, 3usize), (100, 10), (50, 50), (1000, 5)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..64).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    // --- StreamRng: the determinism contract of the sharded passes ----

    /// The counter-based streams are part of the repo's reproducibility
    /// surface: these constants pin the exact mixing. Changing them is
    /// allowed but re-pins every golden trajectory.
    #[test]
    fn stream_rng_pinned_constants() {
        let draws = |seed, iter, point, lane| {
            let mut r = StreamRng::at(seed, iter, point, lane);
            [r.next_u64(), r.next_u64(), r.next_u64()]
        };
        assert_eq!(
            draws(42, 1, 2, 3),
            [0x212AF89AA521A4CA, 0x965BAD16122526B0, 0xF8DDD5DC8D7CE43E]
        );
        assert_eq!(
            draws(0, 0, 0, 0),
            [0x758E01BF3E076C76, 0x334CFD5650EB918E, 0x450D30C53DB3FA41]
        );
        assert_eq!(
            draws(0xDEADBEEF, 7, 123456, 1),
            [0x4F263EBF5A5D3DD2, 0x1AA182C741B20642, 0x733FC1284838DA09]
        );
    }

    /// Streams are pure functions of their coordinates: materialising
    /// them in any order — or interleaved, as concurrent shards would —
    /// yields identical draws (the property the sharded refinement and
    /// negative sampling lean on).
    #[test]
    fn stream_rng_order_and_interleave_invariant() {
        let points = [0u64, 1, 7, 500, 8191];
        let forward: Vec<Vec<u64>> = points
            .iter()
            .map(|&p| {
                let mut r = StreamRng::at(9, 3, p, lane::NEG);
                (0..8).map(|_| r.next_u64()).collect()
            })
            .collect();
        // Reverse order.
        for (pi, &p) in points.iter().enumerate().rev() {
            let mut r = StreamRng::at(9, 3, p, lane::NEG);
            let draws: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
            assert_eq!(draws, forward[pi], "stream for point {p} depends on order");
        }
        // Interleaved one-draw-at-a-time (simulating shard scheduling).
        let mut cursors: Vec<StreamRng> =
            points.iter().map(|&p| StreamRng::at(9, 3, p, lane::NEG)).collect();
        for t in 0..8 {
            for (pi, c) in cursors.iter_mut().enumerate() {
                assert_eq!(c.next_u64(), forward[pi][t]);
            }
        }
    }

    #[test]
    fn stream_rng_coordinates_give_distinct_streams() {
        let base = {
            let mut r = StreamRng::at(5, 10, 20, lane::LD);
            r.next_u64()
        };
        for (s, i, p, l) in [
            (6u64, 10u64, 20u64, lane::LD),
            (5, 11, 20, lane::LD),
            (5, 10, 21, lane::LD),
            (5, 10, 20, lane::HD),
            (5, 10, 20, lane::NEG),
            (5, 10, 20, lane::STEP),
        ] {
            let mut r = StreamRng::at(s, i, p, l);
            assert_ne!(r.next_u64(), base, "stream ({s},{i},{p},{l}) collides with base");
        }
    }

    #[test]
    fn stream_rng_below_in_range_and_roughly_uniform() {
        let mut counts = [0usize; 10];
        for point in 0..2000u64 {
            let mut r = StreamRng::at(1, 1, point, lane::NEG);
            for _ in 0..5 {
                let v = r.below(10);
                assert!(v < 10);
                counts[v] += 1;
            }
        }
        let expect = 10_000.0 / 10.0;
        for (v, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > expect * 0.85 && (c as f64) < expect * 1.15,
                "below(10) count[{v}] = {c}, expect ~{expect}"
            );
        }
    }

    /// `Rng` and `StreamRng` share the Lemire `below` via
    /// [`RandomSource`]; the trait path must agree with the inherent
    /// `Rng::below` draw-for-draw.
    #[test]
    fn trait_below_matches_inherent_below() {
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        for n in [1usize, 2, 3, 10, 1000, 12345] {
            for _ in 0..50 {
                let inherent = a.below(n);
                let through_trait = RandomSource::below(&mut b, n);
                assert_eq!(inherent, through_trait, "below({n}) diverged");
            }
        }
    }
}
