//! Deterministic pseudo-random number generation.
//!
//! A small, fast, reproducible generator (SplitMix64 seeding into
//! xoshiro256**), plus the distribution helpers the embedding engine and
//! the synthetic dataset generators need: uniforms, bounded integers,
//! Gaussians (Box–Muller with caching), shuffles and subset sampling.
//!
//! Determinism matters here: every experiment driver takes an explicit
//! seed so that paper figures regenerate bit-identically.

/// xoshiro256** pseudo-random generator.
///
/// Not cryptographic; chosen for speed (4×u64 state, ~1 ns/draw) and
/// quality sufficient for Monte-Carlo style sampling in the engine's
/// negative-sampling hot loop.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Identical seeds give
    /// identical streams on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-subsystem streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64 bits.
    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline(always)]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline(always)]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline(always)]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection
    /// to avoid modulo bias; `n` must be > 0.
    #[inline(always)]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        // Fast path: 64x64->128 multiply.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline(always)]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller, with spare caching.
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        // Avoid u == 0.
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with given mean / std-dev.
    #[inline]
    pub fn gauss_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n). O(k) expected via
    /// Floyd's algorithm for small k, falls back to shuffle for large k.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Floyd's: guarantees distinctness with O(k) draws.
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            m += g;
            v += g * g;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(10usize, 3usize), (100, 10), (50, 50), (1000, 5)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..64).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }
}
