//! CSV and NPY persistence.
//!
//! * CSV — the bench drivers dump every figure/table's raw numbers to
//!   `results/*.csv` so they can be re-plotted externally.
//! * NPY v1.0 (little-endian f32/i64, C-order) — the interchange format
//!   between the Rust side and optional Python analysis; a tiny reader /
//!   writer pair is implemented here because `ndarray-npy` is not
//!   available offline.

use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

/// Write a CSV file: a header row then one row per record.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a CSV file of f32 values: one row per line, comma-separated.
/// Returns `(data, rows, cols)` with `data` in C order.
///
/// The first non-empty line may be a textual header (as produced by
/// [`write_csv`]); it is skipped when any of its fields fails to parse
/// as a number. Blank lines are ignored; ragged rows are an error.
pub fn read_csv_f32(path: &Path) -> Result<(Vec<f32>, usize, usize)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    parse_csv_f32(&text).with_context(|| format!("parse {path:?}"))
}

/// Parse CSV text (see [`read_csv_f32`] for the accepted dialect).
pub fn parse_csv_f32(text: &str) -> Result<(Vec<f32>, usize, usize)> {
    let mut data = Vec::new();
    let mut rows = 0usize;
    let mut cols = 0usize;
    let mut seen_any = false;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let parsed: std::result::Result<Vec<f32>, _> =
            fields.iter().map(|f| f.parse::<f32>()).collect();
        match parsed {
            Ok(vals) => {
                if rows == 0 {
                    cols = vals.len();
                } else if vals.len() != cols {
                    bail!("line {}: {} fields, expected {cols}", lineno + 1, vals.len());
                }
                data.extend(vals);
                rows += 1;
            }
            Err(e) => {
                // Only the leading line may be non-numeric (a header).
                if !seen_any {
                    seen_any = true;
                    continue;
                }
                bail!("line {}: unparseable number ({e})", lineno + 1);
            }
        }
        seen_any = true;
    }
    if rows == 0 {
        bail!("no numeric rows");
    }
    Ok((data, rows, cols))
}

/// Read a dataset matrix from disk, dispatching on the file extension:
/// `.npy` ([`read_npy_f32`], 1-D shapes become a single column) or
/// `.csv` ([`read_csv_f32`]). Returns `(data, rows, cols)`.
pub fn read_matrix_f32(path: &Path) -> Result<(Vec<f32>, usize, usize)> {
    let ext = path
        .extension()
        .and_then(|e| e.to_str())
        .unwrap_or("")
        .to_ascii_lowercase();
    match ext.as_str() {
        "npy" => {
            let (data, shape) = read_npy_f32(path)?;
            match shape.len() {
                1 => {
                    let n = shape[0];
                    Ok((data, n, 1))
                }
                2 => Ok((data, shape[0], shape[1])),
                d => bail!("{path:?}: expected a 1-D or 2-D array, got {d}-D"),
            }
        }
        "csv" => read_csv_f32(path),
        other => bail!("unsupported dataset extension {other:?} for {path:?} (.npy or .csv)"),
    }
}

/// Write plain text (used for ASCII figures).
pub fn write_text(path: &Path, text: &str) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, text).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Write a C-order f32 matrix as NPY v1.0.
pub fn write_npy_f32(path: &Path, data: &[f32], shape: &[usize]) -> Result<()> {
    let expect: usize = shape.iter().product();
    if expect != data.len() {
        bail!("shape {:?} does not match data length {}", shape, data.len());
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = BufWriter::new(File::create(path)?);
    write_npy_header(&mut f, "<f4", shape)?;
    for &v in data {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_npy_header<W: Write>(f: &mut W, dtype: &str, shape: &[usize]) -> Result<()> {
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header =
        format!("{{'descr': '{dtype}', 'fortran_order': False, 'shape': {shape_str}, }}");
    // Pad with spaces so total (magic 6 + version 2 + len 2 + header) % 64 == 0,
    // header ends with '\n'.
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    f.write_all(b"\x93NUMPY")?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    Ok(())
}

/// Read an NPY file containing little-endian f32 (or f64, converted) in
/// C order. Returns (data, shape).
pub fn read_npy_f32(path: &Path) -> Result<(Vec<f32>, Vec<usize>)> {
    let mut bytes = Vec::new();
    File::open(path)
        .with_context(|| format!("open {path:?}"))?
        .read_to_end(&mut bytes)?;
    parse_npy_f32(&bytes)
}

/// Parse NPY bytes (v1.0/v2.0), f32 or f64 little-endian, C order.
pub fn parse_npy_f32(bytes: &[u8]) -> Result<(Vec<f32>, Vec<usize>)> {
    if bytes.len() < 10 || &bytes[0..6] != b"\x93NUMPY" {
        bail!("not an NPY file");
    }
    let major = bytes[6];
    let (hlen, hstart) = match major {
        1 => (u16::from_le_bytes([bytes[8], bytes[9]]) as usize, 10),
        2 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12,
        ),
        v => bail!("unsupported NPY version {v}"),
    };
    let header = std::str::from_utf8(&bytes[hstart..hstart + hlen])?;
    let descr = extract_quoted(header, "descr").context("descr missing")?;
    if header.contains("'fortran_order': True") {
        bail!("fortran order unsupported");
    }
    let shape = extract_shape(header)?;
    let count: usize = shape.iter().product();
    let body = &bytes[hstart + hlen..];
    let data = match descr.as_str() {
        "<f4" | "|f4" => {
            if body.len() < count * 4 {
                bail!("truncated f32 body");
            }
            body.chunks_exact(4)
                .take(count)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        "<f8" => {
            if body.len() < count * 8 {
                bail!("truncated f64 body");
            }
            body.chunks_exact(8)
                .take(count)
                .map(|c| {
                    f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                })
                .collect()
        }
        d => bail!("unsupported dtype {d}"),
    };
    Ok((data, shape))
}

fn extract_quoted(header: &str, key: &str) -> Option<String> {
    let kpos = header.find(&format!("'{key}'"))?;
    let rest = &header[kpos..];
    let colon = rest.find(':')?;
    let rest = rest[colon + 1..].trim_start();
    let rest = rest.strip_prefix('\'')?;
    let end = rest.find('\'')?;
    Some(rest[..end].to_string())
}

fn extract_shape(header: &str) -> Result<Vec<usize>> {
    let kpos = header.find("'shape'").context("shape missing")?;
    let rest = &header[kpos..];
    let open = rest.find('(').context("shape tuple missing")?;
    let close = rest[open..].find(')').context("shape tuple unclosed")? + open;
    let inner = &rest[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        shape.push(p.parse::<usize>().with_context(|| format!("bad dim {p:?}"))?);
    }
    if shape.is_empty() {
        shape.push(1);
    }
    Ok(shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("funcsne_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn npy_roundtrip_2d() {
        let path = tmp("rt2d.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_npy_f32(&path, &data, &[3, 4]).unwrap();
        let (back, shape) = read_npy_f32(&path).unwrap();
        assert_eq!(shape, vec![3, 4]);
        assert_eq!(back, data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn npy_roundtrip_1d() {
        let path = tmp("rt1d.npy");
        let data = vec![1.5f32, -2.0, 3.25];
        write_npy_f32(&path, &data, &[3]).unwrap();
        let (back, shape) = read_npy_f32(&path).unwrap();
        assert_eq!(shape, vec![3]);
        assert_eq!(back, data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn npy_rejects_bad_shape() {
        let path = tmp("bad.npy");
        assert!(write_npy_f32(&path, &[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn npy_rejects_garbage() {
        assert!(parse_npy_f32(b"not an npy at all").is_err());
    }

    #[test]
    fn csv_roundtrip_against_write_csv() {
        let path = tmp("rt.csv");
        let values = [[1.5f32, -2.0, 0.25], [3.0, 4.5, -0.125], [0.0, 7.0, 9.5]];
        let rows: Vec<Vec<String>> = values
            .iter()
            .map(|r| r.iter().map(|v| v.to_string()).collect())
            .collect();
        write_csv(&path, &["x0", "x1", "x2"], &rows).unwrap();
        let (data, n, d) = read_csv_f32(&path).unwrap();
        assert_eq!((n, d), (3, 3));
        let flat: Vec<f32> = values.iter().flatten().copied().collect();
        assert_eq!(data, flat, "header skipped, values exact");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn csv_parses_without_header_and_skips_blanks() {
        let (data, n, d) = parse_csv_f32("1,2\n\n3,4\n").unwrap();
        assert_eq!((n, d), (2, 2));
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn csv_rejects_ragged_rows_and_mid_file_text() {
        assert!(parse_csv_f32("1,2\n3\n").is_err(), "ragged");
        assert!(parse_csv_f32("1,2\nx,y\n").is_err(), "text after data");
        assert!(parse_csv_f32("a,b\nc,d\n").is_err(), "two header-ish lines");
        assert!(parse_csv_f32("").is_err(), "empty");
        assert!(parse_csv_f32("a,b\n").is_err(), "header only");
    }

    #[test]
    fn read_matrix_dispatches_on_extension() {
        let npy = tmp("dispatch.npy");
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        write_npy_f32(&npy, &data, &[2, 3]).unwrap();
        let (back, n, d) = read_matrix_f32(&npy).unwrap();
        assert_eq!((n, d), (2, 3));
        assert_eq!(back, data);
        std::fs::remove_file(npy).ok();

        let bad = tmp("dispatch.parquet");
        std::fs::write(&bad, b"x").unwrap();
        assert!(read_matrix_f32(&bad).is_err());
        std::fs::remove_file(bad).ok();
    }

    #[test]
    fn csv_writes_rows() {
        let path = tmp("c.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        std::fs::remove_file(path).ok();
    }
}
