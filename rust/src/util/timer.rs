//! Wall-clock timing helpers for the bench harnesses, and the
//! [`PhaseClock`] shim — the only clock deterministic modules may
//! touch.
//!
//! `criterion` is unavailable offline, so the figure/bench drivers use
//! this small stopwatch plus `bench_fn` for repeated timed runs with
//! basic robust statistics (median, min, mean).

use std::time::{Duration, Instant};

/// The telemetry clock for deterministic modules (`engine/`, `knn/`,
/// …), where the `wall_clock` lint rule bans `Instant`/`SystemTime`
/// directly. Centralizing the reads here keeps the constraint
/// auditable: timing is observational only — it feeds phase
/// accounting and scheduling telemetry, never the computation — and
/// one shim is much easier to check than N call sites. (It also gives
/// a single seam if a platform ever needs a different monotonic
/// source.)
#[derive(Clone, Copy, Debug)]
pub struct PhaseClock {
    start: Instant,
}

impl PhaseClock {
    /// Start timing a phase.
    pub fn start() -> PhaseClock {
        PhaseClock { start: Instant::now() }
    }

    /// Nanoseconds since [`PhaseClock::start`], saturating at
    /// `u64::MAX` (584 years — effectively never).
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.start.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// A resettable stopwatch accumulating named phases.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Seconds elapsed since construction or last `reset`.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Result of a repeated timing run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub mean_s: f64,
}

impl BenchStats {
    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: min {:.3} ms | median {:.3} ms | p95 {:.3} ms | mean {:.3} ms ({} iters)",
            self.min_s * 1e3,
            self.median_s * 1e3,
            self.p95_s * 1e3,
            self.mean_s * 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` unmeasured runs, then `iters` measured
/// runs, returning robust statistics. Each run's return value is passed
/// through `std::hint::black_box` to defeat dead-code elimination.
pub fn bench_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min_s = times[0];
    // Interpolated quantiles — the same helper the obs histograms use,
    // so (unlike the old upper-of-two pick) an even `iters` count
    // yields the true median.
    let median_s = crate::obs::hist::quantile_sorted(&times, 0.5);
    let p95_s = crate::obs::hist::quantile_sorted(&times, 0.95);
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    BenchStats { iters, min_s, median_s, p95_s, mean_s }
}

/// Format a duration human-readably for progress logs.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_clock_monotonic() {
        let pc = PhaseClock::start();
        let a = pc.elapsed_ns();
        let b = pc.elapsed_ns();
        assert!(b >= a);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn bench_fn_counts_iters() {
        let stats = bench_fn(1, 5, || 1 + 1);
        assert_eq!(stats.iters, 5);
        assert!(stats.min_s <= stats.median_s);
        assert!(stats.median_s <= stats.p95_s);
        assert!(stats.min_s <= stats.mean_s);
        assert!(stats.summary("x").contains("p95"));
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).contains(" s"));
        assert!(fmt_duration(Duration::from_secs(500)).contains("min"));
    }
}
