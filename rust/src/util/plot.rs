//! ASCII plotting for terminal figure output.
//!
//! The paper's figures are curves (R_NX(K), AUC vs iteration, runtime vs
//! N) and 2-D scatter embeddings. The bench drivers render both to the
//! terminal and to `results/*.txt`, alongside machine-readable CSV, so
//! the "figures" regenerate on any machine without a plotting stack.

/// A single named series for a line chart.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>, xs: Vec<f64>, ys: Vec<f64>) -> Self {
        assert_eq!(xs.len(), ys.len(), "series x/y length mismatch");
        Series { name: name.into(), xs, ys }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render multiple series on one ASCII canvas with axes and a legend.
///
/// `logx` plots x on a log10 scale (used by R_NX(K) figures, where K is
/// logarithmic in the paper).
pub fn line_chart(
    title: &str,
    series: &[Series],
    width: usize,
    height: usize,
    logx: bool,
) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if y.is_finite() && x.is_finite() {
                pts.push((tx(x, logx), y));
            }
        }
    }
    if pts.is_empty() {
        return format!("{title}\n  (no finite data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if !(x.is_finite() && y.is_finite()) {
                continue;
            }
            let gx = ((tx(x, logx) - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let gy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - gy.min(height - 1);
            grid[row][gx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>9.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    let xlabel = if logx {
        format!("x: log10 [{:.3} .. {:.3}]", 10f64.powf(xmin), 10f64.powf(xmax))
    } else {
        format!("x: [{xmin:.3} .. {xmax:.3}]")
    };
    out.push_str(&format!("{:>10} {xlabel}\n", ""));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

fn tx(x: f64, logx: bool) -> f64 {
    if logx {
        x.max(1e-12).log10()
    } else {
        x
    }
}

/// Render a 2-D embedding as an ASCII scatter, marking each point with a
/// per-label character (labels beyond 62 wrap).
pub fn scatter_2d(
    title: &str,
    ys: &[f32],
    labels: &[usize],
    n: usize,
    width: usize,
    height: usize,
) -> String {
    assert_eq!(ys.len(), n * 2, "scatter_2d expects a (N,2) embedding");
    const CHARS: &[u8] = b"0123456789abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ";
    let (mut xmin, mut xmax) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f32::INFINITY, f32::NEG_INFINITY);
    for i in 0..n {
        xmin = xmin.min(ys[2 * i]);
        xmax = xmax.max(ys[2 * i]);
        ymin = ymin.min(ys[2 * i + 1]);
        ymax = ymax.max(ys[2 * i + 1]);
    }
    if !(xmin.is_finite() && ymin.is_finite()) {
        return format!("{title}\n  (non-finite embedding)\n");
    }
    let dx = (xmax - xmin).max(1e-9);
    let dy = (ymax - ymin).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for i in 0..n {
        let gx = (((ys[2 * i] - xmin) / dx) * (width - 1) as f32).round() as usize;
        let gy = (((ys[2 * i + 1] - ymin) / dy) * (height - 1) as f32).round() as usize;
        let c = CHARS[labels.get(i).copied().unwrap_or(0) % CHARS.len()] as char;
        grid[height - 1 - gy.min(height - 1)][gx.min(width - 1)] = c;
    }
    let mut out = String::with_capacity(width * height + 64);
    out.push_str(title);
    out.push('\n');
    for row in grid {
        out.push_str("  ");
        out.extend(row.iter());
        out.push('\n');
    }
    out
}

/// A text histogram (used for the Fig. 3 inter-cluster direction
/// histograms).
pub fn histogram(title: &str, values_a: &[f64], values_b: &[f64], bins: usize) -> String {
    let all: Vec<f64> = values_a.iter().chain(values_b).copied().collect();
    if all.is_empty() {
        return format!("{title}\n  (empty)\n");
    }
    let lo = all.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = all.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let w = ((hi - lo).max(1e-12)) / bins as f64;
    let count = |vals: &[f64], b: usize| {
        vals.iter()
            .filter(|&&v| {
                let idx = (((v - lo) / w) as usize).min(bins - 1);
                idx == b
            })
            .count()
    };
    let maxc = (0..bins)
        .map(|b| count(values_a, b) + count(values_b, b))
        .max()
        .unwrap_or(1)
        .max(1);
    let mut out = format!("{title}  [{lo:.3} .. {hi:.3}], A=red(#) B=blue(=)\n");
    for b in 0..bins {
        let ca = count(values_a, b);
        let cb = count(values_b, b);
        let wa = ca * 60 / maxc;
        let wb = cb * 60 / maxc;
        out.push_str(&format!(
            "  {:>8.3} | {}{}\n",
            lo + (b as f64 + 0.5) * w,
            "#".repeat(wa),
            "=".repeat(wb)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_contains_series_markers_and_legend() {
        let s1 = Series::new("one", vec![0.0, 1.0, 2.0], vec![0.0, 1.0, 4.0]);
        let s2 = Series::new("two", vec![0.0, 1.0, 2.0], vec![4.0, 1.0, 0.0]);
        let out = line_chart("test", &[s1, s2], 40, 10, false);
        assert!(out.contains("one"));
        assert!(out.contains("two"));
        assert!(out.contains('*'));
        assert!(out.contains('o'));
    }

    #[test]
    fn line_chart_handles_empty_and_constant() {
        let out = line_chart("t", &[Series::new("e", vec![], vec![])], 10, 5, false);
        assert!(out.contains("no finite data"));
        let s = Series::new("c", vec![1.0, 2.0], vec![3.0, 3.0]);
        let out = line_chart("t", &[s], 10, 5, true);
        assert!(out.contains('*'));
    }

    #[test]
    fn scatter_renders_labels() {
        let ys = vec![0.0, 0.0, 1.0, 1.0, -1.0, 0.5];
        let out = scatter_2d("s", &ys, &[0, 1, 2], 3, 20, 10);
        assert!(out.contains('0'));
        assert!(out.contains('1'));
        assert!(out.contains('2'));
    }

    #[test]
    fn histogram_counts() {
        let a = vec![0.0, 0.1, 0.2];
        let b = vec![0.9, 1.0];
        let out = histogram("h", &a, &b, 4);
        assert!(out.contains('#'));
        assert!(out.contains('='));
    }
}
