//! Zero-dependency substrate utilities.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency tree vendored, so everything that would normally come from
//! `rand`, `serde`, `csv`, `criterion` or `proptest` is implemented here:
//! a counter-based RNG, wall-clock timing helpers, CSV/NPY persistence,
//! terminal (ASCII) plotting for the figure benches, and a miniature
//! property-testing harness.

pub mod rng;
pub mod simd;
pub mod timer;
pub mod plot;
pub mod io;
pub mod proptest;
pub mod stats;

pub use rng::{lane, RandomSource, Rng, StreamRng};
pub use simd::F32x8;
pub use timer::{PhaseClock, Stopwatch};
