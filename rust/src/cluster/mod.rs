//! Clustering substrate: DBSCAN, the α-sweep hierarchy graph (Figs 9/10)
//! and a force-directed layout for rendering the graph.

pub mod dbscan;
pub mod hierarchy;
pub mod layout;
