//! Force-directed layout (Fruchterman–Reingold flavoured) for rendering
//! hierarchy graphs (Figs 9/10) as ASCII / CSV output.

use super::hierarchy::HierarchyGraph;
use crate::util::Rng;

/// 2-D node positions for a hierarchy graph.
pub fn layout(graph: &HierarchyGraph, iters: usize, seed: u64) -> Vec<(f32, f32)> {
    let n = graph.nodes.len();
    let mut rng = Rng::new(seed);
    let mut pos: Vec<(f32, f32)> = (0..n)
        .map(|_| (rng.gauss() as f32, rng.gauss() as f32))
        .collect();
    if n <= 1 {
        return pos;
    }
    let area_k = (1.0 / n as f32).sqrt() * 4.0;
    let mut disp = vec![(0.0f32, 0.0f32); n];
    for it in 0..iters {
        let temp = 0.5 * (1.0 - it as f32 / iters as f32) + 0.01;
        for d in disp.iter_mut() {
            *d = (0.0, 0.0);
        }
        // Repulsion between all node pairs.
        for a in 0..n {
            for b in (a + 1)..n {
                let dx = pos[a].0 - pos[b].0;
                let dy = pos[a].1 - pos[b].1;
                let d2 = (dx * dx + dy * dy).max(1e-6);
                let f = area_k * area_k / d2;
                disp[a].0 += dx * f;
                disp[a].1 += dy * f;
                disp[b].0 -= dx * f;
                disp[b].1 -= dy * f;
            }
        }
        // Attraction along weighted edges.
        for e in &graph.edges {
            let (a, b) = (e.from, e.to);
            let dx = pos[a].0 - pos[b].0;
            let dy = pos[a].1 - pos[b].1;
            let d = (dx * dx + dy * dy).sqrt().max(1e-6);
            let f = d / area_k * e.weight as f32;
            disp[a].0 -= dx / d * f;
            disp[a].1 -= dy / d * f;
            disp[b].0 += dx / d * f;
            disp[b].1 += dy / d * f;
        }
        for i in 0..n {
            let (dx, dy) = disp[i];
            let d = (dx * dx + dy * dy).sqrt().max(1e-9);
            let step = d.min(temp);
            pos[i].0 += dx / d * step;
            pos[i].1 += dy / d * step;
        }
    }
    pos
}

/// Render the graph + layout as ASCII (nodes labelled `Lℓ.c`, larger
/// clusters shown with `#`-intensity marks), with an edge list appendix.
pub fn render_ascii(graph: &HierarchyGraph, pos: &[(f32, f32)], width: usize, height: usize) -> String {
    let mut out = String::new();
    if graph.nodes.is_empty() {
        return "(empty hierarchy graph)\n".to_string();
    }
    let (mut xmin, mut xmax, mut ymin, mut ymax) =
        (f32::INFINITY, f32::NEG_INFINITY, f32::INFINITY, f32::NEG_INFINITY);
    for &(x, y) in pos {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    let dx = (xmax - xmin).max(1e-6);
    let dy = (ymax - ymin).max(1e-6);
    let mut grid = vec![vec![' '; width]; height];
    for (i, node) in graph.nodes.iter().enumerate() {
        let gx = (((pos[i].0 - xmin) / dx) * (width - 1) as f32).round() as usize;
        let gy = (((pos[i].1 - ymin) / dy) * (height - 1) as f32).round() as usize;
        let c = char::from_digit(node.level as u32 % 10, 10).unwrap_or('?');
        grid[height - 1 - gy.min(height - 1)][gx.min(width - 1)] = c;
    }
    out.push_str("hierarchy graph (digit = level):\n");
    for row in grid {
        out.push_str("  ");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("edges (from -> to, weight, sizes):\n");
    for e in &graph.edges {
        let f = &graph.nodes[e.from];
        let t = &graph.nodes[e.to];
        out.push_str(&format!(
            "  L{}.{} ({} pts) -> L{}.{} ({} pts)  w={:.2}\n",
            f.level,
            f.cluster,
            f.members.len(),
            t.level,
            t.cluster,
            t.members.len(),
            e.weight
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hierarchy::{build_graph, HierNode};

    fn toy_graph() -> HierarchyGraph {
        let l0 = vec![HierNode { level: 0, cluster: 0, members: (0..10).collect() }];
        let l1 = vec![
            HierNode { level: 0, cluster: 0, members: (0..5).collect() },
            HierNode { level: 0, cluster: 1, members: (5..10).collect() },
        ];
        build_graph(vec![l0, l1])
    }

    #[test]
    fn layout_produces_finite_distinct_positions() {
        let g = toy_graph();
        let pos = layout(&g, 100, 1);
        assert_eq!(pos.len(), 3);
        for &(x, y) in &pos {
            assert!(x.is_finite() && y.is_finite());
        }
        // Siblings should not collapse onto each other.
        let d = ((pos[1].0 - pos[2].0).powi(2) + (pos[1].1 - pos[2].1).powi(2)).sqrt();
        assert!(d > 1e-3, "siblings collapsed: {d}");
    }

    #[test]
    fn connected_nodes_closer_than_average() {
        let g = toy_graph();
        let pos = layout(&g, 200, 2);
        let dist = |a: usize, b: usize| {
            ((pos[a].0 - pos[b].0).powi(2) + (pos[a].1 - pos[b].1).powi(2)).sqrt()
        };
        // parent-child distances vs sibling distance
        let pc = (dist(0, 1) + dist(0, 2)) / 2.0;
        let sib = dist(1, 2);
        assert!(pc <= sib * 1.5, "layout ignores edges: pc={pc} sib={sib}");
    }

    #[test]
    fn render_contains_levels_and_edges() {
        let g = toy_graph();
        let pos = layout(&g, 50, 3);
        let s = render_ascii(&g, &pos, 40, 12);
        assert!(s.contains('0'));
        assert!(s.contains('1'));
        assert!(s.contains("w=1.00"));
    }
}
