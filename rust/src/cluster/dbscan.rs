//! DBSCAN (Ester et al. [26]) — the clustering step of the paper's
//! hierarchy-extraction algorithm (§4.2): "Clustering is carried out
//! here with DBSCAN, chosen for its speed and ability to adapt to
//! different number of clusters."
//!
//! Region queries use a uniform grid over the (low-dimensional)
//! embedding when d ≤ 4, falling back to a linear scan otherwise —
//! embeddings handed to DBSCAN in this codebase are ≤ 8-dimensional and
//! a few thousand points, where either path is fast.

use crate::data::Matrix;

/// Label for noise points.
pub const NOISE: i32 = -1;

/// DBSCAN result: cluster id per point (−1 = noise) + cluster count.
#[derive(Clone, Debug)]
pub struct DbscanResult {
    pub labels: Vec<i32>,
    pub n_clusters: usize,
}

/// Run DBSCAN with radius `eps` and density threshold `min_pts`.
pub fn dbscan(y: &Matrix, eps: f64, min_pts: usize) -> DbscanResult {
    let n = y.n();
    let eps2 = (eps * eps) as f32;
    let index = GridIndex::build(y, eps as f32);
    let mut labels = vec![i32::MIN; n]; // MIN = unvisited
    let mut cluster = 0i32;
    let mut seeds: Vec<usize> = Vec::new();
    let mut neigh: Vec<usize> = Vec::new();
    for i in 0..n {
        if labels[i] != i32::MIN {
            continue;
        }
        index.range_query(y, i, eps2, &mut neigh);
        if neigh.len() < min_pts {
            labels[i] = NOISE;
            continue;
        }
        // Expand a new cluster from the core point i.
        labels[i] = cluster;
        seeds.clear();
        seeds.extend(neigh.iter().copied());
        let mut s = 0;
        while s < seeds.len() {
            let q = seeds[s];
            s += 1;
            if labels[q] == NOISE {
                labels[q] = cluster; // border point
            }
            if labels[q] != i32::MIN {
                continue;
            }
            labels[q] = cluster;
            index.range_query(y, q, eps2, &mut neigh);
            if neigh.len() >= min_pts {
                seeds.extend(neigh.iter().copied());
            }
        }
        cluster += 1;
    }
    DbscanResult { labels, n_clusters: cluster as usize }
}

/// Pick `eps` automatically as a quantile of the k-th nearest-neighbour
/// distance (the standard knee heuristic, simplified). Used by the
/// hierarchy sweep where each snapshot has a different scale.
pub fn auto_eps(y: &Matrix, k: usize, quantile: f64) -> f64 {
    let n = y.n();
    let sample = n.min(512);
    let stride = (n / sample).max(1);
    let mut kth = Vec::with_capacity(sample);
    for i in (0..n).step_by(stride) {
        let mut best = vec![f32::INFINITY; k];
        for j in 0..n {
            if j == i {
                continue;
            }
            let d = y.sqdist(i, j);
            // insertion into tiny sorted array
            if d < best[k - 1] {
                let mut t = k - 1;
                while t > 0 && best[t - 1] > d {
                    best[t] = best[t - 1];
                    t -= 1;
                }
                best[t] = d;
            }
        }
        kth.push(best[k - 1].sqrt() as f64);
    }
    kth.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((kth.len() as f64 - 1.0) * quantile).round() as usize;
    kth[idx.min(kth.len() - 1)].max(1e-9)
}

/// Uniform grid for range queries in low dimensions.
struct GridIndex {
    cell: f32,
    dims: usize,
    origin: Vec<f32>,
    shape: Vec<usize>,
    /// cell -> point ids
    buckets: Vec<Vec<u32>>,
    /// Fallback when d > 4: empty grid, linear scans.
    linear: bool,
}

impl GridIndex {
    fn build(y: &Matrix, eps: f32) -> GridIndex {
        let n = y.n();
        let d = y.d();
        if d > 4 || n < 64 {
            return GridIndex {
                cell: eps.max(1e-9),
                dims: d,
                origin: vec![],
                shape: vec![],
                buckets: vec![],
                linear: true,
            };
        }
        let cell = eps.max(1e-9);
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..n {
            for (c, &v) in y.row(i).iter().enumerate() {
                lo[c] = lo[c].min(v);
                hi[c] = hi[c].max(v);
            }
        }
        let mut shape = Vec::with_capacity(d);
        let mut total = 1usize;
        for c in 0..d {
            let s = (((hi[c] - lo[c]) / cell).floor() as usize + 1).max(1);
            // Cap the grid so memory stays bounded for tiny eps.
            let s = s.min(512);
            shape.push(s);
            total = total.saturating_mul(s);
            if total > 4_000_000 {
                return GridIndex {
                    cell,
                    dims: d,
                    origin: vec![],
                    shape: vec![],
                    buckets: vec![],
                    linear: true,
                };
            }
        }
        let mut buckets = vec![Vec::new(); total];
        let origin = lo;
        let idx_of = |row: &[f32]| -> usize {
            let mut idx = 0usize;
            for c in 0..d {
                let b = (((row[c] - origin[c]) / cell) as usize).min(shape[c] - 1);
                idx = idx * shape[c] + b;
            }
            idx
        };
        for i in 0..n {
            buckets[idx_of(y.row(i))].push(i as u32);
        }
        GridIndex { cell, dims: d, origin, shape, buckets, linear: false }
    }

    fn range_query(&self, y: &Matrix, i: usize, eps2: f32, out: &mut Vec<usize>) {
        out.clear();
        let n = y.n();
        if self.linear {
            for j in 0..n {
                if y.sqdist(i, j) <= eps2 {
                    out.push(j);
                }
            }
            return;
        }
        let d = self.dims;
        let row = y.row(i);
        // Walk the 3^d neighbourhood of the point's cell.
        let mut cells: Vec<usize> = vec![0];
        for c in 0..d {
            let b = (((row[c] - self.origin[c]) / self.cell) as isize)
                .clamp(0, self.shape[c] as isize - 1);
            let mut next = Vec::with_capacity(cells.len() * 3);
            for off in -1isize..=1 {
                let bb = b + off;
                if bb < 0 || bb >= self.shape[c] as isize {
                    continue;
                }
                for &base in &cells {
                    next.push(base * self.shape[c] + bb as usize);
                }
            }
            cells = next;
        }
        for &cell in &cells {
            for &j in &self.buckets[cell] {
                if y.sqdist(i, j as usize) <= eps2 {
                    out.push(j as usize);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn finds_separated_blobs() {
        let ds = datasets::blobs(300, 2, 3, 0.3, 20.0, 1);
        let res = dbscan(&ds.x, 1.5, 4);
        assert_eq!(res.n_clusters, 3, "labels: {:?}", &res.labels[..20]);
        // Cluster assignment must be consistent with ground truth.
        for i in 0..300 {
            for j in 0..300 {
                if ds.labels[i] == ds.labels[j]
                    && res.labels[i] >= 0
                    && res.labels[j] >= 0
                {
                    assert_eq!(res.labels[i], res.labels[j], "split a true cluster");
                }
            }
        }
    }

    #[test]
    fn grid_and_linear_agree() {
        // d=2 triggers the grid; force linear by slicing into d=5.
        let ds2 = datasets::blobs(400, 2, 4, 0.5, 15.0, 2);
        let res_grid = dbscan(&ds2.x, 1.2, 4);
        // Rebuild as 5-d with zero padding → same distances → same result.
        let mut x5 = Matrix::zeros(400, 5);
        for i in 0..400 {
            x5.row_mut(i)[..2].copy_from_slice(ds2.x.row(i));
        }
        let res_lin = dbscan(&x5, 1.2, 4);
        assert_eq!(res_grid.n_clusters, res_lin.n_clusters);
        // Same partition up to label renaming.
        let mut mapping = std::collections::HashMap::new();
        for i in 0..400 {
            let (a, b) = (res_grid.labels[i], res_lin.labels[i]);
            assert_eq!(a < 0, b < 0, "noise status differs at {i}");
            if a >= 0 {
                let m = mapping.entry(a).or_insert(b);
                assert_eq!(*m, b, "partitions differ at {i}");
            }
        }
    }

    #[test]
    fn noise_detected() {
        // 2 tight pairs + 1 isolated point far away: isolated = noise
        // with min_pts 2.
        let data = vec![
            0.0, 0.0, 0.1, 0.0, //
            10.0, 10.0, 10.1, 10.0, //
            50.0, 50.0,
        ];
        let y = Matrix::from_vec(data, 5, 2).unwrap();
        let res = dbscan(&y, 0.5, 2);
        assert_eq!(res.labels[4], NOISE);
        assert_eq!(res.n_clusters, 2);
    }

    #[test]
    fn auto_eps_scales_with_data() {
        let tight = datasets::blobs(200, 2, 1, 0.1, 1.0, 3);
        let wide = datasets::blobs(200, 2, 1, 10.0, 1.0, 3);
        let e1 = auto_eps(&tight.x, 4, 0.8);
        let e2 = auto_eps(&wide.x, 4, 0.8);
        assert!(e2 > e1 * 10.0, "auto_eps not scale-aware: {e1} vs {e2}");
    }
}
