//! The paper's hierarchy-extraction algorithm (§4.2, Figs 9/10).
//!
//! An embedding under continual optimisation has its LD kernel tails
//! made progressively heavier (α decreasing); snapshots X^ℓ are taken at
//! intervals, each clustered with DBSCAN, and a level-graph is built
//! where cluster C_i^(g) connects to C_j^(h) iff |h−g| = 1 with weight
//!
//! ```text
//! e_ij = |C_i ∩ C_j| / min(|C_i|, |C_j|)
//! ```
//!
//! The resulting graph is the paper's interactive hierarchy view; here
//! it is rendered with a force-directed layout (Fig. 9/10 style) and
//! evaluated against planted ground-truth trees in the tests.

use super::dbscan::{auto_eps, dbscan};
use crate::data::Matrix;
use crate::engine::{ComputeBackend, FuncSne};
use anyhow::Result;

/// One node of the hierarchy graph.
#[derive(Clone, Debug)]
pub struct HierNode {
    /// Level index ℓ (0 = lightest tails).
    pub level: usize,
    /// Cluster id within the level.
    pub cluster: i32,
    /// Member point indices.
    pub members: Vec<u32>,
}

/// Weighted edge between nodes of adjacent levels.
#[derive(Clone, Debug)]
pub struct HierEdge {
    pub from: usize,
    pub to: usize,
    /// Overlap weight in (0, 1].
    pub weight: f64,
}

/// The level graph.
#[derive(Clone, Debug, Default)]
pub struct HierarchyGraph {
    pub nodes: Vec<HierNode>,
    pub edges: Vec<HierEdge>,
    pub levels: usize,
}

impl HierarchyGraph {
    pub fn nodes_at(&self, level: usize) -> impl Iterator<Item = (usize, &HierNode)> {
        self.nodes.iter().enumerate().filter(move |(_, n)| n.level == level)
    }

    /// The strongest parent (previous-level node) of node `idx`.
    pub fn parent_of(&self, idx: usize) -> Option<usize> {
        self.edges
            .iter()
            .filter(|e| e.to == idx && self.nodes[e.from].level + 1 == self.nodes[idx].level)
            .max_by(|a, b| a.weight.partial_cmp(&b.weight).unwrap())
            .map(|e| e.from)
    }
}

/// Configuration of the α-sweep.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// α per level, decreasing (heavier tails deeper).
    pub alphas: Vec<f64>,
    /// Engine iterations between snapshots.
    pub iters_per_level: usize,
    /// DBSCAN min_pts.
    pub min_pts: usize,
    /// Quantile for the auto-eps heuristic.
    pub eps_quantile: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            alphas: vec![1.0, 0.7, 0.5],
            iters_per_level: 250,
            min_pts: 5,
            eps_quantile: 0.7,
        }
    }
}

/// Cluster a snapshot; noise points are dropped from node membership
/// (matching the paper's rendering, which draws clusters only).
pub fn cluster_snapshot(y: &Matrix, min_pts: usize, eps_quantile: f64) -> Vec<HierNode> {
    let eps = auto_eps(y, min_pts.min(4).max(2), eps_quantile);
    let res = dbscan(y, eps, min_pts);
    let mut nodes: Vec<HierNode> = (0..res.n_clusters)
        .map(|c| HierNode { level: 0, cluster: c as i32, members: Vec::new() })
        .collect();
    for (i, &l) in res.labels.iter().enumerate() {
        if l >= 0 {
            nodes[l as usize].members.push(i as u32);
        }
    }
    nodes.retain(|n| !n.members.is_empty());
    nodes
}

/// Build the level graph from per-level cluster lists.
pub fn build_graph(mut levels: Vec<Vec<HierNode>>) -> HierarchyGraph {
    let mut graph = HierarchyGraph::default();
    graph.levels = levels.len();
    let n_points = levels
        .iter()
        .flat_map(|l| l.iter().flat_map(|n| n.members.iter()))
        .map(|&m| m as usize + 1)
        .max()
        .unwrap_or(0);
    let mut prev_ids: Vec<usize> = Vec::new();
    let mut membership = vec![-1i64; n_points];
    for (level, nodes) in levels.drain(..).enumerate() {
        let mut cur_ids = Vec::new();
        for mut node in nodes {
            node.level = level;
            let id = graph.nodes.len();
            cur_ids.push(id);
            graph.nodes.push(node);
        }
        if level > 0 {
            // Overlap of each current node with each previous-level node.
            for &pid in &prev_ids {
                for m in &graph.nodes[pid].members {
                    membership[*m as usize] = pid as i64;
                }
            }
            for &cid in &cur_ids {
                // BTreeMap: `counts` iteration below fixes edge order.
                let mut counts = std::collections::BTreeMap::<usize, usize>::new();
                for m in &graph.nodes[cid].members {
                    let p = membership[*m as usize];
                    if p >= 0 {
                        *counts.entry(p as usize).or_insert(0) += 1;
                    }
                }
                for (pid, inter) in counts {
                    let denom = graph.nodes[pid].members.len().min(graph.nodes[cid].members.len());
                    if denom > 0 {
                        graph.edges.push(HierEdge {
                            from: pid,
                            to: cid,
                            weight: inter as f64 / denom as f64,
                        });
                    }
                }
            }
            // Reset membership stamps for the next level pair.
            for &pid in &prev_ids {
                for m in &graph.nodes[pid].members {
                    membership[*m as usize] = -1;
                }
            }
        }
        prev_ids = cur_ids;
    }
    graph
}

/// Run the full α-sweep on a live engine: lower α level by level,
/// optimise, snapshot, cluster, and build the graph.
pub fn alpha_sweep(
    engine: &mut FuncSne,
    backend: &mut dyn ComputeBackend,
    cfg: &SweepConfig,
) -> Result<HierarchyGraph> {
    let mut levels = Vec::with_capacity(cfg.alphas.len());
    for &alpha in &cfg.alphas {
        engine.set_alpha(alpha);
        engine.run(cfg.iters_per_level, backend)?;
        levels.push(cluster_snapshot(engine.embedding(), cfg.min_pts, cfg.eps_quantile));
    }
    Ok(build_graph(levels))
}

/// Tree-recovery score against a planted 2-level ground truth:
/// for every pair of leaf-level nodes, do they agree with the planted
/// tree on "share a parent"? Uses each node's majority true-label.
/// Returns the fraction of correctly-classified node pairs (1 = perfect).
pub fn tree_agreement(
    graph: &HierarchyGraph,
    leaf_level: usize,
    point_leaf_labels: &[usize],
    planted_parent: &[usize],
) -> f64 {
    let leaves: Vec<usize> = graph
        .nodes_at(leaf_level)
        .map(|(id, _)| id)
        .collect();
    if leaves.len() < 2 {
        return 0.0;
    }
    // Majority planted leaf label per graph node.
    let majority: Vec<usize> = leaves
        .iter()
        .map(|&id| {
            // BTreeMap: deterministic tie-break in max_by_key below.
            let mut counts = std::collections::BTreeMap::new();
            for m in &graph.nodes[id].members {
                *counts.entry(point_leaf_labels[*m as usize]).or_insert(0usize) += 1;
            }
            counts.into_iter().max_by_key(|&(_, c)| c).map(|(l, _)| l).unwrap_or(0)
        })
        .collect();
    let mut correct = 0usize;
    let mut total = 0usize;
    for a in 0..leaves.len() {
        for b in (a + 1)..leaves.len() {
            let same_true =
                planted_parent[majority[a]] == planted_parent[majority[b]];
            let pa = graph.parent_of(leaves[a]);
            let pb = graph.parent_of(leaves[b]);
            let same_graph = pa.is_some() && pa == pb;
            total += 1;
            if same_true == same_graph {
                correct += 1;
            }
        }
    }
    correct as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    #[test]
    fn cluster_snapshot_finds_blobs() {
        let ds = datasets::blobs(300, 2, 4, 0.3, 25.0, 1);
        let nodes = cluster_snapshot(&ds.x, 5, 0.9);
        assert_eq!(nodes.len(), 4, "found {} clusters", nodes.len());
    }

    #[test]
    fn build_graph_links_overlapping_clusters() {
        // Level 0: one cluster {0..9}; level 1: two clusters {0..4},{5..9}.
        let l0 = vec![HierNode { level: 0, cluster: 0, members: (0..10).collect() }];
        let l1 = vec![
            HierNode { level: 0, cluster: 0, members: (0..5).collect() },
            HierNode { level: 0, cluster: 1, members: (5..10).collect() },
        ];
        let g = build_graph(vec![l0, l1]);
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.edges.len(), 2);
        for e in &g.edges {
            assert_eq!(e.from, 0);
            assert!((e.weight - 1.0).abs() < 1e-9, "full containment ⇒ weight 1");
        }
        assert_eq!(g.parent_of(1), Some(0));
        assert_eq!(g.parent_of(2), Some(0));
    }

    #[test]
    fn partial_overlap_weights() {
        let l0 = vec![
            HierNode { level: 0, cluster: 0, members: (0..6).collect() },
            HierNode { level: 0, cluster: 1, members: (6..12).collect() },
        ];
        // one level-1 cluster straddling both: 2 from A, 4 from B
        let l1 = vec![HierNode {
            level: 0,
            cluster: 0,
            members: vec![4, 5, 6, 7, 8, 9],
        }];
        let g = build_graph(vec![l0, l1]);
        assert_eq!(g.edges.len(), 2);
        let w: Vec<f64> = g.edges.iter().map(|e| e.weight).collect();
        // overlaps 2/min(6,6) and 4/min(6,6)
        assert!(w.contains(&(2.0 / 6.0)));
        assert!(w.contains(&(4.0 / 6.0)));
        // Strongest parent is B.
        assert_eq!(g.parent_of(2), Some(1));
    }

    #[test]
    fn tree_agreement_perfect_on_ideal_graph() {
        // Planted: leaves {0,1}→parent 0, {2,3}→parent 1.
        // Graph level 0: two super-nodes; level 1: four leaf nodes.
        let point_labels: Vec<usize> =
            (0..40).map(|i| i / 10).collect(); // 4 leaf labels, 10 pts each
        let planted_parent = vec![0, 0, 1, 1];
        let l0 = vec![
            HierNode { level: 0, cluster: 0, members: (0..20).collect() },
            HierNode { level: 0, cluster: 1, members: (20..40).collect() },
        ];
        let l1 = vec![
            HierNode { level: 0, cluster: 0, members: (0..10).collect() },
            HierNode { level: 0, cluster: 1, members: (10..20).collect() },
            HierNode { level: 0, cluster: 2, members: (20..30).collect() },
            HierNode { level: 0, cluster: 3, members: (30..40).collect() },
        ];
        let g = build_graph(vec![l0, l1]);
        let score = tree_agreement(&g, 1, &point_labels, &planted_parent);
        assert!((score - 1.0).abs() < 1e-9, "score {score}");
    }
}
