//! K-nearest-neighbour machinery.
//!
//! Three finders share the [`NeighborTable`] representation:
//!
//! * [`brute`] — exact KNN by full scan (ground truth for all metrics);
//! * [`nn_descent`] — Dong et al. [1] nearest-neighbour descent, the
//!   baseline the paper compares against in Figs 7/8;
//! * [`iterative`] — the paper's contribution: *cross-space* iterative
//!   refinement where the HD and LD estimated neighbour sets exchange
//!   candidates, run concurrently with the embedding's gradient descent.

pub mod neighbor_set;
pub mod brute;
pub mod nn_descent;
pub mod iterative;

pub use neighbor_set::NeighborTable;
