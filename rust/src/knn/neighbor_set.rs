//! Fixed-capacity per-point neighbour sets, stored as one contiguous
//! table for all points.
//!
//! Each point owns a slice of `k` slots `(dist, idx)` organised as a
//! binary max-heap on `dist` (worst neighbour at the root), giving O(1)
//! "should I even consider this candidate?" checks and O(log k)
//! replacement. Membership tests are linear scans — `k` ≤ 64 in
//! practice, so a scan over one or two cache lines beats any hash
//! structure.
//!
//! For the sharded refinement passes, [`NeighborTable::rows_mut`]
//! splits the table into disjoint contiguous row-range views
//! ([`RowsMut`]) so each worker thread mutates only the rows it owns,
//! with the borrow checker proving disjointness. Both the whole-table
//! and row-view mutators funnel into the same row-level `row_insert` /
//! `row_rescore` primitives, so sharded and sequential execution are
//! bitwise-identical by construction.

/// Sentinel index for an empty slot.
pub const EMPTY: u32 = u32::MAX;

use std::ops::Range;

/// Core insert into one row's slot arrays (`dists` / `idxs` are that
/// row's `k` slots). Shared by [`NeighborTable::insert`] and
/// [`RowsMut::insert`] — a single implementation is what makes the
/// sharded refinement bitwise-identical to the sequential path.
#[inline]
fn row_insert(
    k: usize,
    owner: usize,
    len: &mut u32,
    dists: &mut [f32],
    idxs: &mut [u32],
    j: u32,
    d: f32,
) -> bool {
    debug_assert!(j != EMPTY);
    if j as usize == owner || !d.is_finite() {
        return false;
    }
    let l = *len as usize;
    if l == k && d >= dists[0] {
        return false; // not better than the worst
    }
    if idxs[..l].contains(&j) {
        return false;
    }
    if l < k {
        // Append then sift up (max-heap).
        let mut slot = l;
        dists[slot] = d;
        idxs[slot] = j;
        *len += 1;
        while slot > 0 {
            let parent = (slot - 1) / 2;
            if dists[parent] < dists[slot] {
                dists.swap(parent, slot);
                idxs.swap(parent, slot);
                slot = parent;
            } else {
                break;
            }
        }
    } else {
        // Replace root then sift down.
        dists[0] = d;
        idxs[0] = j;
        sift_down(dists, idxs, 0, k);
    }
    true
}

/// Restore the max-heap property downward from `slot` over `len` slots.
#[inline]
fn sift_down(dists: &mut [f32], idxs: &mut [u32], mut slot: usize, len: usize) {
    loop {
        let l = 2 * slot + 1;
        let r = 2 * slot + 2;
        let mut largest = slot;
        if l < len && dists[l] > dists[largest] {
            largest = l;
        }
        if r < len && dists[r] > dists[largest] {
            largest = r;
        }
        if largest == slot {
            break;
        }
        dists.swap(slot, largest);
        idxs.swap(slot, largest);
        slot = largest;
    }
}

/// Recompute one row's stored distances and re-heapify (`dists` /
/// `idxs` are the row's *filled* slots). Shared by
/// [`NeighborTable::rescore`] and [`RowsMut::rescore`].
#[inline]
fn row_rescore(dists: &mut [f32], idxs: &mut [u32], mut dist_of: impl FnMut(u32) -> f32) {
    for s in 0..dists.len() {
        dists[s] = dist_of(idxs[s]);
    }
    heapify(dists, idxs);
}

/// A contiguous (n × k) neighbour table.
#[derive(Clone, Debug)]
pub struct NeighborTable {
    k: usize,
    n: usize,
    /// Heap-ordered distances, n*k, f32::INFINITY for empty slots.
    dists: Vec<f32>,
    /// Neighbour indices aligned with `dists`, EMPTY for empty slots.
    idxs: Vec<u32>,
    /// Number of filled slots per point.
    lens: Vec<u32>,
}

impl NeighborTable {
    pub fn new(n: usize, k: usize) -> Self {
        assert!(k >= 1);
        NeighborTable {
            k,
            n,
            dists: vec![f32::INFINITY; n * k],
            idxs: vec![EMPTY; n * k],
            lens: vec![0; n],
        }
    }

    #[inline(always)]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline(always)]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline(always)]
    pub fn len(&self, i: usize) -> usize {
        self.lens[i] as usize
    }

    pub fn is_empty(&self, i: usize) -> bool {
        self.lens[i] == 0
    }

    /// The current worst (largest) distance for point `i`, or +inf if the
    /// set is not yet full — matching the "accept anything" semantics.
    #[inline(always)]
    pub fn worst_dist(&self, i: usize) -> f32 {
        if self.len(i) < self.k {
            f32::INFINITY
        } else {
            self.dists[i * self.k]
        }
    }

    /// Neighbour indices of point `i` (filled slots only, heap order).
    #[inline(always)]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.idxs[i * self.k..i * self.k + self.len(i)]
    }

    /// (idx, dist) pairs for point `i` in heap order.
    pub fn entries(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let base = i * self.k;
        let len = self.len(i);
        (0..len).map(move |s| (self.idxs[base + s], self.dists[base + s]))
    }

    /// Neighbour indices of `i` sorted by ascending distance.
    pub fn sorted_neighbors(&self, i: usize) -> Vec<u32> {
        let mut v: Vec<(f32, u32)> = self.entries(i).map(|(j, d)| (d, j)).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        v.into_iter().map(|(_, j)| j).collect()
    }

    /// Linear membership scan.
    #[inline(always)]
    pub fn contains(&self, i: usize, j: u32) -> bool {
        let base = i * self.k;
        let len = self.len(i);
        self.idxs[base..base + len].contains(&j)
    }

    /// Try to insert neighbour `j` at distance `d` into point `i`'s set.
    /// Returns true iff the set changed. Rejects self-links, duplicates,
    /// and candidates no better than the current worst.
    #[inline]
    pub fn insert(&mut self, i: usize, j: u32, d: f32) -> bool {
        let base = i * self.k;
        row_insert(
            self.k,
            i,
            &mut self.lens[i],
            &mut self.dists[base..base + self.k],
            &mut self.idxs[base..base + self.k],
            j,
            d,
        )
    }

    /// Recompute all stored distances for point `i` with a new metric /
    /// moved coordinates, re-heapifying. Used when LD points move or the
    /// HD metric changes on the fly.
    pub fn rescore(&mut self, i: usize, dist_of: impl FnMut(u32) -> f32) {
        let base = i * self.k;
        let len = self.len(i);
        row_rescore(
            &mut self.dists[base..base + len],
            &mut self.idxs[base..base + len],
            dist_of,
        );
    }

    /// The raw backing storage `(n, k, dists, idxs, lens)`, heap order
    /// and empty-slot sentinels included, for serialization. Feeding it
    /// back through [`NeighborTable::from_raw_parts`] reproduces the
    /// table bitwise — heap layout *is* state here, since insertion
    /// order affects tie-breaking.
    pub fn raw_parts(&self) -> (usize, usize, &[f32], &[u32], &[u32]) {
        (self.n, self.k, &self.dists, &self.idxs, &self.lens)
    }

    /// Rebuild a table from [`NeighborTable::raw_parts`] output,
    /// validating shape and slot invariants (filled slots hold finite
    /// distances and in-range indices; empty slots hold the sentinels).
    pub fn from_raw_parts(
        n: usize,
        k: usize,
        dists: Vec<f32>,
        idxs: Vec<u32>,
        lens: Vec<u32>,
    ) -> Result<NeighborTable, String> {
        if k == 0 {
            return Err("neighbor table: k must be >= 1".to_string());
        }
        if lens.len() != n || dists.len() != n * k || idxs.len() != n * k {
            return Err(format!(
                "neighbor table: shape mismatch (n {n}, k {k}, dists {}, idxs {}, lens {})",
                dists.len(),
                idxs.len(),
                lens.len()
            ));
        }
        for i in 0..n {
            let len = lens[i] as usize;
            if len > k {
                return Err(format!("neighbor table: row {i} len {len} exceeds k {k}"));
            }
            let base = i * k;
            for s in 0..k {
                let idx = idxs[base + s];
                let d = dists[base + s];
                if s < len {
                    if idx == EMPTY || idx as usize >= n || idx as usize == i {
                        return Err(format!(
                            "neighbor table: row {i} slot {s} has invalid index {idx}"
                        ));
                    }
                    if !d.is_finite() {
                        return Err(format!(
                            "neighbor table: row {i} slot {s} has non-finite distance"
                        ));
                    }
                } else if idx != EMPTY || d != f32::INFINITY {
                    return Err(format!(
                        "neighbor table: row {i} slot {s} past len {len} is not empty"
                    ));
                }
            }
        }
        Ok(NeighborTable { k, n, dists, idxs, lens })
    }

    /// Split the table into disjoint mutable row-range views for the
    /// sharded refinement passes: each worker owns one view and can
    /// only reach rows inside it, so concurrent mutation is data-race
    /// free by construction. `ranges` must be ascending, disjoint and
    /// within `[0, n)`; they need not cover every row. Cross-row
    /// *reads* during a mutating pass are not possible through these
    /// views — do them in a separate read-only pass.
    pub fn rows_mut(&mut self, ranges: &[Range<usize>]) -> Vec<RowsMut<'_>> {
        let k = self.k;
        let n = self.n;
        let mut out = Vec::with_capacity(ranges.len());
        let mut dists = self.dists.as_mut_slice();
        let mut idxs = self.idxs.as_mut_slice();
        let mut lens = self.lens.as_mut_slice();
        let mut consumed = 0usize;
        for r in ranges {
            assert!(
                r.start >= consumed && r.start <= r.end && r.end <= n,
                "rows_mut: bad range {r:?} (consumed {consumed}, n {n})"
            );
            // Skip any gap before this range, then split off its rows.
            let skip = r.start - consumed;
            let (_, tail) = dists.split_at_mut(skip * k);
            dists = tail;
            let (_, tail) = idxs.split_at_mut(skip * k);
            idxs = tail;
            let (_, tail) = lens.split_at_mut(skip);
            lens = tail;
            let rows = r.end - r.start;
            let (d_head, d_tail) = dists.split_at_mut(rows * k);
            dists = d_tail;
            let (i_head, i_tail) = idxs.split_at_mut(rows * k);
            idxs = i_tail;
            let (l_head, l_tail) = lens.split_at_mut(rows);
            lens = l_tail;
            out.push(RowsMut {
                k,
                start: r.start,
                rows,
                dists: d_head,
                idxs: i_head,
                lens: l_head,
            });
            consumed = r.end;
        }
        out
    }

    /// Drop every stored reference to point `gone`, and rewrite
    /// references to `moved` (the old last index that swapped into
    /// `gone`'s slot) if provided. Supports dynamic point removal.
    pub fn purge(&mut self, gone: u32, moved: Option<u32>) {
        for i in 0..self.n {
            let base = i * self.k;
            let mut len = self.len(i);
            let mut s = 0;
            while s < len {
                let idx = self.idxs[base + s];
                if idx == gone {
                    // Remove slot s: move last slot in, shrink, re-heapify later.
                    len -= 1;
                    self.dists[base + s] = self.dists[base + len];
                    self.idxs[base + s] = self.idxs[base + len];
                    self.dists[base + len] = f32::INFINITY;
                    self.idxs[base + len] = EMPTY;
                    continue; // re-examine slot s
                }
                if Some(idx) == moved {
                    self.idxs[base + s] = gone; // moved point now lives at `gone`
                }
                s += 1;
            }
            self.lens[i] = len as u32;
            // Restore heap property after removals.
            if len > 1 {
                let d = &mut self.dists[base..base + len];
                let x = &mut self.idxs[base..base + len];
                heapify(d, x);
            }
        }
    }

    /// Add one empty row (dynamic insertion).
    pub fn push_point(&mut self) {
        self.n += 1;
        self.dists.extend(std::iter::repeat(f32::INFINITY).take(self.k));
        self.idxs.extend(std::iter::repeat(EMPTY).take(self.k));
        self.lens.push(0);
    }

    /// Remove the last row (after swap-remove bookkeeping).
    pub fn pop_point(&mut self) {
        assert!(self.n > 0);
        self.n -= 1;
        self.dists.truncate(self.n * self.k);
        self.idxs.truncate(self.n * self.k);
        self.lens.pop();
    }

    /// Clear point `i`'s set (e.g. after it moved to new coordinates).
    pub fn clear_point(&mut self, i: usize) {
        let base = i * self.k;
        for s in 0..self.k {
            self.dists[base + s] = f32::INFINITY;
            self.idxs[base + s] = EMPTY;
        }
        self.lens[i] = 0;
    }

    /// Swap the contents of two rows (dynamic removal bookkeeping).
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for s in 0..self.k {
            self.dists.swap(a * self.k + s, b * self.k + s);
            self.idxs.swap(a * self.k + s, b * self.k + s);
        }
        self.lens.swap(a, b);
    }
}

fn heapify(dists: &mut [f32], idxs: &mut [u32]) {
    let len = dists.len();
    for s in (0..len / 2).rev() {
        sift_down(dists, idxs, s, len);
    }
}

/// A mutable view over a contiguous row range of a [`NeighborTable`],
/// produced by [`NeighborTable::rows_mut`]. Row indices passed to its
/// methods are *absolute* (same coordinates as the whole-table API);
/// reaching outside the view's range panics.
#[derive(Debug)]
pub struct RowsMut<'a> {
    k: usize,
    start: usize,
    rows: usize,
    dists: &'a mut [f32],
    idxs: &'a mut [u32],
    lens: &'a mut [u32],
}

impl RowsMut<'_> {
    /// First absolute row covered by this view.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Rows covered by this view.
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    fn local(&self, i: usize) -> usize {
        assert!(
            i >= self.start && i < self.start + self.rows,
            "row {i} outside view [{}, {})",
            self.start,
            self.start + self.rows
        );
        i - self.start
    }

    /// Same contract as [`NeighborTable::insert`], restricted to this
    /// view's rows.
    #[inline]
    pub fn insert(&mut self, i: usize, j: u32, d: f32) -> bool {
        let li = self.local(i);
        let base = li * self.k;
        row_insert(
            self.k,
            i,
            &mut self.lens[li],
            &mut self.dists[base..base + self.k],
            &mut self.idxs[base..base + self.k],
            j,
            d,
        )
    }

    /// Same contract as [`NeighborTable::rescore`], restricted to this
    /// view's rows.
    pub fn rescore(&mut self, i: usize, dist_of: impl FnMut(u32) -> f32) {
        let li = self.local(i);
        let base = li * self.k;
        let len = self.lens[li] as usize;
        row_rescore(
            &mut self.dists[base..base + len],
            &mut self.idxs[base..base + len],
            dist_of,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    fn heap_ok(t: &NeighborTable, i: usize) -> bool {
        let base = i * t.k;
        let len = t.len(i);
        for s in 0..len {
            let l = 2 * s + 1;
            let r = 2 * s + 2;
            if l < len && t.dists[base + l] > t.dists[base + s] {
                return false;
            }
            if r < len && t.dists[base + r] > t.dists[base + s] {
                return false;
            }
        }
        true
    }

    #[test]
    fn insert_keeps_best_k() {
        let mut t = NeighborTable::new(1, 3);
        assert!(t.insert(0, 10, 5.0));
        assert!(t.insert(0, 11, 3.0));
        assert!(t.insert(0, 12, 4.0));
        // Set is full with worst 5.0; 6.0 must be rejected, 1.0 accepted.
        assert!(!t.insert(0, 13, 6.0));
        assert!(t.insert(0, 14, 1.0));
        let mut sorted = t.sorted_neighbors(0);
        sorted.sort_unstable();
        assert_eq!(sorted, vec![11, 12, 14]);
        assert!((t.worst_dist(0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_self_and_duplicates() {
        let mut t = NeighborTable::new(2, 4);
        assert!(!t.insert(1, 1, 0.0)); // self
        assert!(t.insert(1, 0, 1.0));
        assert!(!t.insert(1, 0, 0.5)); // duplicate (even if closer)
        assert_eq!(t.len(1), 1);
    }

    #[test]
    fn property_heap_and_topk_match_naive() {
        pt::check("neighbor-table-topk", 48, |rng, _| {
            let k = rng.range_usize(1, 9);
            let m = rng.range_usize(1, 60);
            let mut t = NeighborTable::new(1, k);
            let mut naive: Vec<(f32, u32)> = Vec::new();
            // Distinct candidate ids (duplicate-handling is covered by
            // `rejects_self_and_duplicates`; here we verify top-k).
            let mut ids: Vec<usize> = (1..=m).collect();
            rng.shuffle(&mut ids);
            for j in ids {
                let d = rng.f32() * 10.0;
                t.insert(0, j as u32, d);
                naive.push((d, j as u32));
            }
            crate::prop_assert!(heap_ok(&t, 0), "heap violated");
            naive.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            // NOTE: duplicates in the naive list keep the FIRST distance seen,
            // matching table semantics (duplicates rejected).
            let expect: std::collections::HashSet<u32> =
                naive.iter().take(k).map(|&(_, j)| j).collect();
            let got: std::collections::HashSet<u32> =
                t.neighbors(0).iter().copied().collect();
            // Ties at the cut can differ; compare distances instead.
            let worst_expect = naive.get(k.saturating_sub(1)).map(|e| e.0);
            if let Some(we) = worst_expect {
                let mut got_d: Vec<f32> = t.entries(0).map(|(_, d)| d).collect();
                got_d.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let naive_d: Vec<f32> =
                    naive.iter().take(k).map(|&(d, _)| d).collect();
                for (a, b) in got_d.iter().zip(&naive_d) {
                    crate::prop_assert!((a - b).abs() < 1e-6, "top-k dists differ");
                }
                let _ = we;
            } else {
                crate::prop_assert!(expect == got, "sets differ under k");
            }
            Ok(())
        });
    }

    /// The probe/metrics stack leans on three table invariants holding
    /// at ANY insertion order: ranked lists come out sorted by distance,
    /// and are free of duplicates and self-links. The kept top-k
    /// *distance multiset* must also be insertion-order invariant
    /// (candidate ids may differ under exact distance ties at the cut).
    #[test]
    fn property_insert_order_sorted_dupfree_selffree() {
        pt::check("neighbor-insert-order", 48, |rng, _| {
            let k = rng.range_usize(1, 9);
            let m = rng.range_usize(1, 30);
            // Candidate pool over ids 0..=m with one fixed distance per
            // id (0 is the owner, i.e. a self-link), plus duplicate
            // submissions of existing candidates.
            let mut pool: Vec<(u32, f32)> =
                (0..=m as u32).map(|j| (j, rng.f32() * 10.0)).collect();
            for _ in 0..rng.below(m + 1) {
                let dup = pool[rng.below(m + 1)];
                pool.push(dup);
            }
            let build = |order: &[(u32, f32)]| {
                let mut t = NeighborTable::new(1, k);
                for &(j, d) in order {
                    t.insert(0, j, d);
                }
                t
            };
            let t1 = build(&pool);
            let mut shuffled = pool.clone();
            rng.shuffle(&mut shuffled);
            let t2 = build(&shuffled);
            for t in [&t1, &t2] {
                crate::prop_assert!(heap_ok(t, 0), "heap violated");
                let nb = t.sorted_neighbors(0);
                crate::prop_assert!(!nb.contains(&0), "self-link kept");
                let distinct: std::collections::HashSet<u32> = nb.iter().copied().collect();
                crate::prop_assert!(distinct.len() == nb.len(), "duplicate kept");
                // sorted_neighbors is ascending in stored distance.
                let dist_of = |j: u32| t.entries(0).find(|&(jj, _)| jj == j).unwrap().1;
                let mut prev = f32::NEG_INFINITY;
                for &j in &nb {
                    let d = dist_of(j);
                    crate::prop_assert!(d >= prev, "sorted_neighbors not ascending");
                    prev = d;
                }
            }
            let sorted_dists = |t: &NeighborTable| {
                let mut v: Vec<f32> = t.entries(0).map(|(_, d)| d).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            };
            crate::prop_assert!(
                sorted_dists(&t1) == sorted_dists(&t2),
                "top-k distances depend on insertion order"
            );
            Ok(())
        });
    }

    #[test]
    fn rescore_reheapifies() {
        let mut t = NeighborTable::new(1, 4);
        for (j, d) in [(1u32, 1.0f32), (2, 2.0), (3, 3.0), (4, 4.0)] {
            t.insert(0, j, d);
        }
        // Invert the metric: j -> 10 - old d
        t.rescore(0, |j| 10.0 - j as f32);
        assert!(heap_ok(&t, 0));
        assert_eq!(t.worst_dist(0), 9.0); // j=1 now worst
    }

    #[test]
    fn purge_removes_and_renames() {
        let mut t = NeighborTable::new(3, 3);
        t.insert(0, 2, 1.0);
        t.insert(0, 5, 2.0);
        t.insert(1, 5, 0.5);
        t.insert(2, 1, 0.1);
        // Point 2 removed; point 5 (old last) moved into slot 2.
        t.purge(2, Some(5));
        assert!(!t.contains(0, 5)); // renamed to 2
        assert!(t.contains(0, 2)); // the renamed one
        assert_eq!(t.len(0), 1);
        assert!(t.contains(1, 2));
        assert!(t.contains(2, 1)); // untouched entry survives
        assert!(heap_ok(&t, 0) && heap_ok(&t, 1) && heap_ok(&t, 2));
    }

    #[test]
    fn purge_row_with_both_gone_and_moved() {
        // swap-remove of point 2 with old-last point 4 taking its index:
        // a single row holding BOTH must drop the `gone` entry and
        // rename the `moved` entry in the same sweep.
        let mut t = NeighborTable::new(5, 4);
        t.insert(0, 2, 1.0); // gone
        t.insert(0, 4, 2.0); // moved → must become 2
        t.insert(0, 1, 3.0); // untouched
        t.purge(2, Some(4));
        assert_eq!(t.len(0), 2);
        assert!(!t.contains(0, 4), "moved index must be renamed");
        assert!(t.contains(0, 2), "renamed entry must survive");
        assert!(t.contains(0, 1), "unrelated entry must survive");
        // Distances follow their ids through the rename.
        let d2 = t.entries(0).find(|&(j, _)| j == 2).unwrap().1;
        assert!((d2 - 2.0).abs() < 1e-9, "renamed entry kept the wrong dist: {d2}");
        assert!(heap_ok(&t, 0));

        // The removal's backfill slot itself holding `moved`: removing
        // the heap root pulls the last slot forward, and the re-examined
        // slot must still get renamed (regression for the `continue`
        // path).
        let mut t = NeighborTable::new(5, 4);
        t.insert(0, 2, 5.0); // gone at the root (worst dist)
        t.insert(0, 1, 1.0);
        t.insert(0, 4, 2.0); // moved, sits in the backfill slot
        t.purge(2, Some(4));
        assert_eq!(t.len(0), 2);
        assert!(t.contains(0, 2) && t.contains(0, 1) && !t.contains(0, 4));
        assert!(heap_ok(&t, 0));
    }

    #[test]
    fn dynamic_rows() {
        let mut t = NeighborTable::new(2, 2);
        t.push_point();
        assert_eq!(t.n(), 3);
        t.insert(2, 0, 1.0);
        assert_eq!(t.len(2), 1);
        t.swap_rows(0, 2);
        assert_eq!(t.len(0), 1);
        t.pop_point();
        assert_eq!(t.n(), 2);
    }

    #[test]
    fn clear_point_resets() {
        let mut t = NeighborTable::new(1, 2);
        t.insert(0, 1, 1.0);
        t.clear_point(0);
        assert_eq!(t.len(0), 0);
        assert_eq!(t.worst_dist(0), f32::INFINITY);
    }

    /// The contract the sharded refinement passes stand on: inserts and
    /// rescores through disjoint [`RowsMut`] views leave the table in
    /// exactly (bitwise) the state the whole-table methods produce.
    #[test]
    fn rows_mut_matches_whole_table_bitwise() {
        let mut rng = crate::util::Rng::new(31);
        let n = 10usize;
        let k = 4usize;
        let mut ops: Vec<(usize, u32, f32)> = Vec::new();
        for _ in 0..200 {
            ops.push((rng.below(n), rng.below(n) as u32, rng.f32() * 9.0));
        }
        let mut whole = NeighborTable::new(n, k);
        let mut results_whole = Vec::new();
        for &(i, j, d) in &ops {
            results_whole.push(whole.insert(i, j, d));
        }
        let mut sharded = NeighborTable::new(n, k);
        let ranges = [0..3usize, 3..7, 7..10];
        {
            let mut views = sharded.rows_mut(&ranges);
            let mut results = Vec::new();
            for &(i, j, d) in &ops {
                let v = views
                    .iter_mut()
                    .find(|v| i >= v.start() && i < v.start() + v.rows())
                    .unwrap();
                results.push(v.insert(i, j, d));
            }
            assert_eq!(results, results_whole, "insert outcomes differ");
        }
        let state = |t: &NeighborTable| -> Vec<Vec<(u32, u32)>> {
            (0..n).map(|i| t.entries(i).map(|(j, d)| (j, d.to_bits())).collect()).collect()
        };
        assert_eq!(state(&whole), state(&sharded), "slot state differs");
        // Rescore through views == rescore through the table.
        whole.rescore(5, |j| 20.0 - j as f32);
        {
            let mut views = sharded.rows_mut(&[3..7]);
            views[0].rescore(5, |j| 20.0 - j as f32);
        }
        assert_eq!(state(&whole), state(&sharded), "rescore state differs");
    }

    #[test]
    fn rows_mut_supports_gaps_and_partial_cover() {
        let mut t = NeighborTable::new(6, 2);
        let views = t.rows_mut(&[1..2, 4..6]);
        assert_eq!(views.len(), 2);
        assert_eq!((views[0].start(), views[0].rows()), (1, 1));
        assert_eq!((views[1].start(), views[1].rows()), (4, 2));
    }

    #[test]
    #[should_panic(expected = "outside view")]
    fn rows_mut_view_rejects_foreign_row() {
        let mut t = NeighborTable::new(6, 2);
        let mut views = t.rows_mut(&[0..3, 3..6]);
        views[0].insert(4, 1, 1.0); // row 4 belongs to the second view
    }

    #[test]
    #[should_panic(expected = "bad range")]
    fn rows_mut_rejects_overlapping_ranges() {
        let mut t = NeighborTable::new(6, 2);
        let _ = t.rows_mut(&[0..4, 2..6]);
    }
}
